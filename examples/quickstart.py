"""Quickstart: the AEG Control-as-Data pipeline in ~60 lines.

Builds a small neural pipeline, translates it to Runtime Control Blocks
(RCTC), packs weights into a RIMFS image, serializes the *whole workload to
bytes* (control really is data), then provisions + binds + executes it on
the generic engine in both eager (OS-mediated analogue) and fused
(baremetal analogue) modes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import rbl, rctc, rimfs
from repro.core.executor import Executor
from repro.core.rcb import RCBProgram
from repro.core.rtpm import Platform

rng = np.random.RandomState(0)

# 1. Offline toolchain: model -> RCB program + weight image -------------
prog = rctc.compile_conv_relu_softmax(n=2, h=16, w=16, cin=3, cout=10)
weights = {"w_conv": rng.randn(3, 3, 3, 10).astype(np.float32) * 0.3}
image = rimfs.pack(weights)

# control-as-data: the workload is plain bytes (CRC-protected)
program_bytes = prog.encode()
print(f"RCB program: {len(program_bytes)} bytes, "
      f"{sum(len(b.ops) for b in prog.blocks)} ops; "
      f"RIMFS image: {len(image)} bytes")

# 2. Provision (RTPM): load RCBs + weights into the in-memory FS ---------
platform = Platform()
platform.provision(image=image, program_bytes=program_bytes)
print(f"time-to-service: {platform.time_to_service()*1e3:.2f} ms")

# 3. Bind (RBL): symbolic IDs -> physical buffers (zero-copy views) ------
x = rng.randn(2, 16, 16, 3).astype(np.float32)
bound = platform.bind(inputs={"input": x})

# 4. Dispatch + Sync: the generic fetch-decode-dispatch engine ------------
ex = Executor(rtpm=platform)
out_eager = ex.run(bound)["output"]
print("eager  output:", np.round(np.asarray(out_eager[0]), 3))

fused = ex.fuse(platform.bind())            # one XLA program for the stream
out_fused = fused({"input": x}, ex.weights_from(bound))["output"]
print("fused  output:", np.round(np.asarray(out_fused[0]), 3))

diff = float(np.max(np.abs(np.asarray(out_eager) - np.asarray(out_fused))))
print(f"eager == fused: max|diff| = {diff:.2e}")
assert diff < 1e-6
print("OK — same RCBs drive both execution environments.")
