"""Train a ~100M-parameter qwen2-family model on the synthetic pipeline.

Full training substrate: AdamW + cosine schedule, CRC checkpoints with
async save, RTPM telemetry. NOTE: a 108M-param step takes minutes on this
1-core CPU host — this driver is shaped for real accelerators (--steps 300
there); on CPU use --width 256 for a quick functional pass (the serving
example is the paper-kind end-to-end driver).

    PYTHONPATH=src python examples/train_100m.py [--steps N] [--width D]
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--width", type=int, default=768,
                    help="768 -> ~108M params; 256 for a CPU-speed pass")
    ap.add_argument("--ckpt-dir", default="/tmp/aeg_100m_ckpt")
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen2-1.5b",
           "--d-model", str(args.width), "--layers", "12",
           "--steps", str(args.steps), "--batch", "8", "--seq-len", "256",
           "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir,
           "--ckpt-every", "20"]
    sys.exit(subprocess.call(cmd))
