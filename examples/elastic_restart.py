"""Fault-tolerance walkthrough: heartbeat failure -> checkpoint restart ->
elastic re-binding.

Simulates a 4-worker fleet training data-parallel. Worker 2 dies mid-run
(heartbeat deadline); RTPM detects it, training restarts from the latest
CRC-valid checkpoint on the surviving 2-worker fleet, and the deterministic
data pipeline replays the exact global batches — final params match the
uninterrupted run bit-for-bit.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.core.rtpm import HeartbeatMonitor
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.optim.adamw import adamw_init_specs

cfg = get_config("qwen2-1.5b-smoke")
specs = tf.model_specs(cfg)
params0 = init_params(jax.random.PRNGKey(0), specs)
opt0 = init_params(jax.random.PRNGKey(1), adamw_init_specs(specs))
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup=5,
                               total_steps=40))


def batch(i):
    return {k: jnp.asarray(v) for k, v in ds.global_batch_at(i).items()}


# --- uninterrupted reference run (20 steps) --------------------------------
p, o = params0, opt0
for i in range(20):
    p, o, _ = step(p, o, batch(i))
ref = p

# --- fleet run with a failure ----------------------------------------------
clock = [0.0]
mon = HeartbeatMonitor(deadline=5.0, clock=lambda: clock[0])
mgr = CheckpointManager("/tmp/aeg_elastic", keep=2, async_save=False)
workers = [f"w{i}" for i in range(4)]

p, o = params0, opt0
for i in range(12):
    clock[0] += 1.0
    for w in workers:
        mon.beat(w, step=i)
    p, o, _ = step(p, o, batch(i))
    if (i + 1) % 5 == 0:
        mgr.save({"params": p, "opt": o}, step=i + 1)

print("step 12: worker w2 stops heartbeating...")
workers.remove("w2")
clock[0] += 6.0
for w in workers:
    mon.beat(w, step=12)
verdict = mon.check()
print(f"RTPM verdict: failed={verdict['failed']}")
assert verdict["failed"] == ["w2"]

print("restarting from latest CRC-valid checkpoint on 3 workers...")
state, start, _ = mgr.restore_latest({"params": params0, "opt": opt0})
p, o = state["params"], state["opt"]
print(f"restored step {start}; data pipeline re-shards deterministically "
      f"({ds.global_batch} rows -> 3-worker layout not required: global "
      "batch identity is shard-count independent)")
for i in range(start, 20):
    p, o, _ = step(p, o, batch(i))

diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                 b.astype(jnp.float32))))
           for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p)))
print(f"max param diff vs uninterrupted run: {diff:.2e}")
assert diff < 1e-6
print("OK — failure detected, restart bit-exact, fleet shrunk 4 -> 3.")
