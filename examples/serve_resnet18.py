"""End-to-end driver (the paper's kind: network-attached inference).

Spins up the CRC-framed socket service, provisions ResNet-18 over the wire
(RIMFS image + RCB program — the paper's remote provisioning flow), streams
batched requests, and prints the latency/CV telemetry that Table 3 reports.

    PYTHONPATH=src python examples/serve_resnet18.py [n_requests]
"""
import sys
import time

import numpy as np

import jax

from repro.configs.resnet18 import CONFIG
from repro.core import rctc
from repro.models import resnet as rn
from repro.serving.server import Client, InferenceServer

n_requests = int(sys.argv[1]) if len(sys.argv) > 1 else 32
batch = 4

cfg = CONFIG.smoke()
params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=batch)

server = InferenceServer()
addr = server.start()
print(f"serving on {addr}")
try:
    client = Client(addr)
    print("provision:", client.provision(image, prog.encode()))
    rng = np.random.RandomState(0)
    ref_match = 0
    t0 = time.perf_counter()
    for i in range(n_requests):
        x = rng.rand(batch, cfg.image_size, cfg.image_size, 3) \
            .astype(np.float32)
        out = client.infer(input=x)["output"]
        ref = np.asarray(rn.resnet_forward(cfg, params, x))
        ref_match += int(np.allclose(out, ref, atol=1e-5))
    dt = time.perf_counter() - t0
    tel = client.telemetry()
    print(f"{n_requests} requests x batch {batch}: "
          f"{n_requests*batch/dt:.1f} img/s | "
          f"mean={tel['mean']*1e3:.2f} ms  CV={tel['cv_percent']:.2f}%  "
          f"p99={tel['p99']*1e3:.2f} ms")
    print(f"responses matching local oracle: {ref_match}/{n_requests}")
    client.close()
finally:
    server.stop()
