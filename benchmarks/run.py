"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. "eager"/"interpreted" is the
OS-mediated analogue (per-op decode + dispatch + host sync, like Vitis AI's
kernel-crossing path); "linked" is the compiled dispatch path (pre-resolved
thunks, core/linker.py); "fused" is the baremetal analogue (one XLA program
per RCB stream). The paper reports ratios, not absolutes (§5.1) — the
derived column carries the ratio each table is about.

Alongside the CSV, every row lands in ``BENCH_core.json``
(name -> {us_per_call, derived}) so the perf trajectory is machine-checkable
across PRs.

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import gc
import json
import pickle
import re
import statistics
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import opt, partition, rbl, rctc, rhal, rimfs
from repro.core.executor import Executor
from repro.core.rcb import Op, RCBProgram
from repro.core.rtpm import Platform
from repro.models import resnet as rn

ROWS: list[str] = []
RESULTS: dict[str, dict] = {}
PREVIOUS: dict[str, dict] = {}        # prior BENCH_core.json (trend rows)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    RESULTS[name] = {"us_per_call": round(us_per_call, 2),
                     "derived": derived}
    print(row)


def _time(fn, n: int, warmup: int = 3) -> list:
    for _ in range(warmup):
        fn()
    xs = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        xs.append(time.perf_counter() - t0)
    return xs


def _time_steady(fn, n: int, warmup: int) -> list:
    """Steady-state latency samples (the table3 methodology fix).

    JIT warm-up iterations are run and DISCARDED before sampling starts
    (they previously leaked into the CV), and the GC is parked during the
    window so collection pauses don't masquerade as runtime variance.
    ``fn`` must synchronize per iteration (block_until_ready inside) so a
    sample is one real end-to-end latency, not an async enqueue.
    """
    for _ in range(warmup):
        fn()
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        xs = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            xs.append(time.perf_counter() - t0)
    finally:
        if was_enabled:
            gc.enable()
    return xs


def _cv(xs, trim: float = 0.05) -> float:
    """Trimmed CV%: drop the top/bottom ``trim`` fraction (host-contention
    outliers; the paper likewise discards warm-up/outlier iterations)."""
    xs = sorted(xs)
    k = max(1, int(len(xs) * trim))
    xs = xs[k:-k]
    return statistics.stdev(xs) / statistics.fmean(xs) * 100


# ---------------------------------------------------------------------------
# Table 1: per-transfer overhead vs block size (fixed total volume)
# ---------------------------------------------------------------------------

def table1_transfer_overhead(total_mb: float = 2.0) -> None:
    """Per-transfer overhead, per-op dispatch vs control-as-data chain.

    The same n-block transfer stream runs (a) op-at-a-time through the
    eager driver — each block pays the dispatch+sync fixed cost (the
    OS-mediated/ioctl analogue) — and (b) as ONE fused RCB chain — the
    control for all n transfers flattened into a single dispatch (the
    baremetal analogue). Paper Table 1: 7.0x/5.4x/3.0x/2.2x at
    1/4/16/32 KB, decaying as the fixed cost amortizes."""
    rng = np.random.RandomState(0)
    total = int(total_mb * (1 << 20))
    speedups = []
    for kb in (1, 4, 16, 32):
        block = kb << 10
        n = min(256, max(8, total // block))
        floats = block // 4
        prog = rctc.compile_passthrough((floats,))
        bound = rbl.bind(prog, inputs={})
        ex = Executor()
        xs = {f"in{i}": rng.randn(floats).astype(np.float32)
              for i in range(n)}

        def eager():
            for i in range(n):
                ex.run_interpreted(bound, inputs={"input": xs[f"in{i}"]})

        # control-as-data lets the runtime flatten the n-transfer stream
        # into ONE descriptor (paper §5.3: fusion/buffering/batching):
        stacked = np.stack([xs[f"in{i}"] for i in range(n)])
        sprog = rctc.compile_passthrough((n, floats))
        fused = ex.fuse(rbl.bind(sprog, inputs={}))

        def fused_stream():
            jax.block_until_ready(fused({"input": stacked}, {}))

        te = min(_time(eager, 5, warmup=1))
        tf_ = min(_time(fused_stream, 5, warmup=1))
        s = te / tf_
        speedups.append(s)
        emit(f"table1/block_{kb}kb", te / n * 1e6,
             f"speedup={s:.2f}x (eager us/transfer shown)")
    emit("table1/regime", 0.0,
         "small-block advantage "
         + ("CONFIRMED" if speedups[0] > speedups[-1] else "NOT-CONFIRMED")
         + f"; speedups={['%.2f' % s for s in speedups]}")


# ---------------------------------------------------------------------------
# Tables 4/5: matmul + passthrough kernel breakdowns
# ---------------------------------------------------------------------------

def table45_kernel_breakdowns(rng=None) -> None:
    rng = rng or np.random.RandomState(0)
    a = rng.randn(64, 64).astype(np.float32)
    b = rng.randn(64, 64).astype(np.float32)
    prog = rctc.compile_matmul(64, with_dma=True)
    fs = rimfs.mount(rimfs.pack({"b": b}))
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs, inputs={"a": a})

    # eager with per-op traces (paper: 1000 iterations)
    n = 300
    ex.op_traces.clear()
    for _ in range(n):
        ex.run(bound, trace_ops=True)
    by_op: dict = {}
    for t in ex.op_traces:
        by_op.setdefault(t.op, []).append(t.seconds)
    h2d_us = statistics.fmean(by_op[Op.DMA_H2D][n // 10:]) * 1e6
    d2h_us = statistics.fmean(by_op[Op.DMA_D2H][n // 10:]) * 1e6
    gemm_us = statistics.fmean(by_op[Op.GEMM][n // 10:]) * 1e6
    emit("table4/eager_input_transfer", h2d_us, "per-op DMA h2d")
    emit("table4/eager_output_transfer", d2h_us, "per-op DMA d2h")
    emit("table4/eager_kernel_exec", gemm_us, "per-op dispatch")

    bound2 = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound2)
    w = ex.weights_from(bound2)
    t_f = min(_time(lambda: jax.block_until_ready(fused({"a": a}, w)), 30))
    t_e = min(_time(lambda: ex.run_interpreted(bound), 30))
    # fused movement cost = (with-DMA fused) - (no-DMA fused): the compute
    # is identical, the difference is the streamed transfer cost
    prog0 = rctc.compile_matmul(64, with_dma=False)
    b0 = rbl.bind(prog0, rimfs=fs)
    f0 = ex.fuse(b0)
    t_f0 = min(_time(lambda: jax.block_until_ready(f0({"a": a}, w)), 30))
    move_e = h2d_us + d2h_us
    move_f = max((t_f - t_f0) * 1e6, 0.5)
    emit("table4/fused_total", t_f * 1e6,
         f"total_speedup={t_e/t_f:.2f}x; data_movement~"
         f"{move_e/move_f:.1f}x (paper: 3.3x movement, 1.0x kernel)")

    # passthrough: a 32-block transfer stream (pure data movement)
    n, floats = 32, 4096
    prog_p = rctc.compile_passthrough((floats,))
    bp = rbl.bind(prog_p, inputs={})
    xs = {f"in{i}": rng.randn(floats).astype(np.float32) for i in range(n)}

    def p_eager():
        for i in range(n):
            ex.run_interpreted(bp, inputs={"input": xs[f"in{i}"]})

    stacked = np.stack([xs[f"in{i}"] for i in range(n)])
    sp = rctc.compile_passthrough((n, floats))
    fp = ex.fuse(rbl.bind(sp, inputs={}))

    t_pe = min(_time(p_eager, 10))
    t_pf = min(_time(lambda: jax.block_until_ready(
        fp({"input": stacked}, {})), 10))
    emit("table5/passthrough_eager", t_pe / n * 1e6, "us per transfer")
    emit("table5/passthrough_fused", t_pf / n * 1e6,
         f"total_speedup={t_pe/t_pf:.2f}x (paper: 3.0x)")

    # pure data movement with REAL overlap numbers: the same n-block
    # transfer stream as explicit DMA ops, blocking per-op vs the
    # residency plan (batched prefetch prologue + drain epilogue)
    stream = rctc.compile_transfer_pipeline(n, floats)
    feeds = {f"in{i}": xs[f"in{i}"] for i in range(n)}
    bs_int = rbl.bind(stream, inputs=dict(feeds))
    bs_lnk = rbl.bind(stream, inputs=dict(feeds))

    def s_linked():
        jax.block_until_ready(list(ex.run(bs_lnk).values()))

    t_si = min(_time(lambda: ex.run_interpreted(bs_int), 10))
    t_sl = min(_time(s_linked, 10))
    plan = bs_lnk._linked.residency
    emit("table5/stream_perop_dma", t_si / n * 1e6,
         "us per transfer, blocking initiate+wait per block")
    emit("table5/stream_pipelined", t_sl / n * 1e6,
         f"speedup={t_si/t_sl:.2f}x vs per-op (paper: 3.0x); "
         f"moved={plan.bytes_moved}B overlapped={plan.bytes_overlapped}B "
         f"({plan.bytes_overlapped/plan.bytes_moved:.0%} split-phase)")


def table4_dma_pipeline(stages: int = 16, n: int = 64, iters: int = 25,
                        rng=None) -> None:
    """Data-movement overhead: blocking per-op DMA vs the residency plan.

    The same H2D->GEMM->D2H stage pipeline runs (a) interpreted — every
    transfer pays initiate+wait with a host sync (the seed's per-op DMA
    path) — and (b) linked — every H2D issues split-phase in the batched
    prefetch prologue and every D2H drains at the epilogue. Movement
    overhead per mode is isolated by subtracting the identical-compute
    no-DMA variant. Paper Table 4: 3-7x data-movement reduction."""
    rng = rng or np.random.RandomState(0)
    fs = rimfs.mount(rimfs.pack({"b": rng.randn(n, n).astype(np.float32)}))
    ins = {f"in{i}": rng.randn(n, n).astype(np.float32)
           for i in range(stages)}
    ex = Executor()

    def bind(with_dma):
        return rbl.bind(rctc.compile_dma_pipeline(stages, n,
                                                  with_dma=with_dma),
                        rimfs=fs, inputs=dict(ins))

    b_int, b_int0, b_lnk, b_lnk0 = (bind(True), bind(False),
                                    bind(True), bind(False))
    o_int = ex.run_interpreted(b_int)
    o_lnk = ex.run(b_lnk)
    identical = all(np.array_equal(np.asarray(o_int[k]),
                                   np.asarray(jax.block_until_ready(
                                       o_lnk[k]))) for k in o_int)

    def sync_run(b):
        jax.block_until_ready(list(ex.run(b).values()))

    t_i = min(_time(lambda: ex.run_interpreted(b_int), iters))
    t_i0 = min(_time(lambda: ex.run_interpreted(b_int0), iters))
    t_l = min(_time(lambda: sync_run(b_lnk), iters))
    t_l0 = min(_time(lambda: sync_run(b_lnk0), iters))
    move_i = max((t_i - t_i0) * 1e6, 0.5)
    move_l = max((t_l - t_l0) * 1e6, 0.5)
    plan = b_lnk._linked.residency
    emit("table4/movement_perop_dma", move_i,
         f"{stages}-stage pipeline, blocking initiate+wait per transfer")
    emit("table4/movement_pipelined", move_l,
         f"reduction={move_i/move_l:.1f}x vs per-op (target >= 3x, "
         f"paper: 3-7x); bit_identical={identical}")
    emit("table4/movement_overlap_bytes", 0.0,
         f"planned moved={plan.bytes_moved}B "
         f"overlapped={plan.bytes_overlapped}B "
         f"({plan.bytes_overlapped/plan.bytes_moved:.0%} split-phase); "
         f"arena_high_water={plan.high_water}B")


def residency_reuse_bench(rng=None) -> None:
    """Zero re-upload residency: repeated binds + engine constructions
    over one RIMFS image move bytes exactly once (driver DMA counters)."""
    rng = rng or np.random.RandomState(0)
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    folded = rn.fold_bn(params)
    prog, image = rctc.compile_resnet18(cfg, folded, batch=1)
    fs = rimfs.mount(image)
    driver = rhal.make_eager_driver()
    x = rng.rand(1, cfg.image_size, cfg.image_size, 3).astype(np.float32)

    t0 = time.perf_counter()
    b1 = rbl.bind(prog, rimfs=fs, driver=driver, inputs={"input": x})
    t_first = time.perf_counter() - t0
    first_bytes = driver.stats.get("dma_bytes", 0)
    t0 = time.perf_counter()
    for _ in range(10):
        rbl.bind(prog, rimfs=fs, driver=driver, inputs={"input": x})
        rbl.rebind(b1)
    t_re = (time.perf_counter() - t0) / 20
    re_bytes = driver.stats.get("dma_bytes", 0) - first_bytes
    ex = Executor(driver=driver)
    out = np.asarray(jax.block_until_ready(ex.run(b1)["output"]))
    assert out.shape[0] == 1
    emit("residency/first_bind", t_first * 1e6,
         f"uploads {first_bytes}B once (batched split-phase)")
    emit("residency/rebind", t_re * 1e6,
         f"re-uploaded_bytes={re_bytes} over 20 re-binds "
         f"(target: 0); speedup={t_first/max(t_re, 1e-9):.0f}x")


# ---------------------------------------------------------------------------
# Table 2: resource utilization + time-to-network-ready
# ---------------------------------------------------------------------------

def table2_resource_utilization(rng=None) -> None:
    # full-size ResNet-18 weights (the paper's 12.63 MB is the INT8 image;
    # fp32 folded is ~46 MB) so fixed overheads are in realistic proportion
    rng = rng or np.random.RandomState(0)
    cfg = __import__("repro.configs.resnet18", fromlist=["CONFIG"]).CONFIG
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    folded = rn.fold_bn(params)
    prog, image = rctc.compile_resnet18(cfg, folded, batch=1)

    # image size: RIMFS vs OS-stack analogue (pickle of the weight dict)
    blob_os = pickle.dumps({k: np.asarray(v) for k, v in folded.items()})
    emit("table2/image_rimfs_bytes", 0.0, f"{len(image)}")
    emit("table2/image_pickle_bytes", 0.0,
         f"{len(blob_os)}; ratio={len(blob_os)/len(image):.2f}x")

    # runtime memory overhead: RIMFS index vs full deserialization copies
    fs = rimfs.mount(image)
    emit("table2/runtime_overhead_rimfs", 0.0,
         f"{fs.overhead_bytes()}B "
         f"({fs.overhead_bytes()/fs.total_bytes():.2%})")

    # time-to-service: zero-copy mount+bind vs deserialize+copy+stage.
    # (CRC verification is per-message on the wire in the paper; at mount
    # time it is on-demand, so the boot path stays O(header).)
    def aeg_boot():
        plat = Platform()
        plat.provision(image=image, program_bytes=prog.encode(),
                       verify=False)
        plat.bind()

    def os_boot():
        # OS-stack analogue: full deserialization + per-tensor copies +
        # device staging of every tensor
        w = pickle.loads(blob_os)
        w = {k: jnp.asarray(np.array(v, copy=True)) for k, v in w.items()}
        jax.block_until_ready(list(w.values()))

    t_aeg = min(_time(aeg_boot, 10))
    t_os = min(_time(os_boot, 10))
    emit("table2/time_to_service_aeg", t_aeg * 1e6, "")
    emit("table2/time_to_service_os", t_os * 1e6,
         f"ratio={t_os/t_aeg:.1f}x (paper: 350-745x vs Linux boot)")


# ---------------------------------------------------------------------------
# Table 3 / Fig 3: ResNet-18 inference latency, CV, per-device efficiency
# ---------------------------------------------------------------------------

def table3_resnet_inference(rng=None, iters: int = 200) -> None:
    """Latency + CV, steady-state methodology (the PR 2 fix).

    Both modes sample through ``_time_steady``: JIT warm-up iterations are
    discarded BEFORE sampling (previously they leaked into the fused CV —
    22.23%, *worse* than eager: a harness artifact), every iteration ends
    at ``block_until_ready``, the GC is parked, and the CV is trimmed. A
    noise-floor row (CV of a trivial pre-compiled dispatch under the same
    estimator) quantifies the host's irreducible scheduling jitter, so a
    fused CV at the floor reads as "the runtime adds no variance of its
    own" — the paper's determinism property, environment-bounded."""
    rng = rng or np.random.RandomState(0)
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    folded = rn.fold_bn(params)
    prog, image = rctc.compile_resnet18(cfg, folded, batch=1)
    fs = rimfs.mount(image)
    ex = Executor()
    x = rng.rand(1, cfg.image_size, cfg.image_size, 3).astype(np.float32)

    # noise floor: a trivial already-compiled dispatch, same estimator
    tiny = jax.jit(lambda v: v * 2.0)
    tx = jnp.ones((8, 8), jnp.float32)
    floor = _cv(_time_steady(
        lambda: jax.block_until_ready(tiny(tx)), iters, warmup=30))
    emit("table3/noise_floor", 0.0,
         f"cv={floor:.2f}% (host dispatch jitter under the same "
         f"estimator; CVs below are environment-bounded)")

    bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    lat_e = _time_steady(lambda: ex.run_interpreted(bound), iters,
                         warmup=30)

    bound2 = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound2)
    w = ex.weights_from(bound2)
    lat_f = _time_steady(
        lambda: jax.block_until_ready(fused({"input": x}, w)), iters,
        warmup=30)

    cv_e, cv_f = _cv(lat_e), _cv(lat_f)
    mu_e, mu_f = statistics.fmean(lat_e), statistics.fmean(lat_f)
    emit("table3/eager_latency", mu_e * 1e6, f"cv={cv_e:.2f}%")
    emit("table3/fused_latency", mu_f * 1e6, f"cv={cv_f:.2f}%")
    prev = PREVIOUS.get("table3/fused_latency", {}).get("derived", "")
    m = re.search(r"cv=([\d.]+)%", prev)
    emit("table3/cv_trend", 0.0,
         f"fused_cv prev={m.group(1) + '%' if m else 'n/a'} "
         f"now={cv_f:.2f}% floor={floor:.2f}% (steady-state fix: "
         f"warmup discarded, per-iter sync, gc off, 5% trim)")
    # compute efficiency := throughput per device (1 device on this box)
    emit("table3/efficiency_ratio", 0.0,
         f"fused/eager={(1/mu_f)/(1/mu_e):.2f}x (paper: 9.2x per tile); "
         f"cv_ratio={cv_e/max(cv_f,1e-9):.1f}x (paper: 21x)")


# ---------------------------------------------------------------------------
# Batched compiled execution (bucketed batch-axis programs)
# ---------------------------------------------------------------------------

def batched_execution_bench(iters: int = 10, rng=None) -> None:
    """Throughput of ``Executor.run_batched`` per batch bucket vs the
    batch-1 serial linked loop, on the ResNet-18 program.

    Each bucket stages the fused program ONCE under ``jax.vmap`` (inputs
    mapped over a leading axis, weights broadcast; compile cache keyed
    (program CRC, bucket)); the gate is bit-identical per-lane outputs
    AND >= 3x request throughput at bucket 8 — the dispatch fixed cost
    is paid once per bucket instead of once per request."""
    rng = rng or np.random.RandomState(0)
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    fs = rimfs.mount(image)
    driver = rhal.make_eager_driver()
    ex = Executor(driver=driver)
    # bind THROUGH the driver: weights pin device-side once (residency),
    # so neither path re-uploads per dispatch
    bound = rbl.bind(prog, rimfs=fs, driver=driver)
    chunks = 4                 # sustained: chunks-per-bucket measurement
    top = Executor.BATCH_BUCKETS[-1]
    xs = [{"input": rng.rand(1, cfg.image_size, cfg.image_size, 3)
           .astype(np.float32)} for _ in range(chunks * top)]
    refs = [np.asarray(jax.block_until_ready(
        ex.run(bound, inputs=x)["output"])) for x in xs]

    def serial_batch(k: int) -> None:
        # the per-request serving unit batching replaces: one linked
        # dispatch + host materialization of the reply tensors (serial
        # dispatches cannot overlap — each reply synchronizes)
        for x in xs[:k]:
            np.asarray(jax.block_until_ready(
                ex.run(bound, inputs=x)["output"]))

    serial_min = None
    for bucket in Executor.BATCH_BUCKETS:
        n = chunks * bucket
        batch = xs[:n]
        outs = ex.run_batched(bound, batch, max_bucket=bucket)   # warm
        assert ex.batch_stats["buckets"] == [bucket] * chunks
        identical = all(np.array_equal(np.asarray(o["output"]), refs[j])
                        for j, o in enumerate(outs))
        serial_batch(4)
        # serial and batched measured INTERLEAVED so container load
        # drift hits both sides; the paired ratio is the robust stat (a
        # tight same-call serial loop alone runs unrealistically hot)
        ratios, t1s, tbs = [], [], []
        for _ in range(iters):
            t0 = time.perf_counter()
            serial_batch(4)
            t1 = (time.perf_counter() - t0) / 4
            t0 = time.perf_counter()
            ex.run_batched(bound, batch, max_bucket=bucket)
            tb = (time.perf_counter() - t0) / n
            ratios.append(t1 / tb)
            t1s.append(t1)
            tbs.append(tb)
        serial_min = min(t1s) if serial_min is None \
            else min(serial_min, min(t1s))
        per_req = min(tbs)
        emit(f"batched/bucket_{bucket}", per_req * 1e6,
             f"thpt={1 / per_req:.1f}req/s "
             f"vs_batch1_serial={statistics.median(ratios):.2f}x paired"
             + (" (target >= 3x)" if bucket == 8 else "")
             + f" [min-based {min(t1s) / per_req:.2f}x]; "
             f"sustained over {chunks} chunks; "
             f"bit_identical={identical}")
    emit("batched/batch1_serial", serial_min * 1e6,
         "the linked batch-1 dispatch+materialize unit the bucket rows "
         "amortize (measured interleaved with the batched runs)")
    # pad-to-bucket path: 6 requests ride one 8-bucket (2 padded lanes)
    outs = ex.run_batched(bound, xs[:6])
    identical = all(np.array_equal(np.asarray(o["output"]), refs[j])
                    for j, o in enumerate(outs))
    emit("batched/pad_n6", 0.0,
         f"buckets={ex.batch_stats['buckets']} "
         f"padded={ex.batch_stats['padded']} (slice-back); "
         f"bit_identical={identical}")


# ---------------------------------------------------------------------------
# Partitioned multi-tile scaling (paper Fig 3: tile-array deployment)
# ---------------------------------------------------------------------------

def partition_scaling_bench(rng=None, iters: int = 10,
                            stream_samples: int = 48) -> None:
    """Multi-tile scaling, both deployment shapes: the **latency-mode**
    rows (one sample through all stages back-to-back — per-stage
    occupancy shows exactly why adding groups LOSES throughput: every
    group idles while the others run) and the **stream** rows
    (``execute_stream`` software-pipelines a batch of inputs so the
    array stays full; gate: steady-state throughput >= 1.0x the
    single-device linked loop at depth >= 4).

    On this box every tile group is modeled on the same host device, so
    the latency rows also account the cost side of the paper's
    multi-tile story: cut-edge count, inter-tile movement bytes per
    execution (per directed edge), and per-group arena high-water, with
    bit-identical outputs as the gate."""
    rng = rng or np.random.RandomState(0)
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    fs = rimfs.mount(image)
    x = rng.rand(1, cfg.image_size, cfg.image_size, 3).astype(np.float32)
    ex = Executor()

    bound_l = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    t_single = min(_time(lambda: jax.block_until_ready(
        ex.run(bound_l)["output"]), iters))
    ref = np.asarray(jax.block_until_ready(ex.run(bound_l)["output"]))
    emit("partition/single_linked", t_single * 1e6,
         f"throughput={1/t_single:.1f}/s (the 1-device baseline)")

    def occupancy_str(busy_by_gid: dict, wall: float,
                      label: str = "occ") -> str:
        occ = [busy_by_gid.get(g, 0.0) / wall
               for g in sorted(busy_by_gid)]
        out = (f"{label}=[" + ",".join(f"{o:.0%}" for o in occ) + "]")
        if label == "occ":
            out += f" bubble={max(0.0, 1.0 - sum(occ)):.0%}"
        return out

    for n_groups in (1, 2, 4, 8):
        mesh = rhal.TileMesh(n_groups)
        bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
        out = ex.run_partitioned(bound, rimfs=fs, mesh=mesh)   # warm/link
        identical = np.array_equal(
            ref, np.asarray(jax.block_until_ready(out["output"])))
        before = mesh.moved_bytes()
        ex.run_partitioned(bound, rimfs=fs, mesh=mesh)
        per_exec = mesh.moved_bytes() - before
        t_p = min(_time(lambda: jax.block_until_ready(
            ex.run_partitioned(bound, rimfs=fs, mesh=mesh)["output"]),
            iters))
        part = bound._partitions[mesh.n_groups]
        # per-stage occupancy of one timed execution: in latency mode a
        # group is busy only while ITS stage runs, so the occupancy sum
        # falls as 1/groups — the bubble the stream rows close
        stage_times: list = []
        t0 = time.perf_counter()
        jax.block_until_ready(partition.execute(
            part, mesh, rimfs=fs, stage_times=stage_times)["output"])
        wall = time.perf_counter() - t0
        busy: dict = {}
        for gid, sec in stage_times:
            busy[gid] = busy.get(gid, 0.0) + sec
        per_edge = sorted(
            (f"{s}->{d}:{st['bytes'] // st['transfers']}B"
             for (s, d), st in mesh.edge_stats.items()), )
        plans = [t.residency(mesh.group(t.gid).driver)
                 for t in part.tiles]
        high = max((p.high_water for p in plans if p is not None),
                   default=0)
        thpt = 1 / t_p
        emit(f"partition/groups_{n_groups}", t_p * 1e6,
             f"thpt={thpt:.1f}/s per_tile={thpt / n_groups:.1f}/s "
             f"vs_single={thpt * t_single:.2f}x (latency mode); "
             f"{occupancy_str(busy, wall)}; "
             f"cut_edges={len(part.edges)} moved_per_exec={per_exec}B "
             f"[{','.join(per_edge) or 'none'}]; "
             f"max_group_high_water={high}B; bit_identical={identical}")

    # ------------------------- streaming pipeline fill (execute_stream)
    M = stream_samples
    xs = [rng.rand(1, cfg.image_size, cfg.image_size, 3)
          .astype(np.float32) for _ in range(M)]
    refs = [np.asarray(jax.block_until_ready(
        ex.run(bound_l, inputs={"input": xi})["output"])) for xi in xs]
    depth = 4
    for n_groups in (2, 4):
        mesh = rhal.TileMesh(n_groups)
        bound = rbl.bind(prog, rimfs=fs)
        part = partition.partition(bound, n_groups)
        outs = [np.asarray(jax.block_until_ready(o["output"]))
                for o in partition.execute_stream(
                    part, mesh, ({"input": xi} for xi in xs),
                    rimfs=fs, depth=depth)]
        identical = all(np.array_equal(a, b) for a, b in zip(outs, refs))
        stats: dict = {}

        def run_stream():
            for o in partition.execute_stream(
                    part, mesh, ({"input": xi} for xi in xs),
                    rimfs=fs, depth=depth, stats=stats):
                np.asarray(jax.block_until_ready(o["output"]))

        # single-linked re-measured INTERLEAVED with the stream runs so
        # container load drift hits both sides of the gate ratio (same
        # pairing the batched rows use)
        run_stream()                                   # warm
        ratios, t1s, tss = [], [], []
        for _ in range(max(4, iters)):
            t0 = time.perf_counter()
            for xi in xs[:4]:
                np.asarray(jax.block_until_ready(
                    ex.run(bound_l, inputs={"input": xi})["output"]))
            t1s.append((time.perf_counter() - t0) / 4)
            t0 = time.perf_counter()
            run_stream()
            tss.append((time.perf_counter() - t0) / M)
            ratios.append(t1s[-1] / tss[-1])
        t_s = min(tss)
        emit(f"partition/stream_groups_{n_groups}", t_s * 1e6,
             f"thpt={1 / t_s:.1f}/s "
             f"vs_single={statistics.median(ratios):.2f}x paired "
             f"[min-based {min(t1s) / t_s:.2f}x] "
             f"(steady-state target >= 1.0x at depth {depth}"
             + (", GATE" if n_groups == 2 else "") + "); "
             # fused stages dispatch asynchronously, so per-group busy
             # time measures HOST dispatch share, not device utilization
             # (the latency-mode rows' occ/bubble column is the
             # utilization view — their linked stages sync per stage)
             f"{occupancy_str(stats['busy'], tss[-1] * M, 'host_disp')}; "
             f"samples={M} fused_stages={stats['fused']}; "
             f"bit_identical={identical}")


# ---------------------------------------------------------------------------
# Serving concurrency (protocol v2 pipelining + single-dispatcher batching)
# ---------------------------------------------------------------------------

def serving_concurrency_bench(per_client: int = 6, pipeline: int = 3) -> None:
    """Aggregate serving throughput at 1/4/8 concurrent pipelined
    connections against ONE dispatcher-owned device, with a bit-identical
    gate: every concurrent response must equal the serial reference for
    the same input. The dispatcher now coalesces backlogged same-program
    requests into batched dispatches, so aggregate throughput is expected
    to RISE with fan-in instead of flattening; the ``serving_batched``
    row pins the 8-client number against the PR 4 (per-request dispatch)
    baseline. Every row also reports p50/p99 per-request latency —
    batching is not allowed to buy throughput with unobserved tails."""
    import threading

    from repro.serving.server import Client, InferenceServer

    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    server = InferenceServer(max_queue=512)
    addr = server.start()
    try:
        c0 = Client(addr)
        c0.provision(image, prog.encode())
        rng = np.random.RandomState(0)
        max_clients = 8
        xs = {(c, i): rng.rand(1, cfg.image_size, cfg.image_size, 3)
              .astype(np.float32)
              for c in range(max_clients) for i in range(per_client)}
        refs = {k: c0.infer(input=v)["output"] for k, v in xs.items()}

        def pct(lat: list, q: float) -> float:
            lat = sorted(lat)
            return lat[min(len(lat) - 1, int(q * len(lat)))]

        t_base = None
        thpt_8 = lat_8 = None
        for n_clients in (1, 4, 8):
            results: dict = {}
            latencies: list = []
            lat_lock = threading.Lock()

            def run_client(cid: int) -> None:
                cl = Client(addr)
                lats = []
                try:
                    for base in range(0, per_client, pipeline):
                        sent = {}
                        rids = []
                        for i in range(base, min(base + pipeline,
                                                 per_client)):
                            rid = cl.infer_async(input=xs[(cid, i)])
                            sent[rid] = time.perf_counter()
                            rids.append((i, rid))
                        for i, rid in rids:
                            results[(cid, i)] = cl.result(rid)["output"]
                            lats.append(time.perf_counter() - sent[rid])
                finally:
                    cl.close()
                with lat_lock:
                    latencies.extend(lats)

            threads = [threading.Thread(target=run_client, args=(c,))
                       for c in range(n_clients)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            dt = time.perf_counter() - t0
            n = n_clients * per_client
            identical = len(results) == n and all(
                np.array_equal(results[k], refs[k]) for k in results)
            thpt = n / dt
            if t_base is None:
                t_base = thpt
            lat_str = (f"p50={pct(latencies, 0.5)*1e3:.1f}ms "
                       f"p99={pct(latencies, 0.99)*1e3:.1f}ms")
            if n_clients == 8:
                thpt_8, lat_8 = thpt, lat_str
            emit(f"serving_concurrency/clients_{n_clients}", dt / n * 1e6,
                 f"agg_thpt={thpt:.1f}req/s vs_1client={thpt/t_base:.2f}x "
                 f"(pipeline depth {pipeline}); {lat_str}; "
                 f"bit_identical={identical}")
        tel = c0.telemetry()["serving"]
        bt = tel.get("batched", {})
        emit("serving_concurrency/dispatcher", 0.0,
             f"processed={tel['processed']} rejected={tel['rejected']} "
             f"shed={tel['shed']} "
             f"batched_dispatches={bt.get('dispatches', 0)} "
             f"batched_requests={bt.get('requests', 0)} "
             f"max_batch={bt.get('max_batch', 0)} "
             f"queue_wait_p95={tel['queue_wait'].get('p95', 0)*1e3:.2f}ms")
        # aggregate 8-client throughput vs the committed PR 4 baseline
        # (per-request dispatch): the coalescing win, trend-tracked
        prev = PREVIOUS.get("serving_concurrency/clients_8",
                            {}).get("derived", "")
        m = re.search(r"agg_thpt=([\d.]+)req/s", prev)
        base = float(m.group(1)) if m else None
        emit("serving_batched/clients_8", 0.0,
             f"agg_thpt={thpt_8:.1f}req/s vs_pr4_baseline="
             + (f"{thpt_8 / base:.2f}x (prev {base:.1f}req/s)" if base
                else "n/a (no prior row)")
             + f"; {lat_8}; coalesced={bt.get('requests', 0)} reqs in "
             f"{bt.get('dispatches', 0)} batched dispatches")
        c0.close()
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# Pallas kernels: linked opcode vs GRAPH_EXEC artifact, per family (ISSUE 9)
# ---------------------------------------------------------------------------

def kernel_microbench(rng=None, iters: int = 10) -> None:
    """kernels/* rows: each Pallas kernel dispatched as its linked RCB
    opcode (Op.ATTENTION/MATMUL_INT8/SSM_SCAN/WKV6 through the RHAL
    ``link_compute`` registry handler) vs the SAME registry math wrapped
    as one monolithic GRAPH_EXEC artifact — the pre-registry lowering.
    Both run through ``Executor.run`` on an identically shaped one-op
    program, so the delta is pure dispatch-path cost; the derived column
    carries the ratio plus a match gate (``compare.py
    check_kernel_gates``, warn-only). The interpret-mode wrapper rows
    stay as raw-latency trend lines."""
    rng = rng or np.random.RandomState(0)
    from repro.core.rcb import RCB, RCBOp, TensorDesc
    from repro.kernels import registry as kreg
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.int8_matmul.ops import int8_matmul
    q = jnp.asarray(rng.randn(1, 128, 4, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 128, 2, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 128, 2, 64), jnp.float32)
    t = min(_time(lambda: flash_attention(q, k, v).block_until_ready(), 5))
    emit("kernels/flash_attention_interpret", t * 1e6, "vs ref in tests")
    xi = jnp.asarray(rng.randint(-127, 128, (128, 128)), jnp.int8)
    wi = jnp.asarray(rng.randint(-127, 128, (128, 128)), jnp.int8)
    s = jnp.asarray(rng.rand(128).astype(np.float32))
    t = min(_time(lambda: int8_matmul(xi, wi, s).block_until_ready(), 5))
    emit("kernels/int8_matmul_interpret", t * 1e6, "vs ref in tests")

    def arr(*shape):
        return jnp.asarray(rng.randn(*shape), jnp.float32)

    lw = -jnp.abs(arr(1, 32, 2, 16)).clip(0.05, 3.0)
    cases = {
        "attention": (Op.ATTENTION, (arr(1, 64, 4, 32), arr(1, 64, 2, 32),
                                     arr(1, 64, 2, 32)), {"causal": True}),
        "matmul_int8": (Op.MATMUL_INT8, (xi, wi, s),
                        {"out_dtype": "float32"}),
        "ssm_scan": (Op.SSM_SCAN, (-jnp.abs(arr(1, 32, 8, 4)),
                                   arr(1, 32, 8, 4), arr(1, 32, 4)), {}),
        "wkv6": (Op.WKV6, (arr(1, 32, 2, 16), arr(1, 32, 2, 16),
                           arr(1, 32, 2, 16), lw, arr(2, 16)), {}),
    }
    ex = Executor()
    for name, (opcode, args, attrs) in cases.items():
        ref = jax.block_until_ready(kreg.call_op(name, args, attrs))
        tensors = {f"in{i}": TensorDesc(f"in{i}", tuple(a.shape),
                                        str(a.dtype), "input")
                   for i, a in enumerate(args)}
        tensors["out"] = TensorDesc("out", tuple(ref.shape),
                                    str(ref.dtype), "output")
        srcs = tuple(f"in{i}" for i in range(len(args)))
        ins = {f"in{i}": np.asarray(a) for i, a in enumerate(args)}
        prog_k = RCBProgram(f"bench_k_{name}", dict(tensors), [RCB(
            0, "layer", (), (RCBOp(opcode, ("out",), srcs, attrs),
                             RCBOp(Op.FENCE)))])
        prog_g = RCBProgram(f"bench_g_{name}", dict(tensors), [RCB(
            0, "layer", (), (RCBOp(Op.GRAPH_EXEC, ("out",), srcs,
                                   {"artifact": name}),
                             RCBOp(Op.FENCE)))],
            artifacts={name: jax.jit(
                lambda *xs, _n=name, _a=attrs: kreg.call_op(_n, xs, _a))})
        b_k = rbl.bind(prog_k, inputs=dict(ins))
        b_g = rbl.bind(prog_g, inputs=dict(ins))
        o_k = np.asarray(jax.block_until_ready(ex.run(b_k)["out"]))
        o_g = np.asarray(jax.block_until_ready(ex.run(b_g)["out"]))
        match = np.allclose(o_k, o_g, rtol=0, atol=1e-6)
        t_g = min(_time(lambda: jax.block_until_ready(
            ex.run(b_g)["out"]), iters))
        t_k = min(_time(lambda: jax.block_until_ready(
            ex.run(b_k)["out"]), iters))
        emit(f"kernels/{name}_graph_exec", t_g * 1e6,
             "monolithic artifact dispatch (pre-registry lowering)")
        emit(f"kernels/{name}_linked", t_k * 1e6,
             f"vs_graph_exec={t_g / t_k:.2f}x; match={match}; "
             f"bit_identical={np.array_equal(o_k, o_g)}; "
             f"params={kreg.params_for(name, args)}")


# ---------------------------------------------------------------------------
# Core dispatch spine: linked vs interpreted, v1 vs v2 wire, peephole pass
# ---------------------------------------------------------------------------

def core_dispatch_bench(rng=None, iters: int = 30) -> None:
    """The two hottest runtime fixed costs, before/after this PR's compiled
    path: per-op dispatch (interpreted decode loop vs linked thunks) and
    program load (JSON-v1 vs packed-v2 decode)."""
    rng = rng or np.random.RandomState(0)
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    folded = rn.fold_bn(params)
    raw, image = rctc.compile_resnet18(cfg, folded, batch=1,
                                       optimize=False)
    optd, _ = rctc.compile_resnet18(cfg, folded, batch=1, optimize=True)
    fs = rimfs.mount(image)
    x = rng.rand(1, cfg.image_size, cfg.image_size, 3).astype(np.float32)
    ex = Executor()
    n_ops = opt.op_count(raw)

    # --- dispatch: interpreted baseline vs linked thunk loop (same program)
    bound_i = rbl.bind(raw, rimfs=fs, inputs={"input": x})
    bound_l = rbl.bind(raw, rimfs=fs, inputs={"input": x})
    t_int = min(_time(lambda: ex.run_interpreted(bound_i), iters))
    t_lnk = min(_time(lambda: jax.block_until_ready(
        ex.run(bound_l)["output"]), iters))
    ops_int, ops_lnk = n_ops / t_int, n_ops / t_lnk
    emit("core/dispatch_interpreted_resnet18", t_int * 1e6,
         f"ops_per_sec={ops_int:.0f}")
    emit("core/dispatch_linked_resnet18", t_lnk * 1e6,
         f"ops_per_sec={ops_lnk:.0f}; speedup={ops_lnk/ops_int:.2f}x "
         f"vs interpreted (target >= 2x)")

    # --- bit-identical equivalence across all three modes + peephole
    o_int = np.asarray(ex.run_interpreted(bound_i)["output"])
    o_lnk = np.asarray(jax.block_until_ready(ex.run(bound_l)["output"]))
    bound_o = rbl.bind(optd, rimfs=fs, inputs={"input": x})
    o_opt = np.asarray(jax.block_until_ready(ex.run(bound_o)["output"]))
    bound_f = rbl.bind(optd, rimfs=fs)
    fused = ex.fuse(bound_f)
    o_fus = np.asarray(jax.block_until_ready(
        fused({"input": x}, ex.weights_from(bound_f))["output"]))
    identical = (np.array_equal(o_int, o_lnk)
                 and np.array_equal(o_lnk, o_opt)
                 and np.array_equal(o_lnk, o_fus))
    n_opt = opt.op_count(optd)
    emit("core/peephole_resnet18_opcount", 0.0,
         f"raw={n_ops} optimized={n_opt} "
         f"reduction={(n_ops - n_opt) / n_ops:.1%} (target >= 15%); "
         f"bit_identical={identical} (interpreted/linked/fused)")

    # --- wire format: v1 (per-op JSON) vs v2 (interned symtab + packed)
    cfg_f = __import__("repro.configs.resnet18", fromlist=["CONFIG"]).CONFIG
    params_f = rn.init_resnet(jax.random.PRNGKey(0), cfg_f)
    prog_f, _ = rctc.compile_resnet18(cfg_f, rn.fold_bn(params_f), batch=1,
                                      optimize=False)
    b1 = prog_f.encode(version=1)
    b2 = prog_f.encode(version=2)
    assert RCBProgram.decode(b1).blocks == RCBProgram.decode(b2).blocks
    reps = 200
    te1 = min(_time(lambda: prog_f.encode(version=1), reps))
    te2 = min(_time(lambda: prog_f.encode(version=2), reps))
    td1 = min(_time(lambda: RCBProgram.decode(b1), reps))
    td2 = min(_time(lambda: RCBProgram.decode(b2), reps))
    emit("core/encode_v1", te1 * 1e6,
         f"{len(b1)/te1/1e6:.1f}MB/s size={len(b1)}B")
    emit("core/encode_v2", te2 * 1e6,
         f"{len(b2)/te2/1e6:.1f}MB/s size={len(b2)}B; "
         f"speedup={te1/te2:.2f}x vs v1")
    emit("core/decode_v1", td1 * 1e6, f"{len(b1)/td1/1e6:.1f}MB/s")
    emit("core/decode_v2", td2 * 1e6,
         f"{len(b2)/td2/1e6:.1f}MB/s; speedup={td1/td2:.2f}x vs v1 "
         f"(target >= 3x)")


# ---------------------------------------------------------------------------
# Integrity plane: CRC-verify overhead + corruption-recovery cost
# ---------------------------------------------------------------------------

def integrity_bench(iters: int = 200, rng=None) -> None:
    """Cost of the end-to-end DMA integrity plane (ISSUE 7).

    ``crc_verify_overhead``: the same h2d issue+wait loop with endpoint
    CRC verification on vs off — the steady-state tax every checked
    transfer pays (one host-side crc32 at issue + one at redeem).
    ``corrupt_retry_recovery``: one corrupted delivery detected at
    redeem and healed by the bounded in-place re-issue, with the
    bit-identical gate — the price of a caught fault, not of the
    common path."""
    rng = rng or np.random.RandomState(0)
    x = rng.randn(256, 256).astype(np.float32)      # 256 KB payload

    def roundtrip(drv):
        t = drv.dma_async(x, "h2d")
        jax.block_until_ready(drv.dma_wait(t))

    d_on = rhal.make_eager_driver()
    d_off = rhal.make_eager_driver()
    d_off.integrity.enabled = False
    t_on = min(_time(lambda: roundtrip(d_on), iters, warmup=10))
    t_off = min(_time(lambda: roundtrip(d_off), iters, warmup=10))
    assert d_on.stats.get("dma_crc_checked", 0) > 0
    assert d_off.stats.get("dma_crc_checked", 0) == 0
    emit("integrity/crc_verify_overhead", (t_on - t_off) * 1e6,
         f"checked={t_on*1e6:.2f}us unchecked={t_off*1e6:.2f}us "
         f"overhead={(t_on/t_off - 1)*100:.1f}% per 256KB h2d "
         f"(issue-time stamp + redeem-time verify; dominated by the "
         f"verify readback a real DMA engine computes inline)")

    # one-shot corruption: flip a delivered bit after issue, measure the
    # detect + re-issue + re-verify path at redeem
    drv = rhal.make_eager_driver()
    recs = []
    for _ in range(max(5, iters // 20)):
        t = drv.dma_async(x, "h2d")
        bad = np.array(x, copy=True)
        bad.reshape(-1).view(np.uint8)[0] ^= 0x01
        t.buf = jax.device_put(jnp.asarray(bad))
        t0 = time.perf_counter()
        out = jax.block_until_ready(drv.dma_wait(t))
        recs.append(time.perf_counter() - t0)
        assert np.array_equal(np.asarray(out), x)   # bit-identical heal
    emit("integrity/corrupt_retry_recovery", min(recs) * 1e6,
         f"detect+reissue+verify per caught fault; "
         f"recovered={drv.stats.get('dma_retry_recovered', 0)} "
         f"mismatches={drv.stats.get('dma_crc_mismatch', 0)}; "
         f"bit_identical=True")


# ---------------------------------------------------------------------------
# Fleet operations: scale cycle + hot swap + kill/heal under live traffic
# ---------------------------------------------------------------------------

def _load_chaos():
    """Load tests/chaos.py as a module (it lives outside the package)."""
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).resolve().parent.parent / "tests" / \
        "chaos.py"
    spec = importlib.util.spec_from_file_location("chaos_bench", path)
    chaos = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(chaos)
    return chaos


def fleet_operations_bench(quick: bool = False) -> None:
    """One seeded chaos scenario (tests/chaos.py): a 2 -> peak -> 2 scale
    cycle, a hot weight swap, a forced bad swap and a tile-group kill all
    land mid-traffic; the rows carry the robustness gate — zero failed
    requests, bit-identical responses, bounded p99."""
    chaos = _load_chaos()
    p99_bound_s = 30.0
    if quick:
        rep = chaos.run_chaos(groups=2, seed=7, requests=30, clients=2,
                              scale_peak=4, pace_s=0.01, dma_delay_s=0.1,
                              p99_bound_s=p99_bound_s)
    else:
        rep = chaos.run_chaos(groups=2, seed=7, requests=90, clients=3,
                              scale_peak=8, p99_bound_s=p99_bound_s)
    violations = chaos.check_report(rep)
    bit_identical = rep["mismatches"] == 0 and rep["ok"] == rep["sent"]
    tm = rep["timings"]
    emit("fleet/scale_cycle", rep["p50_s"] * 1e6,
         f"failed_requests={rep['failed']} "
         f"p99={rep['p99_s'] * 1e3:.1f}ms "
         f"p99_bound={rep['p99_bound_s'] * 1e3:.0f}ms "
         f"bit_identical={bit_identical} "
         f"up={tm['scale_up'] * 1e3:.1f}ms "
         f"down={tm['scale_down'] * 1e3:.1f}ms "
         f"violations={len(violations)}")
    emit("fleet/weight_swap", tm["swap_good"] * 1e6,
         f"result={rep['good_swap']} (probe + atomic flip, "
         f"zero dropped requests)")
    emit("fleet/chaos_kill", tm["kill_to_heal"] * 1e6,
         f"kill->heal_complete under traffic; "
         f"final_groups={rep['n_groups_final']}")
    emit("fleet/bad_swap_rollback", tm["swap_bad"] * 1e6,
         f"result={rep['bad_swap']} (conformance probe caught the "
         f"wrong weights; old binding kept serving)")


def rollout_control_bench(quick: bool = False) -> None:
    """Safe-rollout & overload control plane rows (ISSUE 10).

    ``fleet/canary_overhead``: per-request cost of a fully-sampled
    canary — fraction=1.0 + sample=1.0 means EVERY request dual-runs
    primary + shadow and bit-compares, the worst-case tax; production
    fractions pay it on the routed slice only. ``fleet/partial_reshape_
    ms``: kill -> splice latency of replacing ONE tile group in place,
    gated on zero survivor DMA bytes. ``overload/recovery_time``: the
    rollout chaos scenario's burst -> ladder -> rung-0 walk-back, with
    the scenario's full invariant checklist folded into the derived
    column (compare.py check_rollout_gates, warn-only)."""
    from repro.core.fleet import FleetController
    from repro.serving.server import Client, InferenceServer

    depth, n = (4, 16) if quick else (8, 24)
    prog = rctc.compile_gemm_chain(depth, n)
    files = rctc.gemm_chain_weights(depth, n)
    image = rimfs.pack(files)
    server = InferenceServer(mesh=rhal.TileMesh(4))
    addr = server.start()
    client = Client(addr)
    try:
        client.provision(image, prog.encode())
        fleet = FleetController(server)
        x = np.random.RandomState(0).randn(n, n).astype(np.float32)
        ref = client.infer(input=x)
        iters = 8 if quick else 16
        t_plain = min(_time(lambda: client.infer(input=x), iters,
                            warmup=2))
        assert fleet.canary(image, fraction=1.0,
                            label="bench") == "started"
        t_can = min(_time(lambda: client.infer(input=x), iters,
                          warmup=2))
        fleet.abort_canary(reason="bench")
        out = client.infer(input=x)
        identical = all(np.array_equal(ref[k], out[k]) for k in ref)
        emit("fleet/canary_overhead", (t_can - t_plain) * 1e6,
             f"dual_run={t_can / t_plain:.2f}x vs primary-only per "
             f"request (fraction=1.0, sample=1.0: every request "
             f"bit-compared); bit_identical={identical}")

        mesh = server.mesh
        times = []
        zero_bytes = True
        for i in range(3 if quick else 5):
            gid = 1 + (i % (mesh.n_groups - 1))
            survivors = {g: mesh.group(g).driver.stats.get("dma_bytes", 0)
                         for g in mesh.gids if g != gid}
            mesh.kill(gid)
            t0 = time.perf_counter()
            fleet.replace_group(gid, reason="bench")
            times.append(time.perf_counter() - t0)
            zero_bytes &= all(
                mesh.group(g).driver.stats.get("dma_bytes", 0) == b
                for g, b in survivors.items())
        out = client.infer(input=x)
        identical = all(np.array_equal(ref[k], out[k]) for k in ref)
        emit("fleet/partial_reshape_ms", min(times) * 1e6,
             f"{min(times) * 1e3:.1f}ms kill->splice (fsck + spawn + "
             f"one-group prewarm + CRC revalidate + install_group); "
             f"survivors_zero_bytes={zero_bytes}; "
             f"bit_identical={identical}")
    finally:
        client.close()
        server.stop()

    chaos = _load_chaos()
    rep = chaos.run_rollout_chaos(groups=2, seed=7,
                                  requests=60 if quick else 90,
                                  clients=3)
    violations = chaos.check_rollout_report(rep)
    ov = rep["overload"]
    rec = rep["timings"].get("overload_recovery", 0.0)
    emit("overload/recovery_time", rec * 1e6,
         f"burst->rung{ov['max_rung']}->rung{ov['final_rung']} "
         f"recovered={ov['recovered']} breaker={ov['breaker']['state']} "
         f"canary_good={rep.get('canary_good')} "
         f"canary_bad={rep.get('canary_bad')} "
         f"reshape={rep.get('reshape', {}).get('happened')} "
         f"violations={len(violations)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke profile: minimal iteration counts")
    ap.add_argument("--json", default="BENCH_core.json",
                    help="machine-readable results path")
    ap.add_argument("--baseline", default="BENCH_core.json",
                    help="prior results the trend rows compare against "
                         "(kept separate from --json so CI can write "
                         "fresh results without losing the committed "
                         "baseline)")
    args = ap.parse_args()
    quick = args.quick or args.smoke
    try:
        with open(args.baseline) as f:
            PREVIOUS.update(json.load(f))          # trend rows
    except (OSError, ValueError):
        pass
    print("name,us_per_call,derived")
    core_dispatch_bench(iters=10 if quick else 30)
    batched_execution_bench(iters=5 if quick else 10)
    table1_transfer_overhead(total_mb=1.0 if quick else 4.0)
    table45_kernel_breakdowns()
    table4_dma_pipeline(iters=10 if quick else 25)
    partition_scaling_bench(iters=5 if quick else 10,
                            stream_samples=24 if quick else 48)
    residency_reuse_bench()
    table2_resource_utilization()
    table3_resnet_inference(iters=50 if quick else 200)
    serving_concurrency_bench(per_client=3 if quick else 6)
    from benchmarks.decode_bench import run_sweep as lm_decode_sweep
    lm_decode_sweep(emit, quick=quick)
    integrity_bench(iters=50 if quick else 200)
    fleet_operations_bench(quick=quick)
    rollout_control_bench(quick=quick)
    kernel_microbench()
    with open(args.json, "w") as f:
        json.dump(RESULTS, f, indent=2, sort_keys=True)
    print(f"# {len(ROWS)} rows -> {args.json}")


if __name__ == "__main__":
    main()
