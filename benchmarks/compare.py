"""Warn-only benchmark regression diff (CI perf-drift visibility).

Compares a freshly produced ``BENCH_core.json`` against the committed
one and prints a GitHub Actions ``::warning::`` annotation for every row
whose ``us_per_call`` regressed past the threshold — so perf drift shows
up in PR logs without flaking the build on noisy CI containers (the
exit code is ALWAYS 0; these numbers gate by eyeball, not by assert).

Rows with ``us_per_call == 0`` are informational (derived-only gates —
bit-identical flags, byte counts) and are skipped; rows present on only
one side are listed as added/removed.

Run: python benchmarks/compare.py BENCH_core.json BENCH_fresh.json
"""
from __future__ import annotations

import argparse
import json
import re
import sys


def check_fleet_gates(new: dict) -> int:
    """Warn-only robustness gates over the fleet/* rows: zero failed
    requests, bit-identical responses, p99 under its bound, swaps landing
    on the right side (committed / rolled_back). Returns the number of
    warnings emitted — informational, never fails the build."""
    warned = 0

    def warn(name: str, msg: str) -> None:
        nonlocal warned
        warned += 1
        print(f"::warning title=fleet gate::{name}: {msg}")

    d = new.get("fleet/scale_cycle", {}).get("derived", "")
    if d:
        m = re.search(r"failed_requests=(\d+)", d)
        if m and int(m.group(1)) != 0:
            warn("fleet/scale_cycle", f"{m.group(1)} failed requests "
                 f"(gate: 0)")
        m = re.search(r"p99=([\d.]+)ms", d)
        b = re.search(r"p99_bound=([\d.]+)ms", d)
        if m and b and float(m.group(1)) > float(b.group(1)):
            warn("fleet/scale_cycle", f"p99 {m.group(1)}ms past bound "
                 f"{b.group(1)}ms")
        if "bit_identical=False" in d:
            warn("fleet/scale_cycle", "responses not bit-identical")
    d = new.get("fleet/weight_swap", {}).get("derived", "")
    if d and "result=committed" not in d:
        warn("fleet/weight_swap", "hot swap did not commit")
    d = new.get("fleet/bad_swap_rollback", {}).get("derived", "")
    if d and "result=rolled_back" not in d:
        warn("fleet/bad_swap_rollback",
             "bad-weight swap was not rolled back")
    return warned


def check_integrity_gates(new: dict) -> int:
    """Warn-only gates over the integrity/* rows (ISSUE 7): a caught
    corruption must heal bit-identically, and the steady-state CRC tax
    must stay modest (the plane is supposed to be cheap enough to leave
    on). Informational, never fails the build."""
    warned = 0

    def warn(name: str, msg: str) -> None:
        nonlocal warned
        warned += 1
        print(f"::warning title=integrity gate::{name}: {msg}")

    d = new.get("integrity/corrupt_retry_recovery", {}).get("derived", "")
    if d:
        if "bit_identical=True" not in d:
            warn("integrity/corrupt_retry_recovery",
                 "corruption recovery not bit-identical")
        m = re.search(r"recovered=(\d+)", d)
        if m and int(m.group(1)) == 0:
            warn("integrity/corrupt_retry_recovery",
                 "no retry-recovered transfers recorded")
    d = new.get("integrity/crc_verify_overhead", {}).get("derived", "")
    if d:
        # the modeled verify pays a host readback a real DMA engine
        # computes inline, so the bound is the pathological level, not
        # a production budget
        m = re.search(r"overhead=(-?[\d.]+)%", d)
        if m and float(m.group(1)) > 1000.0:
            warn("integrity/crc_verify_overhead",
                 f"CRC verification tax {m.group(1)}% past 1000% "
                 f"(runaway verify path)")
    return warned


def check_lm_decode_gates(new: dict) -> int:
    """Warn-only gates over the lm_decode/* rows (ISSUE 8): every
    speedup row must stay bit-identical to the dense engine (greedy),
    and the gate cells (batch 8, occupancy >= 50%) must hold the >= 2x
    paged-vs-dense throughput floor. Informational, never fails the
    build."""
    warned = 0

    def warn(name: str, msg: str) -> None:
        nonlocal warned
        warned += 1
        print(f"::warning title=lm_decode gate::{name}: {msg}")

    for name, row in sorted(new.items()):
        if not name.startswith("lm_decode/speedup_"):
            continue
        d = row.get("derived", "")
        if "bit_identical=True" not in d:
            warn(name, "paged decode not bit-identical to dense (greedy)")
        m = re.search(r"paged_vs_dense=([\d.]+)x", d)
        if "GATE" in d and m and float(m.group(1)) < 2.0:
            warn(name, f"paged/dense throughput {m.group(1)}x "
                 f"under the 2x gate")
    return warned


def check_kernel_gates(new: dict) -> int:
    """Warn-only gates over the kernels/* rows (ISSUE 9): every linked
    kernel opcode must match its GRAPH_EXEC artifact twin (same registry
    math, two dispatch paths — a mismatch means the RHAL handler and the
    monolithic artifact diverged), and linked dispatch must stay within
    3x of the monolithic artifact's latency (the per-layer lowering is
    not allowed to price kernel ops out of the compiled path).
    Informational, never fails the build."""
    warned = 0

    def warn(name: str, msg: str) -> None:
        nonlocal warned
        warned += 1
        print(f"::warning title=kernel gate::{name}: {msg}")

    for name, row in sorted(new.items()):
        if not (name.startswith("kernels/") and name.endswith("_linked")):
            continue
        d = row.get("derived", "")
        if "match=True" not in d:
            warn(name, "linked kernel op diverged from its GRAPH_EXEC "
                 "artifact twin")
        m = re.search(r"vs_graph_exec=([\d.]+)x", d)
        if m and float(m.group(1)) < 0.33:
            warn(name, f"linked dispatch at {m.group(1)}x of the "
                 f"GRAPH_EXEC artifact (gate: >= 0.33x)")
    return warned


def check_rollout_gates(new: dict) -> int:
    """Warn-only gates over the safe-rollout & overload rows (ISSUE 10):
    canary serving must stay bit-identical (wrong bytes never reach a
    client), a partial reshape must move ZERO survivor weight bytes, and
    the rollout chaos scenario must converge — both canaries decided
    correctly, ladder walked back to rung 0, zero invariant violations.
    Informational, never fails the build."""
    warned = 0

    def warn(name: str, msg: str) -> None:
        nonlocal warned
        warned += 1
        print(f"::warning title=rollout gate::{name}: {msg}")

    d = new.get("fleet/canary_overhead", {}).get("derived", "")
    if d and "bit_identical=True" not in d:
        warn("fleet/canary_overhead", "canary serving not bit-identical")
    d = new.get("fleet/partial_reshape_ms", {}).get("derived", "")
    if d:
        if "survivors_zero_bytes=True" not in d:
            warn("fleet/partial_reshape_ms",
                 "partial reshape moved survivor weight bytes (gate: 0)")
        if "bit_identical=True" not in d:
            warn("fleet/partial_reshape_ms",
                 "post-reshape responses not bit-identical")
    d = new.get("overload/recovery_time", {}).get("derived", "")
    if d:
        if "recovered=True" not in d:
            warn("overload/recovery_time",
                 "brown-out ladder did not walk back to rung 0")
        if "canary_good=promoted" not in d:
            warn("overload/recovery_time",
                 "good canary was not auto-promoted under chaos")
        if "canary_bad=aborted" not in d:
            warn("overload/recovery_time",
                 "bad canary was not auto-aborted under chaos")
        m = re.search(r"violations=(\d+)", d)
        if m and int(m.group(1)) != 0:
            warn("overload/recovery_time",
                 f"{m.group(1)} rollout chaos invariant violations "
                 f"(gate: 0)")
    return warned


def load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"::notice::bench-compare: cannot read {path}: {e}")
        return {}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_core.json")
    ap.add_argument("fresh", help="freshly produced results json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative us_per_call increase that counts as "
                         "a regression (default 0.25 = +25%%)")
    ap.add_argument("--only", default=None, metavar="PREFIX",
                    help="restrict the diff (and gate checks) to rows "
                         "whose name starts with PREFIX — lets a partial "
                         "fresh run (e.g. decode-bench-smoke) diff only "
                         "the rows it produced without the rest of the "
                         "baseline showing up as removed")
    args = ap.parse_args(argv)
    old, new = load(args.baseline), load(args.fresh)
    if not old or not new:
        return 0
    if args.only:
        # partial-run semantics: a filtered fresh run (smoke sweeps emit
        # a subset of the full grid) diffs only the rows it produced
        new = {k: v for k, v in new.items() if k.startswith(args.only)}
        old = {k: v for k, v in old.items() if k in new}
    fleet_warnings = check_fleet_gates(new)
    integrity_warnings = check_integrity_gates(new)
    lm_decode_warnings = check_lm_decode_gates(new)
    kernel_warnings = check_kernel_gates(new)
    rollout_warnings = check_rollout_gates(new)

    regressed = improved = 0
    for name in sorted(set(old) & set(new)):
        o = old[name].get("us_per_call", 0) or 0
        n = new[name].get("us_per_call", 0) or 0
        if o <= 0 or n <= 0:
            continue                     # derived-only / gate rows
        ratio = n / o
        if ratio > 1 + args.threshold:
            regressed += 1
            print(f"::warning title=bench regression::{name}: "
                  f"{o:.2f}us -> {n:.2f}us (+{(ratio - 1) * 100:.0f}%)")
        elif ratio < 1 - args.threshold:
            improved += 1
            print(f"::notice title=bench improvement::{name}: "
                  f"{o:.2f}us -> {n:.2f}us ({(ratio - 1) * 100:.0f}%)")
    for name in sorted(set(new) - set(old)):
        print(f"::notice::bench row added: {name}")
    for name in sorted(set(old) - set(new)):
        print(f"::warning title=bench row removed::{name}")
    print(f"bench-compare: {regressed} regressed, {improved} improved, "
          f"{len(set(old) & set(new))} compared, "
          f"{fleet_warnings} fleet-gate warnings, "
          f"{integrity_warnings} integrity-gate warnings, "
          f"{lm_decode_warnings} lm_decode-gate warnings, "
          f"{kernel_warnings} kernel-gate warnings, "
          f"{rollout_warnings} rollout-gate warnings "
          f"(threshold +{args.threshold:.0%}, warn-only)")
    return 0                             # NEVER fails the build


if __name__ == "__main__":
    sys.exit(main())
