"""LM decode microbenchmark (ISSUE 8): dense slot cache vs paged KV.

Sweeps batch x prompt-length x cache-occupancy over the smoke LM config
and measures sustained decode throughput per engine:

* ``lm_decode/dense_*``   — ``ServingEngine`` (dense (L,B,max_seq) cache,
  one jitted dispatch + host sampling round-trip per token).
* ``lm_decode/paged_*``   — ``PagedServingEngine`` (block tables over a
  shared pool, AOT multi-token decode window with on-device sampling).
  Occupancy is set by sizing the pool so the steady-state working set
  (batch x blocks reserved per sequence) is the target fraction of
  ``num_blocks``; the span bucket makes the gathered block axis track
  occupancy, so low occupancy is not free speed.
* ``lm_decode/speedup_*`` — paged/dense tokens-per-sec ratio per cell,
  with a bit-identity flag (same prompts, greedy). The batch-8,
  occupancy>=50% cell carries the ISSUE 8 gate: >= 2x.

``us_per_call`` is the mean engine-recorded per-token decode latency;
``derived`` carries tokens/sec and p50/p99 per-token. Tokens/sec is
end-to-end over the drained wave (prefill + decode), so the paged path's
larger prefill dispatch is charged against its window amortization.

Run standalone (rows MERGE into an existing results json):
  PYTHONPATH=src python -m benchmarks.decode_bench [--smoke] \
      [--json BENCH_core.json]
or as part of the full harness: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _pct(xs: list, q: float) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def _drain_wave(eng, prompts, max_new: int):
    """Submit one wave and drain it; returns (tokens_per_s, per-token
    latency samples, out token lists)."""
    from repro.serving.engine import Request
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.telemetry._lat.clear()          # decode-only per-token samples
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    assert all(r.done and not r.shed for r in reqs), \
        [(r.rid, r.verdict) for r in reqs if r.shed]
    decode_tokens = sum(len(r.out_tokens) - 1 for r in reqs)
    return decode_tokens / wall, list(eng.telemetry._lat), \
        [r.out_tokens for r in reqs]


def run_sweep(emit, quick: bool = False) -> None:
    """Emit the lm_decode/* rows through the harness ``emit`` hook."""
    import jax
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.models.common import init_params
    from repro.serving.engine import ServingEngine
    from repro.serving.paged_engine import PagedServingEngine

    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    rng = np.random.RandomState(0)
    block_size = 8
    max_new = 16 if quick else 32
    batches = (1, 8) if quick else (1, 4, 8)
    plens = (8,) if quick else (8, 32)
    occs = (0.5,) if quick else (0.5, 0.9)
    waves = 1 if quick else 2
    max_seq = 128

    def lat_str(lats):
        return (f"p50={_pct(lats, 0.5) * 1e6:.0f}us "
                f"p99={_pct(lats, 0.99) * 1e6:.0f}us")

    for batch in batches:
        for plen in plens:
            prompts = [rng.randint(0, cfg.vocab_size, (plen,))
                       .astype(np.int32) for _ in range(batch)]

            # dense baseline: one engine per cell (occupancy is a paged
            # concept — the dense cache is always max_batch x max_seq)
            dense = ServingEngine(cfg, params, max_batch=batch,
                                  max_seq=max_seq)
            _drain_wave(dense, prompts, max_new)             # warm (JIT)
            d_tps, d_lats, d_toks = max(
                (_drain_wave(dense, prompts, max_new) for _ in range(waves)),
                key=lambda r: r[0])
            emit(f"lm_decode/dense_b{batch}_p{plen}",
                 np.mean(d_lats) * 1e6,
                 f"tokens_per_s={d_tps:.1f} {lat_str(d_lats)}; "
                 f"per-token dispatch + host sampling")

            for occ in occs:
                # pool sized so the wave's worst-case reservation IS the
                # target occupancy (ceil: never under-provision a lane)
                need = batch * -(-(plen + max_new) // block_size)
                num_blocks = max(need, int(np.ceil(need / occ)))
                paged = PagedServingEngine(
                    cfg, params, max_batch=batch, max_seq=max_seq,
                    block_size=block_size, num_blocks=num_blocks)
                _drain_wave(paged, prompts, max_new)         # warm (AOT)
                p_tps, p_lats, p_toks = max(
                    (_drain_wave(paged, prompts, max_new)
                     for _ in range(waves)), key=lambda r: r[0])
                pct = int(round(100 * need / num_blocks))
                emit(f"lm_decode/paged_b{batch}_p{plen}_occ{pct}",
                     np.mean(p_lats) * 1e6,
                     f"tokens_per_s={p_tps:.1f} {lat_str(p_lats)}; "
                     f"occupancy={need}/{num_blocks} blocks "
                     f"window<=8 on-device sampling")
                gate = batch == max(batches) and occ >= 0.5
                emit(f"lm_decode/speedup_b{batch}_p{plen}_occ{pct}", 0.0,
                     f"paged_vs_dense={p_tps / d_tps:.2f}x"
                     + (" (GATE >= 2x)" if gate else "")
                     + f"; bit_identical={d_toks == p_toks} (greedy)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke profile: minimal sweep")
    ap.add_argument("--json", default="BENCH_core.json",
                    help="results json; lm_decode/* rows are MERGED into "
                         "it (other rows are preserved)")
    args = ap.parse_args(argv)
    results: dict = {}
    try:
        with open(args.json) as f:
            results = json.load(f)
    except (OSError, ValueError):
        pass

    def emit(name: str, us_per_call: float, derived: str = "") -> None:
        results[name] = {"us_per_call": round(us_per_call, 2),
                         "derived": derived}
        print(f"{name},{us_per_call:.2f},{derived}")

    print("name,us_per_call,derived")
    run_sweep(emit, quick=args.quick or args.smoke)
    with open(args.json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    n = sum(1 for k in results if k.startswith("lm_decode/"))
    print(f"# {n} lm_decode rows -> {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
