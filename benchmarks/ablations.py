"""Ablations: structural knobs vs HLO cost (single-device, smoke-scale).

Quantifies the knobs the §Perf loop reasons about, on CPU-compilable sizes:

  * rwkv6 WKV chunk size        -> FLOPs/bytes of the chunked recurrence
  * attention query chunking    -> peak temp of the scores pipeline
  * remat policy                -> FLOPs (recompute) vs temp (storage)

Run: PYTHONPATH=src python -m benchmarks.ablations
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


def wkv_chunk_ablation() -> None:
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, K = 2, 1024, 4, 64
    rng = np.random.RandomState(0)
    r = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, T, H, K), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.randn(B, T, H, K), jnp.float32))
    u = jnp.asarray(rng.randn(H, K), jnp.float32)
    s0 = jnp.zeros((B, H, K, K))
    print("## rwkv6 WKV chunk size (T=1024): intra-chunk work is O(C) per "
          "token, state hops are O(T/C)")
    for chunk in (8, 16, 32, 64, 128):
        fn = jax.jit(lambda a, b, c_, d, e, f, ch=chunk:
                     wkv_chunked(a, b, c_, d, e, f, chunk=ch)[0])
        comp = fn.lower(r, k, v, lw, u, s0).compile()
        ca = comp.cost_analysis()
        m = comp.memory_analysis()
        print(f"  chunk={chunk:4d}: flops={ca['flops']:.3e} "
              f"bytes={ca['bytes accessed']:.3e} "
              f"temp={m.temp_size_in_bytes/1e6:.1f}MB")


def attn_qchunk_ablation() -> None:
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.models.common import init_params
    cfg = get_config("qwen2-1.5b-smoke")
    cfg = dataclasses.replace(cfg, num_layers=2)
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    x = jnp.zeros((2, 512), jnp.int32)
    print("## attention scores pipeline (S=512, 2L): full vs remat")
    for remat in (False, True):
        fn = jax.jit(lambda p, t, r=remat: tf.forward_full(
            cfg, p, t, unroll=True, remat=r)[0])
        comp = fn.lower(params, x).compile()
        ca = comp.cost_analysis()
        m = comp.memory_analysis()
        print(f"  remat={str(remat):5s}: flops={ca['flops']:.3e} "
              f"temp={m.temp_size_in_bytes/1e6:.1f}MB")


def remat_policy_ablation() -> None:
    from repro.configs import get_config
    from repro.launch.steps import make_train_step, input_specs
    from repro.models import transformer as tf
    from repro.models.common import init_params
    from repro.optim.adamw import adamw_init_specs
    from repro.configs.base import ShapeConfig
    cfg = get_config("qwen2-1.5b-smoke")
    shape = ShapeConfig("abl", "train", 256, 4)
    specs = tf.model_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), specs)
    opt = init_params(jax.random.PRNGKey(1), adamw_init_specs(specs))
    batch = {"inputs": jnp.zeros((4, 256), jnp.int32),
             "targets": jnp.zeros((4, 256), jnp.int32)}
    print("## remat policy (train step): recompute FLOPs vs stored temp")
    for policy in ("full", "dots"):
        step = make_train_step(cfg, unroll=True, remat_policy=policy)
        comp = jax.jit(step).lower(params, opt, batch).compile()
        ca = comp.cost_analysis()
        m = comp.memory_analysis()
        print(f"  policy={policy:5s}: flops={ca['flops']:.3e} "
              f"temp={m.temp_size_in_bytes/1e6:.1f}MB")


def main() -> None:
    wkv_chunk_ablation()
    attn_qchunk_ablation()
    remat_policy_ablation()


if __name__ == "__main__":
    main()
