"""Deterministic, shardable synthetic data pipeline.

Generates a stationary Markov-ish token stream (so a ~100M model has real
signal to learn: loss drops well below uniform entropy) with per-(step,
shard) determinism: worker i of n draws exactly the global batch rows
[i*b/n, (i+1)*b/n) — restart-safe and elastic (a re-sharded fleet replays
identical global batches, the data-side half of fault tolerance).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    structure: int = 97         # hidden-state count of the generator

    def _rows(self, step: int, row_lo: int, row_hi: int) -> np.ndarray:
        """Deterministic rows of the global batch for [row_lo, row_hi)."""
        out = np.empty((row_hi - row_lo, self.seq_len + 1), np.int32)
        for r in range(row_lo, row_hi):
            rng = np.random.RandomState(
                (self.seed * 1_000_003 + step) % (2**31) ^ (r * 2_654_435))
            # token t+1 = f(token t) + small noise -> learnable structure
            s = rng.randint(self.structure)
            row = np.empty(self.seq_len + 1, np.int32)
            for t in range(self.seq_len + 1):
                s = (s * 31 + 7) % self.structure
                noise = rng.randint(0, 4)
                row[t] = (s * (self.vocab_size // self.structure) + noise) \
                    % self.vocab_size
            out[r - row_lo] = row
        return out

    def global_batch_at(self, step: int) -> dict:
        rows = self._rows(step, 0, self.global_batch)
        return {"inputs": rows[:, :-1], "targets": rows[:, 1:]}

    def shard_at(self, step: int, shard: int, num_shards: int) -> dict:
        assert self.global_batch % num_shards == 0
        per = self.global_batch // num_shards
        rows = self._rows(step, shard * per, (shard + 1) * per)
        return {"inputs": rows[:, :-1], "targets": rows[:, 1:]}


def make_batch_specs(vocab: int, batch: int, seq: int):
    return {"inputs": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
