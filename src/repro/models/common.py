"""Shared model machinery: ParamSpec trees, norms, RoPE, initialization.

Every model exposes ``param_specs(cfg) -> pytree[ParamSpec]`` — a single
source of truth from which we derive (a) materialized params for smoke
tests/training, (b) ``ShapeDtypeStruct`` stand-ins for the multi-pod dry-run
(no allocation), and (c) ``NamedSharding``s via the RBL logical-axis
resolver. This mirrors the paper's RCTC "mapping generation" step: descriptors
that map logical tensor IDs to physical requirements, resolved at bind time.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import sharding_for


class ParamSpec(NamedTuple):
    shape: tuple
    dtype: str
    axes: tuple               # logical axis names (len == ndim), None entries ok
    init: str = "normal"      # normal | zeros | ones | embed | decay | uniform
    scale: float = 1.0

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def shape_structs(specs, sharded: bool = True):
    """ShapeDtypeStruct tree (with shardings when a binding ctx is active)."""
    def mk(s: ParamSpec):
        sh = sharding_for(s.shape, s.axes) if sharded else None
        return jax.ShapeDtypeStruct(s.shape, s.jdtype, sharding=sh)
    return spec_tree_map(mk, specs)


def param_shardings(specs):
    return spec_tree_map(lambda s: sharding_for(s.shape, s.axes), specs)


def init_params(rng: jax.Array, specs):
    """Materialize parameters from specs (deterministic per-leaf fold-in)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    out = []
    for i, s in enumerate(leaves):
        key = jax.random.fold_in(rng, i)
        if s.init == "zeros":
            v = jnp.zeros(s.shape, s.jdtype)
        elif s.init == "ones":
            v = jnp.ones(s.shape, s.jdtype)
        elif s.init == "uniform":
            v = jax.random.uniform(key, s.shape, jnp.float32, -1.0, 1.0)
            v = (v * s.scale).astype(s.jdtype)
        elif s.init == "decay":       # rwkv decay base: spread in [-6, -1]
            u = jax.random.uniform(key, s.shape, jnp.float32)
            v = (-6.0 + 5.0 * u).astype(s.jdtype)
        elif s.init == "embed":
            # 1/sqrt(d) std: keeps tied-embedding logits at O(1) scale
            std = s.shape[-1] ** -0.5
            v = (jax.random.normal(key, s.shape, jnp.float32)
                 * std).astype(s.jdtype)
        else:                          # truncated-normal fan-in
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = s.scale / math.sqrt(max(1, fan_in))
            v = (jax.random.truncated_normal(key, -3, 3, s.shape, jnp.float32)
                 * std).astype(s.jdtype)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def group_norm(x: jax.Array, w: jax.Array, b: jax.Array, groups: int,
               eps: float = 1e-5) -> jax.Array:
    """GroupNorm over the trailing dim (rwkv6 ln_x)."""
    dt = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, groups, d // groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    x = x.reshape(*lead, d)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    dt = x.dtype
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., seq, d/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., seq, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL; stable in fp32; logits may be vocab-sharded."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
