"""Modality frontend STUBS for the [vlm]/[audio] backbone architectures.

Per assignment, pixtral-12b and musicgen-medium specify the transformer
BACKBONE only; the modality frontend supplies precomputed embeddings via
``input_specs()``. These helpers generate deterministic stand-ins with the
right shapes/statistics so examples and tests can exercise the backbones
end-to-end without a ViT/EnCodec implementation.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig


def patch_embed_stub(cfg: ModelConfig, batch: int, seq: int,
                     seed: int = 0) -> np.ndarray:
    """Pixtral: stand-in for ViT patch embeddings, unit-RMS like a real
    post-LN patch encoder output."""
    rng = np.random.RandomState(seed)
    x = rng.randn(batch, seq, cfg.d_model).astype(np.float32)
    return x / np.sqrt((x ** 2).mean(-1, keepdims=True) + 1e-6)


def frame_embed_stub(cfg: ModelConfig, batch: int, seq: int,
                     seed: int = 0, codebooks: int = 4) -> np.ndarray:
    """MusicGen: stand-in for summed EnCodec codebook embeddings (the
    backbone sees the SUM of per-codebook embeddings per frame)."""
    rng = np.random.RandomState(seed)
    parts = [rng.randn(batch, seq, cfg.d_model).astype(np.float32)
             * (0.5 ** i) for i in range(codebooks)]
    return np.sum(parts, axis=0) / codebooks
