"""ResNet-18 (He et al. 2016) — the paper's case-study workload.

Pure-JAX reference implementation (the oracle), plus BN-folding into
inference scale/shift pairs. The RCTC toolchain (core/rctc.py) flattens this
network into a fine-grained RCB program (CONV2D / SCALE_SHIFT / RELU / ADD /
POOL / DENSE / SOFTMAX ops) executed by the generic engine — the same
deployment path the paper demonstrates on the 4x7 AIE grid.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet18 import ResNetConfig
from repro.models.common import ParamSpec, init_params


def _conv_spec(kh, kw, cin, cout):
    return ParamSpec((kh, kw, cin, cout), "float32", (None, None, None, None),
                     "normal", 1.4)


def _bn_specs(c):
    return {
        "scale": ParamSpec((c,), "float32", (None,), "ones"),
        "bias": ParamSpec((c,), "float32", (None,), "zeros"),
        "mean": ParamSpec((c,), "float32", (None,), "zeros"),
        "var": ParamSpec((c,), "float32", (None,), "ones"),
    }


def resnet_specs(cfg: ResNetConfig) -> dict:
    specs: dict[str, Any] = {
        "stem_conv": _conv_spec(7, 7, 3, cfg.stem_width),
        "stem_bn": _bn_specs(cfg.stem_width),
        "fc_w": ParamSpec((cfg.stage_widths[-1], cfg.num_classes), "float32",
                          (None, None)),
        "fc_b": ParamSpec((cfg.num_classes,), "float32", (None,), "zeros"),
    }
    cin = cfg.stem_width
    for si, (n_blocks, width) in enumerate(zip(cfg.stage_sizes,
                                               cfg.stage_widths)):
        for bi in range(n_blocks):
            pre = f"s{si}b{bi}_"
            stride = 2 if (bi == 0 and si > 0) else 1
            specs[pre + "conv1"] = _conv_spec(3, 3, cin, width)
            specs[pre + "bn1"] = _bn_specs(width)
            specs[pre + "conv2"] = _conv_spec(3, 3, width, width)
            specs[pre + "bn2"] = _bn_specs(width)
            if stride != 1 or cin != width:
                specs[pre + "proj"] = _conv_spec(1, 1, cin, width)
                specs[pre + "proj_bn"] = _bn_specs(width)
            cin = width
    return specs


def init_resnet(rng: jax.Array, cfg: ResNetConfig) -> dict:
    return init_params(rng, resnet_specs(cfg))


def _bn(x, p, eps=1e-5):
    inv = jax.lax.rsqrt(p["var"] + eps)
    return (x - p["mean"]) * inv * p["scale"] + p["bias"]


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def resnet_forward(cfg: ResNetConfig, params: dict, x: jax.Array,
                   softmax: bool = True) -> jax.Array:
    """Oracle forward: x (N,H,W,3) float32 -> (N, classes)."""
    h = _conv(x, params["stem_conv"], stride=2)
    h = jax.nn.relu(_bn(h, params["stem_bn"]))
    if cfg.image_size >= 64:
        h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                  (1, 2, 2, 1), "SAME")
    cin = cfg.stem_width
    for si, (n_blocks, width) in enumerate(zip(cfg.stage_sizes,
                                               cfg.stage_widths)):
        for bi in range(n_blocks):
            pre = f"s{si}b{bi}_"
            stride = 2 if (bi == 0 and si > 0) else 1
            res = h
            y = _conv(h, params[pre + "conv1"], stride)
            y = jax.nn.relu(_bn(y, params[pre + "bn1"]))
            y = _conv(y, params[pre + "conv2"], 1)
            y = _bn(y, params[pre + "bn2"])
            if pre + "proj" in params:
                res = _bn(_conv(h, params[pre + "proj"], stride),
                          params[pre + "proj_bn"])
            h = jax.nn.relu(y + res)
            cin = width
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params["fc_w"] + params["fc_b"]
    return jax.nn.softmax(logits, axis=-1) if softmax else logits


def fold_bn(params: dict, eps: float = 1e-5) -> dict:
    """Fold BN into per-channel (scale, shift) pairs for inference RCBs."""
    out = {}
    for k, v in params.items():
        if isinstance(v, dict) and set(v) == {"scale", "bias", "mean", "var"}:
            inv = 1.0 / np.sqrt(np.asarray(v["var"]) + eps)
            out[k + "_scale"] = np.asarray(v["scale"]) * inv
            out[k + "_shift"] = np.asarray(v["bias"]) - \
                np.asarray(v["mean"]) * np.asarray(v["scale"]) * inv
        else:
            out[k] = np.asarray(v)
    return out
