"""Dense SwiGLU MLP and capacity-based top-k MoE (expert-parallel).

The MoE dispatch uses the Mesh-TensorFlow/Switch formulation: tokens are
grouped, a (group, token, expert, capacity) dispatch tensor routes tokens to
per-expert slots, and experts run as one batched einsum with the expert dim
sharded over the ``model`` mesh axis (EP). Under pjit the dispatch/combine
einsums lower to the expert all-to-all. Arctic's dense-residual branch is a
parallel SwiGLU added to the routed output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.common import ParamSpec


# ---------------------------------------------------------------------------
# Dense SwiGLU
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int | None = None,
              prefix: str = "mlp_") -> dict:
    L, d = cfg.num_layers, cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.dtype
    return {
        prefix + "wi_gate": ParamSpec((L, d, f), dt, ("layers", "fsdp", "mlp")),
        prefix + "wi_up": ParamSpec((L, d, f), dt, ("layers", "fsdp", "mlp")),
        prefix + "wo": ParamSpec((L, f, d), dt, ("layers", "mlp", "fsdp")),
    }


def swiglu(p: dict, x: jax.Array, prefix: str = "mlp_") -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p[prefix + "wi_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p[prefix + "wi_up"])
    h = shard(jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u,
              "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p[prefix + "wo"])


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_specs(cfg: ModelConfig) -> dict:
    L, d, f, E = cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cfg.dtype
    p = {
        "router": ParamSpec((L, d, E), "float32", ("layers", None, "experts")),
        "we_gate": ParamSpec((L, E, d, f), dt, ("layers", "experts", "fsdp", "mlp")),
        "we_up": ParamSpec((L, E, d, f), dt, ("layers", "experts", "fsdp", "mlp")),
        "we_out": ParamSpec((L, E, f, d), dt, ("layers", "experts", "mlp", "fsdp")),
    }
    if cfg.moe_dense_residual:
        p.update(mlp_specs(cfg, cfg.d_ff_dense, prefix="dense_"))
    return p


def _group(x: jax.Array, group_size: int):
    B, S, d = x.shape
    g = min(group_size, S)
    return x.reshape(B * (S // g), g, d)


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array,
            group_size: int = 1024) -> tuple[jax.Array, jax.Array]:
    """Top-k capacity-dropped MoE. Returns (output, aux_load_balance_loss)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    xg = _group(x, group_size)                     # (G, T, d)
    G, T, _ = xg.shape
    cap = max(K, int(math.ceil(T * K * cfg.moe_capacity_factor / E)))
    xg = shard(xg, "batch", None, None)

    # router in bf16 with fp32 accumulation: an fp32 .astype copy of the
    # whole token stream costs a (G,T,d) fp32 all-gather per layer under TP
    # (§Perf H5); MXU-style mixed precision keeps logits fp32-exact enough.
    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)        # (G, T, E)
    gate, eidx = jax.lax.top_k(probs, K)           # (G, T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch): E * sum_e fraction_e * mean_prob_e.
    fraction = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=(1, 2))
    aux = E * jnp.mean(jnp.sum(fraction * jnp.mean(probs, axis=1), axis=-1))

    # Position of each (token, slot) within its expert's capacity buffer.
    onehot_e = jax.nn.one_hot(eidx, E, dtype=jnp.float32)       # (G,T,K,E)
    flat = onehot_e.reshape(G, T * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0                         # (G,TK,E)
    pos = jnp.sum(pos.reshape(G, T, K, E) * onehot_e, axis=-1)   # (G,T,K)
    keep = (pos < cap).astype(jnp.float32)
    onehot_c = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]

    # dispatch: (G,T,E,cap); combine adds the gate weight.
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot_e, onehot_c)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot_e, onehot_c, gate)
    dispatch = shard(dispatch.astype(x.dtype), "batch", None, "experts", None)
    combine = shard(combine.astype(x.dtype), "batch", None, "experts", None)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)              # expert slots
    xe = shard(xe, "batch", "experts", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["we_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, p["we_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["we_out"])
    ye = shard(ye, "batch", "experts", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)

    y = y.reshape(B, S, d)
    if cfg.moe_dense_residual:
        y = y + swiglu(p, x, prefix="dense_")
    return y, aux
