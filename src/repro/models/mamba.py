"""Selective SSM (Mamba-style) branch for the Hymba hybrid architecture.

Full-sequence processing scans over fixed-size chunks; inside a chunk the
linear recurrence ``h_t = a_t * h_{t-1} + b_t`` runs as a log-depth
``associative_scan`` (small, statically-unrolled HLO). Decode is a single
state update. The Pallas ``ssm_scan`` kernel implements the same chunked
recurrence with VMEM tiling (kernels/ssm_scan); ``AEG_SSM_IMPL=kernel``
routes the full-sequence scan through the kernel registry — the same
handler the RCTC per-layer lowering dispatches as ``Op.SSM_SCAN``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.common import ParamSpec

DT_RANK = 32


def _ssm_impl() -> str:
    return os.environ.get("AEG_SSM_IMPL", "jnp")


def mamba_specs(cfg: ModelConfig) -> dict:
    L, d = cfg.num_layers, cfg.d_model
    di, N = cfg.d_model, cfg.ssm_state          # d_inner == d_model (Hymba)
    dt = cfg.dtype
    return {
        "m_in": ParamSpec((L, d, 2 * di), dt, ("layers", "fsdp", "mlp")),
        "m_x": ParamSpec((L, di, DT_RANK + 2 * N), dt, ("layers", "fsdp", None)),
        "m_dt": ParamSpec((L, DT_RANK, di), dt, ("layers", None, "fsdp")),
        "m_dt_b": ParamSpec((L, di), "float32", ("layers", None), "zeros"),
        "m_alog": ParamSpec((L, di, N), "float32",
                            ("layers", "fsdp", "state"), "uniform", 1.0),
        "m_d": ParamSpec((L, di), "float32", ("layers", None), "ones"),
        "m_out": ParamSpec((L, di, d), dt, ("layers", "mlp", "fsdp")),
    }


def mamba_state_specs(cfg: ModelConfig, batch: int) -> dict:
    L, di, N = cfg.num_layers, cfg.d_model, cfg.ssm_state
    return {"ssm": ParamSpec((L, batch, di, N), "float32",
                             ("layers", "batch", "mlp", "state"), "zeros")}


def _ssm_inputs(cfg: ModelConfig, p: dict, x: jax.Array):
    """Project x -> (u, z, dt, B, C). u/z (B,T,di); dt (B,T,di) fp32;
    B/C (B,T,N) fp32."""
    N = cfg.ssm_state
    uz = jnp.einsum("btd,de->bte", x, p["m_in"])
    u, z = jnp.split(uz, 2, axis=-1)
    proj = jnp.einsum("btd,de->bte", u, p["m_x"]).astype(jnp.float32)
    dtr, B_, C_ = jnp.split(proj, [DT_RANK, DT_RANK + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dtr, p["m_dt"].astype(jnp.float32))
        + p["m_dt_b"])
    return u, z, dt, B_, C_


def ssm_chunked(u, dt, B_, C_, A, D, h0, chunk: int = 64):
    """Chunked selective scan.

    u (B,T,di) fp32, dt (B,T,di), B_/C_ (B,T,N), A (di,N) negative,
    D (di,), h0 (B,di,N). Returns (y (B,T,di), h_final).
    """
    Bb, T, di = u.shape
    N = B_.shape[-1]
    C = min(chunk, T)
    Tp = (T + C - 1) // C * C

    da_log = dt[..., None] * A[None, None]            # (B,T,di,N)  <= 0
    binp = (dt * u)[..., None] * B_[:, :, None, :]    # (B,T,di,N)
    if Tp != T:
        # identity padding: da=0 keeps h, binp=0 adds nothing
        da_log = jnp.pad(da_log, [(0, 0), (0, Tp - T), (0, 0), (0, 0)])
        binp = jnp.pad(binp, [(0, 0), (0, Tp - T), (0, 0), (0, 0)])
        C_ = jnp.pad(C_, [(0, 0), (0, Tp - T), (0, 0)])
    NC = Tp // C

    def resh(a):
        return a.reshape(Bb, NC, C, *a.shape[2:]).swapaxes(0, 1)

    da_c, b_c, c_c = resh(da_log), resh(binp), resh(C_)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def body(h, inp):
        da_, b_, cc_ = inp                             # (B,C,di,N),(B,C,N)
        a_ = jnp.exp(da_)
        # within-chunk recurrence, seeded by the carried state
        b_ = b_.at[:, 0].add(a_[:, 0] * h)
        acc_a, acc_b = jax.lax.associative_scan(assoc, (a_, b_), axis=1)
        y = jnp.einsum("btdn,btn->btd", acc_b, cc_)
        return acc_b[:, -1], y

    h_final, ys = jax.lax.scan(body, h0, (da_c, b_c, c_c))
    y = ys.swapaxes(0, 1).reshape(Bb, Tp, di)[:, :T]
    return y + u * D[None, None], h_final


def ssm_kernel_inputs(cfg: ModelConfig, p: dict, x: jax.Array):
    """Project x into the kernel-registry ``ssm_scan`` operand layout.

    Returns (da_log (B,T,di,N) fp32 <= 0, bx (B,T,di,N) fp32, c (B,T,N)
    fp32, u (B,T,di) fp32, z (B,T,di)) — the first three are exactly the
    operands of ``Op.SSM_SCAN``; u/z feed the output stage (skip + gate).
    Shared by the eager kernel route below and the RCTC per-layer glue.
    """
    u, z, dt, B_, C_ = _ssm_inputs(cfg, p, x)
    A = -jnp.exp(p["m_alog"])
    u32 = u.astype(jnp.float32)
    da_log = dt[..., None] * A[None, None]            # (B,T,di,N)  <= 0
    bx = (dt * u32)[..., None] * B_[:, :, None, :]    # (B,T,di,N)
    return da_log, bx, C_, u32, z


def ssm_output(cfg: ModelConfig, p: dict, y: jax.Array, u: jax.Array,
               z: jax.Array, x_dtype) -> jax.Array:
    """Skip connection + silu gate + output projection (shared tail)."""
    y = y + u * p["m_d"][None, None]
    y = y.astype(x_dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x_dtype)
    y = shard(y, "batch", "seq", "mlp")
    return jnp.einsum("btd,de->bte", y, p["m_out"])


def ssm_core(u, dt, B_, C_, A, D, h0, impl: str | None = None):
    """Full-sequence selective scan with impl routing. Returns (y, h_final)
    where y already carries the ``u * D`` skip term.

    ``impl``: "jnp" (chunked associative scan, default — differentiable) or
    "kernel" (registry ``ssm_scan`` handler: pallas with interpret fallback,
    ref fallback when pallas is unavailable). The kernel computes the
    zero-state scan; h0 is folded in by seeding step 0's input with
    ``exp(da_0) * h0`` and the final state recovered in closed form.
    """
    if (impl or _ssm_impl()) != "kernel":
        return ssm_chunked(u, dt, B_, C_, A, D, h0)
    from repro.kernels import registry
    da_log = dt[..., None] * A[None, None]
    bx = (dt * u)[..., None] * B_[:, :, None, :]
    bx = bx.at[:, 0].add(jnp.exp(da_log[:, 0]) * h0)
    y = registry.call("ssm_scan", da_log, bx, C_)
    # closed-form final state: h_T = sum_t exp(P_T - P_t) * bx_t with
    # P = inclusive cumsum of da_log (exp args <= 0 — overflow-safe).
    P = jnp.cumsum(da_log, axis=1)
    h_final = jnp.sum(jnp.exp(P[:, -1:] - P) * bx, axis=1)
    return y + u * D[None, None], h_final


def mamba_mix(cfg: ModelConfig, p: dict, x: jax.Array, h0: jax.Array):
    """Full-sequence Mamba branch. Returns (y, h_final)."""
    u, z, dt, B_, C_ = _ssm_inputs(cfg, p, x)
    A = -jnp.exp(p["m_alog"])
    y, h1 = ssm_core(u.astype(jnp.float32), dt, B_, C_, A, p["m_d"], h0)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = shard(y, "batch", "seq", "mlp")
    return jnp.einsum("btd,de->bte", y, p["m_out"]), h1


def mamba_step(cfg: ModelConfig, p: dict, x: jax.Array, h0: jax.Array):
    """Single-token decode. x (B,1,d); h0 (B,di,N)."""
    u, z, dt, B_, C_ = _ssm_inputs(cfg, p, x)
    A = -jnp.exp(p["m_alog"])
    da = jnp.exp(dt[:, 0, :, None] * A[None])               # (B,di,N)
    h1 = da * h0 + (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] \
        * B_[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", h1, C_[:, 0]) + u[:, 0].astype(jnp.float32) \
        * p["m_d"]
    y = y[:, None].astype(x.dtype) * \
        jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("btd,de->bte", y, p["m_out"]), h1
