from repro.models.common import ParamSpec, init_params, shape_structs, param_shardings  # noqa: F401
