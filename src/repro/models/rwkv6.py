"""RWKV-6 "Finch": token-shift mixing + data-dependent decay WKV recurrence.

The WKV core is computed in chunks: within a chunk the pairwise decay
``exp(p_{t-1} - p_j)`` (j < t) is always an exp of a non-positive number —
numerically safe for arbitrarily strong decay, unlike the classic
``exp(p) / exp(p)`` factorization which overflows. Chunks are carried by a
``lax.scan`` over an (B, H, K, K) state; this same algorithm is what the
Pallas ``wkv6`` kernel tiles into VMEM (kernels/wkv6). ``AEG_WKV_IMPL=kernel``
routes the full-sequence recurrence through the kernel registry — the same
handler the RCTC per-layer lowering dispatches as ``Op.WKV6``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.common import ParamSpec, group_norm

LORA_DIM = 64


def _wkv_impl() -> str:
    return os.environ.get("AEG_WKV_IMPL", "jnp")


def rwkv_specs(cfg: ModelConfig) -> dict:
    L, d, f = cfg.num_layers, cfg.d_model, cfg.d_ff
    K = cfg.rwkv_head_dim
    H = d // K
    dt = cfg.dtype
    return {
        # time-mix
        "tm_mix": ParamSpec((L, 5, d), dt, ("layers", None, None), "uniform", 0.5),
        "tm_w0": ParamSpec((L, d), "float32", ("layers", None), "decay"),
        "tm_wa": ParamSpec((L, d, LORA_DIM), dt, ("layers", "fsdp", None)),
        "tm_wb": ParamSpec((L, LORA_DIM, d), dt, ("layers", None, "fsdp")),
        "tm_u": ParamSpec((L, H, K), "float32", ("layers", "heads", None),
                          "uniform", 0.5),
        "tm_wr": ParamSpec((L, d, d), dt, ("layers", "fsdp", "heads")),
        "tm_wk": ParamSpec((L, d, d), dt, ("layers", "fsdp", "heads")),
        "tm_wv": ParamSpec((L, d, d), dt, ("layers", "fsdp", "heads")),
        "tm_wg": ParamSpec((L, d, d), dt, ("layers", "fsdp", "heads")),
        "tm_wo": ParamSpec((L, d, d), dt, ("layers", "heads", "fsdp")),
        "tm_ln_w": ParamSpec((L, d), dt, ("layers", None), "ones"),
        "tm_ln_b": ParamSpec((L, d), dt, ("layers", None), "zeros"),
        # channel-mix
        "cm_mix": ParamSpec((L, 2, d), dt, ("layers", None, None), "uniform", 0.5),
        "cm_wk": ParamSpec((L, d, f), dt, ("layers", "fsdp", "mlp")),
        "cm_wv": ParamSpec((L, f, d), dt, ("layers", "mlp", "fsdp")),
        "cm_wr": ParamSpec((L, d, d), dt, ("layers", "fsdp", None)),
    }


def state_specs(cfg: ModelConfig, batch: int) -> dict:
    L, d = cfg.num_layers, cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    return {
        "wkv": ParamSpec((L, batch, H, K, K), "float32",
                         ("layers", "batch", "heads", None, None), "zeros"),
        "ts_tm": ParamSpec((L, batch, d), cfg.dtype,
                           ("layers", "batch", None), "zeros"),
        "ts_cm": ParamSpec((L, batch, d), cfg.dtype,
                           ("layers", "batch", None), "zeros"),
    }


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x: (B,T,d); prev: (B,d) last token of the previous segment."""
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, lw, u, s0, chunk: int = 16):
    """Chunked WKV6: r/k/v/lw (B,T,H,K) fp32, u (H,K), s0 (B,H,K,K).

    Returns (y (B,T,H,K), s_final). All exponentials have non-positive
    arguments (p is a running sum of lw <= 0), so no overflow is possible.
    """
    B, T, H, K = r.shape
    C = min(chunk, T)
    Tp = (T + C - 1) // C * C
    if Tp != T:
        # identity padding: k=v=0 adds nothing to the state, lw=0 (w=1)
        # leaves it undecayed; padded y rows are sliced off below.
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        r, k, v, lw = (jnp.pad(a, pad) for a in (r, k, v, lw))
    N = Tp // C

    def resh(a):
        return a.reshape(B, N, C, H, K).transpose(1, 0, 2, 3, 4)

    rs, ks, vs, lws = resh(r), resh(k), resh(v), resh(lw)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)          # j < t

    def body(S, inp):
        r_, k_, v_, lw_ = inp                               # (B,C,H,K)
        p = jnp.cumsum(lw_, axis=1)                         # inclusive
        pprev = p - lw_                                     # exclusive (p_{t-1})
        diff = pprev[:, :, None] - p[:, None, :]            # (B,Ct,Cj,H,K)
        e = jnp.where(mask[None, :, :, None, None], jnp.exp(diff), 0.0)
        att = jnp.einsum("bthi,bjhi,btjhi->bthj", r_, k_, e)
        y = jnp.einsum("bthj,bjho->btho", att, v_)
        # diagonal "bonus" term
        coef = jnp.einsum("bthi,hi,bthi->bth", r_, u, k_)
        y = y + coef[..., None] * v_
        # inter-chunk: state entering the chunk
        y = y + jnp.einsum("bthi,bhio->btho", r_ * jnp.exp(pprev), S)
        # state update
        kd = k_ * jnp.exp(p[:, -1:] - p)                    # decay to chunk end
        S = jnp.exp(p[:, -1])[..., None] * S + \
            jnp.einsum("bthi,btho->bhio", kd, v_)
        return S, y

    s_final, ys = jax.lax.scan(body, s0, (rs, ks, vs, lws))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, K)
    return y[:, :T], s_final


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent decay log-weights lw = -exp(w0 + lora(x)) (<= 0)."""
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", xw.astype(jnp.float32),
                               p["tm_wa"].astype(jnp.float32)))
    w_raw = p["tm_w0"].astype(jnp.float32) + jnp.einsum(
        "btr,re->bte", lora, p["tm_wb"].astype(jnp.float32))
    w_raw = jnp.clip(w_raw, -12.0, 3.0)
    return -jnp.exp(w_raw)


def time_mix_pre(cfg: ModelConfig, p: dict, x: jax.Array,
                 ts_prev: jax.Array):
    """Token-shift mixing + projections into the WKV operand layout.

    Returns (r, k, v, lw — all (B,T,H,K) fp32, lw <= 0; g (B,T,d)) — the
    first four are exactly the tensor operands of ``Op.WKV6``. Shared by
    ``time_mix`` below and the RCTC per-layer glue artifact.
    """
    B, T, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    xprev = _shift(x, ts_prev)
    mix = p["tm_mix"].astype(x.dtype)                       # (5, d)
    xr, xk, xv, xw, xg = [x + (xprev - x) * mix[i] for i in range(5)]

    r = jnp.einsum("btd,de->bte", xr, p["tm_wr"]).reshape(B, T, H, K)
    k = jnp.einsum("btd,de->bte", xk, p["tm_wk"]).reshape(B, T, H, K)
    v = jnp.einsum("btd,de->bte", xv, p["tm_wv"]).reshape(B, T, H, K)
    g = jnp.einsum("btd,de->bte", xg, p["tm_wg"])
    lw = _decay(p, xw).reshape(B, T, H, K)
    return (r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), lw, g)


def time_mix_post(cfg: ModelConfig, p: dict, y: jax.Array, g: jax.Array,
                  x_dtype) -> jax.Array:
    """Group-norm + silu gate + output projection (shared tail).
    y: (B,T,H,K) fp32 WKV output; g: (B,T,d) gate projection."""
    B, T, H, K = y.shape
    y = y.reshape(B, T, H * K).astype(x_dtype)
    y = group_norm(y, p["tm_ln_w"], p["tm_ln_b"], H, cfg.norm_eps)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x_dtype)
    y = shard(y, "batch", "seq", "heads")
    return jnp.einsum("btd,de->bte", y, p["tm_wo"])


def wkv_core(r, k, v, lw, u, s0, impl: str | None = None):
    """Full-sequence WKV recurrence with impl routing. Returns (y, s_final).

    ``impl``: "jnp" (chunked scan, default — differentiable) or "kernel"
    (registry ``wkv6`` handler). The kernel computes the zero-state
    recurrence; an arbitrary entering state s0 is folded in exactly with
    the rank-1 correction ``y += (r * exp(p_prev)) @ s0`` (p_prev the
    exclusive decay prefix — exp args <= 0) and the final state recovered
    in closed form.
    """
    if (impl or _wkv_impl()) != "kernel":
        return wkv_chunked(r, k, v, lw, u, s0)
    from repro.kernels import registry
    y = registry.call("wkv6", r, k, v, lw, u)
    p = jnp.cumsum(lw, axis=1)                              # inclusive
    pprev = p - lw                                          # exclusive
    y = y + jnp.einsum("bthi,bhio->btho", r * jnp.exp(pprev), s0)
    s_final = jnp.exp(p[:, -1])[..., None] * s0 + \
        jnp.einsum("bthi,btho->bhio", k * jnp.exp(p[:, -1:] - p), v)
    return y, s_final


def time_mix(cfg: ModelConfig, p: dict, x: jax.Array, ts_prev: jax.Array,
             s0: jax.Array):
    """RWKV6 attention replacement. Returns (y, new_ts, new_state)."""
    r, k, v, lw, g = time_mix_pre(cfg, p, x, ts_prev)
    y, s1 = wkv_core(r, k, v, lw, p["tm_u"].astype(jnp.float32), s0)
    y = time_mix_post(cfg, p, y, g, x.dtype)
    return y, x[:, -1], s1


def time_mix_step(cfg: ModelConfig, p: dict, x: jax.Array, ts_prev: jax.Array,
                  s0: jax.Array):
    """Single-token decode step. x: (B,1,d); s0: (B,H,K,K) fp32."""
    B, _, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    mix = p["tm_mix"].astype(x.dtype)
    xp = ts_prev[:, None, :].astype(x.dtype)
    xr, xk, xv, xw, xg = [x + (xp - x) * mix[i] for i in range(5)]

    proj = lambda a, w: jnp.einsum("btd,de->bte", a, w)[:, 0]   # (B,d)
    r = proj(xr, p["tm_wr"]).reshape(B, H, K).astype(jnp.float32)
    k = proj(xk, p["tm_wk"]).reshape(B, H, K).astype(jnp.float32)
    v = proj(xv, p["tm_wv"]).reshape(B, H, K).astype(jnp.float32)
    g = proj(xg, p["tm_wg"])
    w = jnp.exp(_decay(p, xw)[:, 0]).reshape(B, H, K)           # per-channel
    u = p["tm_u"].astype(jnp.float32)

    # y = r . (S + (u*k) v^T);  S' = diag(w) S + k v^T
    kv = jnp.einsum("bhi,bho->bhio", k, v)
    y = jnp.einsum("bhi,bhio->bho", r, s0 + u[None, :, :, None] * kv)
    s1 = w[..., None] * s0 + kv
    y = y.reshape(B, d).astype(x.dtype)
    y = group_norm(y, p["tm_ln_w"], p["tm_ln_b"], H, cfg.norm_eps)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bd,de->be", y, p["tm_wo"])[:, None], x[:, -1], s1


def channel_mix(cfg: ModelConfig, p: dict, x: jax.Array, ts_prev: jax.Array):
    """RWKV6 FFN replacement. Returns (y, new_ts)."""
    xprev = _shift(x, ts_prev)
    mix = p["cm_mix"].astype(x.dtype)
    xk = x + (xprev - x) * mix[0]
    xr = x + (xprev - x) * mix[1]
    k = jnp.einsum("btd,df->btf", xk, p["cm_wk"])
    k = shard(jnp.square(jax.nn.relu(k)), "batch", "seq", "mlp")
    kv = jnp.einsum("btf,fd->btd", k, p["cm_wv"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr,
                                  p["cm_wr"]).astype(jnp.float32))
    return r.astype(x.dtype) * kv, x[:, -1]


def channel_mix_step(cfg: ModelConfig, p: dict, x: jax.Array,
                     ts_prev: jax.Array):
    xp = ts_prev[:, None, :].astype(x.dtype)
    mix = p["cm_mix"].astype(x.dtype)
    xk = x + (xp - x) * mix[0]
    xr = x + (xp - x) * mix[1]
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["cm_wk"])))
    kv = jnp.einsum("btf,fd->btd", k, p["cm_wv"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr,
                                  p["cm_wr"]).astype(jnp.float32))
    return r.astype(x.dtype) * kv, x[:, -1]
