"""GQA attention with full/sliding-window variants and seq-sharded KV decode.

Full-sequence attention computes scores in a small static number of query
chunks (flash-style at the XLA level: peak memory drops by the chunk count
while FLOPs stay statically counted for the roofline). Decode attends one new
token against a (possibly ring-buffered) KV cache whose sequence dim may be
sharded over the ``model`` mesh axis — XLA inserts the partial-softmax
collectives (flash-decode-style sequence parallelism).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import mesh_axis_size, shard
from repro.models.common import ParamSpec, apply_rope, rms_norm

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array            # (L, B, S_cache, Hkv, D) — rope-applied keys
    v: jax.Array            # (L, B, S_cache, Hkv, D)


def attn_specs(cfg: ModelConfig) -> dict:
    """Stacked (num_layers leading dim) attention parameter specs."""
    L, d = cfg.num_layers, cfg.d_model
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.dtype
    p = {
        "wq": ParamSpec((L, d, H, D), dt, ("layers", "fsdp", "heads", "head_dim")),
        "wk": ParamSpec((L, d, Hkv, D), dt, ("layers", "fsdp", "kv_heads", "head_dim")),
        "wv": ParamSpec((L, d, Hkv, D), dt, ("layers", "fsdp", "kv_heads", "head_dim")),
        "wo": ParamSpec((L, H, D, d), dt, ("layers", "heads", "head_dim", "fsdp")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((L, H, D), dt, ("layers", "heads", "head_dim"), "zeros")
        p["bk"] = ParamSpec((L, Hkv, D), dt, ("layers", "kv_heads", "head_dim"), "zeros")
        p["bv"] = ParamSpec((L, Hkv, D), dt, ("layers", "kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((L, D), dt, ("layers", "head_dim"), "ones")
        p["k_norm"] = ParamSpec((L, D), dt, ("layers", "head_dim"), "ones")
    return p


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """KV-cache shape specs. Sliding-window archs keep a ring buffer."""
    s = min(seq_len, cfg.sliding_window) if cfg.attention == "sliding" else seq_len
    shp = (cfg.num_layers, batch, s, cfg.num_kv_heads, cfg.head_dim)
    axes = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"k": ParamSpec(shp, cfg.dtype, axes, "zeros"),
            "v": ParamSpec(shp, cfg.dtype, axes, "zeros")}


def _project(x, w, b):
    y = jnp.einsum("bsd,dhk->bshk", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    """Shared projection + qk-norm + RoPE for both full and decode paths."""
    q = _project(x, p["wq"], p.get("bq"))
    k = _project(x, p["wk"], p.get("bk"))
    v = _project(x, p["wv"], p.get("bv"))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _grouped_scores(q, k):
    """q: (B,Sq,Hkv,G,D)  k: (B,Skv,Hkv,D) -> (B,Hkv,G,Sq,Skv) fp32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _attend_full(cfg: ModelConfig, p: dict, q, k, v, out_dtype):
    """Causal (optionally sliding-window) attention over a full sequence.

    Query-chunked: `n_chunks` static chunks bound peak score memory; sliding
    window additionally slices the KV span statically per chunk.
    """
    B, S, H, D = q.shape
    Hkv = cfg.num_kv_heads
    G = H // Hkv
    # layout note (§Perf H6, REFUTED): forcing pure heads-TP here (q by
    # kv_heads when divisible) measured +17% collective and +25% HBM bytes
    # on moonshot train_4k — the seq-sharded-q mixed layout lets XLA keep
    # the scores seq-local and only reshard K once. Keep q by seq.
    q = shard(q.reshape(B, S, Hkv, G, D),
              "batch", "seq", "kv_heads", None, None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)

    n_chunks = max(1, S // 8192) if S >= 16384 else 1
    cs = S // n_chunks
    scale = 1.0 / (D ** 0.5)
    outs = []
    for ci in range(n_chunks):
        q0 = ci * cs
        qc = jax.lax.slice_in_dim(q, q0, q0 + cs, axis=1)
        if cfg.attention == "sliding":
            k0 = max(0, q0 - cfg.sliding_window)   # KV span: window before chunk
        else:
            k0 = 0
        k1 = q0 + cs
        kc = jax.lax.slice_in_dim(k, k0, k1, axis=1)
        vc = jax.lax.slice_in_dim(v, k0, k1, axis=1)
        s_ = _grouped_scores(qc, kc) * scale           # (B,Hkv,G,cs,k1-k0)
        qpos = jnp.arange(q0, q0 + cs)[:, None]
        kpos = jnp.arange(k0, k1)[None, :]
        mask = kpos <= qpos
        if cfg.attention == "sliding":
            mask &= kpos > qpos - cfg.sliding_window
        s_ = jnp.where(mask, s_, NEG_INF)
        a = jax.nn.softmax(s_, axis=-1).astype(out_dtype)
        outs.append(jnp.einsum("bhgqk,bkhd->bqhgd", a, vc))
    o = jnp.concatenate(outs, axis=1).reshape(B, S, H, D)
    o = shard(o, "batch", "seq", "heads", None)
    return jnp.einsum("bshd,hdk->bsk", o, p["wo"])


def _attend_flash(cfg: ModelConfig, p: dict, q, k, v, out_dtype):
    """Registry flash-attention path (pallas on TPU, interpret-mode on CPU,
    ref fallback when pallas is unavailable) — the same handler the RCTC
    lowering dispatches as ``Op.ATTENTION``. Opt in via AEG_ATTN_IMPL=flash
    — the jnp path remains the default because interpret-mode pallas_call
    is slow to trace at dry-run scale."""
    from repro.kernels import registry
    o = registry.call("attention", q, k, v, causal=True)
    B, S, H, D = o.shape
    o = shard(o, "batch", "seq", "heads", None)
    return jnp.einsum("bshd,hdk->bsk", o.astype(out_dtype), p["wo"])


def _attn_impl() -> str:
    import os
    return os.environ.get("AEG_ATTN_IMPL", "jnp")


def full_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    q, k, v = _qkv(cfg, p, x, positions)
    if _attn_impl() == "flash" and cfg.attention == "full":
        return _attend_flash(cfg, p, q, k, v, x.dtype)
    return _attend_full(cfg, p, q, k, v, x.dtype)


def prefill_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                      positions: jax.Array):
    """Full attention that also returns the (layer-local) KV cache entry."""
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x, positions)
    y = _attend_full(cfg, p, q, k, v, x.dtype)
    if cfg.attention == "sliding":
        W = cfg.sliding_window
        if S >= W:
            k, v = k[:, -W:], v[:, -W:]
    return y, (k, v)


def decode_attention_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                           pos: jax.Array, pool_k: jax.Array,
                           pool_v: jax.Array, tables: jax.Array):
    """One-token decode against a paged KV pool (one layer's slice).

    x: (B,1,d); pos: (B,) logical position of the new token; pool_k/v:
    (num_blocks+1, block_size, Hkv, D) — the last row is the null block;
    tables: (B, W) int32 physical block ids, null-padded. Logical
    position t of lane b lives at (tables[b, t // bs], t % bs).

    The new token's K/V is scattered at its (block, offset) — live lanes
    hold disjoint blocks so the B writes never collide; pad lanes all
    target the null row, whose garbage is only ever gathered back behind
    the NEG_INF mask. Greedy decode stays bit-identical to the dense
    ``decode_attention``: the valid positions carry exactly the same
    scores, and masked lanes contribute exact zeros to the softmax.

    Returns (out (B,1,d), new_pool_k, new_pool_v).
    """
    NBp1, bs, Hkv, D = pool_k.shape
    B, W = tables.shape
    H = cfg.num_heads
    G = H // Hkv
    q, k, v = _qkv(cfg, p, x, pos[:, None])
    blk = jnp.take_along_axis(tables, (pos[:, None] // bs) % W, axis=1)[:, 0]
    off = pos % bs
    pool_k = pool_k.at[blk, off].set(k[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[blk, off].set(v[:, 0].astype(pool_v.dtype))

    kg = pool_k[tables].reshape(B, W * bs, Hkv, D)      # gather block axis
    vg = pool_v[tables].reshape(B, W * bs, Hkv, D)
    qg = q.reshape(B, 1, Hkv, G, D)
    s_ = _grouped_scores(qg, kg) / (D ** 0.5)           # (B,Hkv,G,1,W*bs)
    idx = jnp.arange(W * bs)[None, :]
    valid = idx <= pos[:, None]
    s_ = jnp.where(valid[:, None, None, None, :], s_, NEG_INF)
    a = jax.nn.softmax(s_, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", a, vg).reshape(B, 1, H, D)
    y = jnp.einsum("bshd,hdk->bsk", o, p["wo"])
    return y, pool_k, pool_v


def decode_attention(cfg: ModelConfig, p: dict, x: jax.Array,
                     pos: jax.Array, k_cache: jax.Array, v_cache: jax.Array):
    """One-token decode: x (B,1,d), pos (B,), caches (B,S,Hkv,D).

    Returns (out (B,1,d), new_k_cache, new_v_cache). The caches already hold
    `pos` valid tokens; the new token is written at `pos` (mod window for
    sliding archs).
    """
    B, S, Hkv, D = k_cache.shape
    H = cfg.num_heads
    G = H // Hkv
    q, k, v = _qkv(cfg, p, x, pos[:, None])

    slot = pos % S if cfg.attention == "sliding" else pos

    def ins(cache, new):
        # masked elementwise insert instead of dynamic_update_slice: a
        # traced-index scatter into the seq-SHARDED dim makes the SPMD
        # partitioner materialize the full cache per device (measured
        # 2.1 GB/layer on qwen3 decode_32k); the iota-compare form is
        # elementwise, so every device touches only its local shard.
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, S, 1, 1), 1)
        mask = idx == slot[:, None, None, None]
        return jnp.where(mask, new.astype(cache.dtype), cache)

    k_cache = ins(k_cache, k)
    v_cache = ins(v_cache, v)
    k_cache = shard(k_cache, "batch", "seq", "kv_heads", None)
    v_cache = shard(v_cache, "batch", "seq", "kv_heads", None)

    qg = q.reshape(B, 1, Hkv, G, D)
    s_ = _grouped_scores(qg, k_cache) / (D ** 0.5)      # (B,Hkv,G,1,S)
    idx = jnp.arange(S)[None, :]                        # (1,S)
    valid = idx <= pos[:, None]                         # (B,S)
    if cfg.attention == "sliding":
        # ring buffer: once pos >= S the whole window is live
        valid = valid | (pos[:, None] >= S)
    s_ = jnp.where(valid[:, None, None, None, :], s_, NEG_INF)
    a = jax.nn.softmax(s_, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", a, v_cache).reshape(B, 1, H, D)
    y = jnp.einsum("bshd,hdk->bsk", o, p["wo"])
    return y, k_cache, v_cache
