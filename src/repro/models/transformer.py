"""Family-polymorphic decoder stack for all ten assigned architectures.

One block layout per family:

  dense/moe/vlm/audio :  x += Attn(LN(x));        x += FFN|MoE(LN(x))
  ssm (rwkv6)         :  x += TimeMix(LN(x));     x += ChannelMix(LN(x))
  hybrid (hymba)      :  x += (Attn+Mamba)(LN(x))/2;  x += FFN(LN(x))

Layers either run under ``lax.scan`` over stacked params (O(1) HLO — used by
smoke tests and real training) or statically unrolled (used by the dry-run so
``cost_analysis`` FLOPs/bytes are exact; XLA's while-loop cost model does not
multiply by trip count).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mlp as mlpm
from repro.models import rwkv6 as rwkv
from repro.models.common import ParamSpec, rms_norm

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> dict:
    L, d, V = cfg.num_layers, cfg.d_model, cfg.vocab_size
    dt = cfg.dtype
    specs: dict[str, Any] = {
        "ln1": ParamSpec((L, d), dt, ("layers", None), "ones"),
        "ln2": ParamSpec((L, d), dt, ("layers", None), "ones"),
        "final_norm": ParamSpec((d,), dt, (None,), "ones"),
    }
    if cfg.input_kind == "tokens":
        specs["embed"] = ParamSpec((V, d), dt, ("vocab", "embed"), "embed")
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, V), dt, ("embed", "vocab"))

    if cfg.family == "ssm":
        specs.update(rwkv.rwkv_specs(cfg))
    else:
        specs.update(attn.attn_specs(cfg))
        if cfg.family == "hybrid":
            specs.update(mam.mamba_specs(cfg))
            specs.update(mlpm.mlp_specs(cfg))
        elif cfg.num_experts > 0:
            specs.update(mlpm.moe_specs(cfg))
        else:
            specs.update(mlpm.mlp_specs(cfg))
    return specs


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Decode-state specs (KV cache / recurrent states) per family."""
    if cfg.family == "ssm":
        return rwkv.state_specs(cfg, batch)
    c = attn.cache_specs(cfg, batch, seq_len)
    if cfg.family == "hybrid":
        c.update(mam.mamba_state_specs(cfg, batch))
    return c


_BLOCK_KEYS_GLOBAL = ("embed", "lm_head", "final_norm")


def split_params(params: dict):
    blocks = {k: v for k, v in params.items() if k not in _BLOCK_KEYS_GLOBAL}
    glob = {k: v for k, v in params.items() if k in _BLOCK_KEYS_GLOBAL}
    return glob, blocks


# ---------------------------------------------------------------------------
# Blocks (per-layer params, i.e. the leading L dim already sliced away)
# ---------------------------------------------------------------------------

def block_full(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
               want_cache: bool):
    """Full-sequence block. Returns (x, cache_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    cache: dict = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        B = x.shape[0]
        H = cfg.d_model // cfg.rwkv_head_dim
        s0 = jnp.zeros((B, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                       jnp.float32)
        ts0 = jnp.zeros((B, cfg.d_model), x.dtype)
        y, ts_tm, s1 = rwkv.time_mix(cfg, p, h, ts0, s0)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y2, ts_cm = rwkv.channel_mix(cfg, p, h2, ts0)
        x = x + y2
        if want_cache:
            cache = {"wkv": s1, "ts_tm": ts_tm.astype(cfg.dtype),
                     "ts_cm": ts_cm.astype(cfg.dtype)}
        return x, cache, aux

    if cfg.family == "hybrid":
        B = x.shape[0]
        if want_cache:
            ya, (kc, vc) = attn.prefill_attention(cfg, p, h, positions)
        else:
            ya = attn.full_attention(cfg, p, h, positions)
        h0 = jnp.zeros((B, cfg.d_model, cfg.ssm_state), jnp.float32)
        ym, h1 = mam.mamba_mix(cfg, p, h, h0)
        x = x + 0.5 * (ya + ym)
        if want_cache:
            cache = {"k": kc, "v": vc, "ssm": h1}
    else:
        if want_cache:
            ya, (kc, vc) = attn.prefill_attention(cfg, p, h, positions)
            cache = {"k": kc, "v": vc}
        else:
            ya = attn.full_attention(cfg, p, h, positions)
        x = x + ya

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts > 0 and cfg.family != "hybrid":
        y2, aux = mlpm.moe_ffn(cfg, p, h2)
    else:
        y2 = mlpm.swiglu(p, h2)
    x = x + y2
    return x, cache, aux


def block_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
                 cache: dict):
    """One-token block. x (B,1,d); cache entries are per-layer slices."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "ssm":
        y, ts_tm, s1 = rwkv.time_mix_step(cfg, p, h, cache["ts_tm"],
                                          cache["wkv"])
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y2, ts_cm = rwkv.channel_mix_step(cfg, p, h2, cache["ts_cm"])
        x = x + y2
        return x, {"wkv": s1, "ts_tm": ts_tm.astype(cfg.dtype),
                   "ts_cm": ts_cm.astype(cfg.dtype)}

    if cfg.family == "hybrid":
        ya, kc, vc = attn.decode_attention(cfg, p, h, pos, cache["k"],
                                           cache["v"])
        ym, h1 = mam.mamba_step(cfg, p, h, cache["ssm"])
        x = x + 0.5 * (ya + ym)
        new_cache = {"k": kc, "v": vc, "ssm": h1}
    else:
        ya, kc, vc = attn.decode_attention(cfg, p, h, pos, cache["k"],
                                           cache["v"])
        x = x + ya
        new_cache = {"k": kc, "v": vc}

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts > 0 and cfg.family != "hybrid":
        y2, _ = mlpm.moe_ffn(cfg, p, h2)
    else:
        y2 = mlpm.swiglu(p, h2)
    return x + y2, new_cache


def block_decode_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                       pos: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                       tables: jax.Array):
    """One-token block against a paged KV pool layer slice. Identical math
    to ``block_decode`` around the attention call — greedy bit-identity
    with the dense engine hinges on this."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    ya, pool_k, pool_v = attn.decode_attention_paged(cfg, p, h, pos,
                                                     pool_k, pool_v, tables)
    x = x + ya
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.num_experts > 0 and cfg.family != "hybrid":
        y2, _ = mlpm.moe_ffn(cfg, p, h2)
    else:
        y2 = mlpm.swiglu(p, h2)
    return x + y2, pool_k, pool_v


# ---------------------------------------------------------------------------
# Stack runners
# ---------------------------------------------------------------------------

def _slice_layer(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def run_blocks_full(cfg: ModelConfig, blocks: dict, x: jax.Array,
                    positions: jax.Array, want_cache: bool,
                    unroll: bool, remat: bool,
                    remat_policy: str = "full"):
    def fn(pl, xc, pos_, wc=want_cache):
        return block_full(cfg, pl, xc, pos_, wc)

    if remat:
        # "dots": keep matmul outputs (incl. gathered operands) — backward
        # does not replay the forward's collectives (§Perf H7); costs HBM.
        policy = None if remat_policy == "full" else \
            jax.checkpoint_policies.dots_saveable
        fn = jax.checkpoint(fn, policy=policy)
    if unroll:
        caches, aux = [], jnp.zeros((), jnp.float32)
        for i in range(cfg.num_layers):
            x, c, a = fn(_slice_layer(blocks, i), x, positions)
            caches.append(c)
            aux = aux + a
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches) \
            if want_cache else {}
        return x, cache, aux

    def body(carry, pl):
        xc, auxc = carry
        xc, c, a = fn(pl, xc, positions)
        return (xc, auxc + a), c

    (x, aux), cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   blocks)
    return x, (cache if want_cache else {}), aux


def run_blocks_decode(cfg: ModelConfig, blocks: dict, x: jax.Array,
                      pos: jax.Array, cache: dict, unroll: bool):
    if unroll:
        new = []
        for i in range(cfg.num_layers):
            x, c = block_decode(cfg, _slice_layer(blocks, i), x, pos,
                                _slice_layer(cache, i))
            new.append(c)
        return x, jax.tree.map(lambda *xs: jnp.stack(xs), *new)

    def body(xc, inp):
        pl, cl = inp
        xc, c = block_decode(cfg, pl, xc, pos, cl)
        return xc, c

    x, new_cache = jax.lax.scan(body, x, (blocks, cache))
    return x, new_cache


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, glob: dict, inputs: jax.Array) -> jax.Array:
    if cfg.input_kind == "tokens":
        x = jnp.take(glob["embed"], inputs, axis=0)
    else:                                   # vlm/audio frontend stub output
        x = inputs.astype(jnp.dtype(cfg.dtype))
    return shard(x, "batch", None, "embed")


def logits_head(cfg: ModelConfig, glob: dict, x: jax.Array) -> jax.Array:
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, glob["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, glob["lm_head"])
    return shard(logits, "batch", None, "vocab")


def forward_full(cfg: ModelConfig, params: dict, inputs: jax.Array,
                 want_cache: bool = False, unroll: bool = False,
                 remat: bool = False, remat_policy: str = "full"):
    """Train/prefill forward. inputs: (B,S) int tokens or (B,S,d) embeds.
    Returns (logits, cache, aux)."""
    glob, blocks = split_params(params)
    x = embed_inputs(cfg, glob, inputs)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, cache, aux = run_blocks_full(cfg, blocks, x, positions, want_cache,
                                    unroll, remat, remat_policy)
    return logits_head(cfg, glob, x), cache, aux


def forward_decode(cfg: ModelConfig, params: dict, inputs: jax.Array,
                   pos: jax.Array, cache: dict, unroll: bool = False):
    """One-token decode. inputs (B,1) tokens or (B,1,d); pos (B,) int32.
    Returns (logits (B,1,V), new_cache)."""
    glob, blocks = split_params(params)
    x = embed_inputs(cfg, glob, inputs)
    x, new_cache = run_blocks_decode(cfg, blocks, x, pos, cache, unroll)
    return logits_head(cfg, glob, x), new_cache


def _check_paged_family(cfg: ModelConfig) -> None:
    if cfg.family in ("ssm", "hybrid") or cfg.attention != "full":
        raise NotImplementedError(
            f"paged KV decode supports full-attention transformer families "
            f"only (got family={cfg.family}, attention={cfg.attention}); "
            f"recurrent/sliding state does not page")


def forward_decode_paged(cfg: ModelConfig, params: dict, inputs: jax.Array,
                         pos: jax.Array, pool_k: jax.Array,
                         pool_v: jax.Array, tables: jax.Array,
                         unroll: bool = False):
    """One-token decode addressing a paged KV pool through block tables.

    inputs (B,1) tokens or (B,1,d); pos (B,) int32; pool_k/v
    (L, num_blocks+1, block_size, Hkv, D); tables (B, W) int32.
    Returns (logits (B,1,V), new_pool_k, new_pool_v).
    """
    _check_paged_family(cfg)
    glob, blocks = split_params(params)
    x = embed_inputs(cfg, glob, inputs)
    if unroll:
        nk, nv = [], []
        for i in range(cfg.num_layers):
            x, pk, pv = block_decode_paged(cfg, _slice_layer(blocks, i), x,
                                           pos, pool_k[i], pool_v[i], tables)
            nk.append(pk)
            nv.append(pv)
        return (logits_head(cfg, glob, x),
                jnp.stack(nk), jnp.stack(nv))

    def body(xc, inp):
        pl, pk, pv = inp
        xc, pk, pv = block_decode_paged(cfg, pl, xc, pos, pk, pv, tables)
        return xc, (pk, pv)

    x, (pool_k, pool_v) = jax.lax.scan(body, x, (blocks, pool_k, pool_v))
    return logits_head(cfg, glob, x), pool_k, pool_v


def scatter_prefill_cache(pool_k: jax.Array, pool_v: jax.Array,
                          cache_k: jax.Array, cache_v: jax.Array,
                          tables: jax.Array):
    """Scatter a dense prefill cache (L, B, S, Hkv, D) into the paged pool
    through block tables (B, W), W * block_size >= S. Pad lanes (tables
    all-null) land their rows in the null block. Runs inside the compiled
    prefill step — the pool is addressed device-side, never rebuilt on
    host."""
    L, B, S, Hkv, D = cache_k.shape
    bs = pool_k.shape[2]
    W = tables.shape[1]
    tpos = jnp.arange(S, dtype=jnp.int32)[None, :]            # (1, S)
    blk = jnp.take_along_axis(tables, jnp.broadcast_to((tpos // bs) % W,
                                                       (B, S)), axis=1)
    off = jnp.broadcast_to(tpos % bs, (B, S))
    pool_k = pool_k.at[:, blk, off].set(cache_k.astype(pool_k.dtype))
    pool_v = pool_v.at[:, blk, off].set(cache_v.astype(pool_v.dtype))
    return pool_k, pool_v
