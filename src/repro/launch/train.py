"""Training driver: the end-to-end example entrypoint.

Runs real steps on whatever devices exist (CPU smoke -> pod): synthetic
shardable data, AdamW, CRC-checkpointing with async save, RTPM heartbeats
and telemetry, restart-from-latest on relaunch.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 200 --d-model 256
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.core.rtpm import Platform
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.models.common import init_params, param_count
from repro.optim.adamw import adamw_init_specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model: 768)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    if args.d_model:
        head_dim = max(16, args.d_model // max(1, cfg.num_heads or 12))
        head_dim -= head_dim % 2                      # RoPE needs even dims
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, d_ff=args.d_model * 4,
            head_dim=head_dim if cfg.num_heads else 0,
            vocab_size=min(cfg.vocab_size, 8192))
    if args.layers:
        cfg = dataclasses.replace(cfg, num_layers=args.layers)

    platform = Platform()
    specs = tf.model_specs(cfg)
    print(f"[train] {cfg.name}: {param_count(specs)/1e6:.1f}M params, "
          f"{jax.device_count()} device(s)")

    params = init_params(jax.random.PRNGKey(0), specs)
    opt = init_params(jax.random.PRNGKey(1), adamw_init_specs(specs))
    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    restored = mgr.restore_latest({"params": params, "opt": opt})
    if restored is not None:
        state, start, _ = restored
        params, opt = state["params"], state["opt"]
        print(f"[train] restored checkpoint at step {start}")

    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                     global_batch=args.batch)
    step_fn = jax.jit(make_train_step(cfg, peak_lr=args.lr, warmup=20,
                                      total_steps=args.steps))

    t_last = time.perf_counter()
    for i in range(start, args.steps):
        b = ds.global_batch_at(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = step_fn(params, opt, batch)
        platform.heartbeats.beat("worker0", step=i)
        now = time.perf_counter()
        platform.telemetry.record_latency(now - t_last)
        t_last = now
        if (i + 1) % args.log_every == 0:
            print(f"  step {i+1:5d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f}")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save({"params": params, "opt": opt}, step=i + 1)
    mgr.save({"params": params, "opt": opt}, step=args.steps, block=True)
    s = platform.telemetry.summary(warmup=3)
    if s.get("n", 0) > 2:
        print(f"[train] done. step latency mean={s['mean']*1e3:.1f}ms "
              f"CV={s['cv_percent']:.2f}% p99={s['p99']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
