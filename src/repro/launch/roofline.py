"""Roofline analysis over the dry-run artifacts.

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bandwidth
  collective term = collective_bytes_per_device / ICI_link_bandwidth

(cost_analysis of an SPMD-compiled module is per-device, so the "chips x"
denominators in the assignment formulas are already divided out.)

Additionally: MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference steps), with
N_active for MoE; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch/
attention-cache overheads; roofline_fraction = ideal compute time over the
dominant term (the report's score); and a per-cell bottleneck note.

Usage:  python -m repro.launch.roofline [--mesh pod256] [--markdown]
"""
from __future__ import annotations

import argparse
import json
import pathlib

TPU_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip (v5e)
TPU_HBM_BW = 819e9               # B/s per chip
TPU_ICI_BW = 50e9                # B/s per link

REPO = pathlib.Path(__file__).resolve().parents[3]
RESULTS = REPO / "results"


def model_flops_per_device(rec: dict) -> float:
    m = rec["model"]
    n = m["active_params"]
    if rec["kind"] == "train":
        toks = m["global_batch"] * m["seq_len"]
        total = 6.0 * n * toks
    elif rec["kind"] == "prefill":
        toks = m["global_batch"] * m["seq_len"]
        total = 2.0 * n * toks
    else:                                     # decode: one token per seq
        toks = m["global_batch"]
        total = 2.0 * n * toks
    return total / rec["devices"]


def analyze(rec: dict) -> dict:
    t_c = rec["flops_per_device"] / TPU_PEAK_FLOPS
    t_m = rec["bytes_per_device"] / TPU_HBM_BW
    t_x = rec["collective_bytes_per_device"] / TPU_ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    t_ideal = mf / TPU_PEAK_FLOPS
    frac = t_ideal / max(terms.values()) if max(terms.values()) > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_device": mf,
        "useful_ratio": mf / rec["flops_per_device"]
        if rec["flops_per_device"] else 0.0,
        "roofline_fraction": frac,
        "note": note_for(rec, dom, terms),
    }


def note_for(rec: dict, dom: str, terms: dict) -> str:
    kind = rec["kind"]
    if dom == "collective":
        return ("shrink collective volume: fewer/larger fused all-reduces, "
                "EP all-to-all instead of all-gather dispatch, keep TP "
                "traffic intra-pod" if kind != "decode" else
                "decode collective-bound: replicate small states instead of "
                "gathering, batch KV-sharded partial-softmax reductions")
    if dom == "memory":
        if kind == "decode":
            return ("decode is KV/weight-streaming bound (expected): raise "
                    "batch per chip, quantize KV cache, or fuse cache "
                    "read+attend (flash-decode kernel)")
        return ("cut HBM traffic: fuse softmax/norm chains (flash kernels), "
                "bf16 intermediates, larger remat blocks")
    return ("compute-bound (good): push MXU utilization via larger per-chip "
            "tiles and int8 where the paper's quantized path applies")


def load(mesh: str, include_skips: bool = False) -> list:
    out = []
    for p in sorted((RESULTS / "dryrun" / mesh).glob("*.json")):
        if p.name.count("__") > 1:       # __full / __train_zero1 variants
            continue
        rec = json.loads(p.read_text())
        if rec.get("skipped"):
            if include_skips:
                out.append(rec)
            continue
        out.append(analyze(rec))
    return out


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | note |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        if r.get("skipped"):
            body += (f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                     f"| SKIP: {r['reason']} |\n")
            continue
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_fraction']:.2%} | {r['note']} |\n")
    return hdr + body


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod256", choices=("pod256", "pod512"))
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = load(args.mesh, include_skips=True)
    analyzed = [r for r in rows if not r.get("skipped")]
    (RESULTS / f"roofline_{args.mesh}.json").write_text(
        json.dumps(rows, indent=2))
    if args.markdown:
        print(markdown_table(rows))
    else:
        for r in analyzed:
            print(f"{r['arch']:24s} {r['shape']:12s} dom={r['dominant']:10s} "
                  f"frac={r['roofline_fraction']:7.2%} "
                  f"useful={r['useful_ratio']:.2f}")
    worst = sorted(analyzed, key=lambda r: r["roofline_fraction"])[:5]
    print("\n# worst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']} x {r['shape']}: {r['roofline_fraction']:.2%} "
              f"({r['dominant']}-bound)")
    collb = [r for r in analyzed if r["dominant"] == "collective"]
    print(f"# collective-bound cells: {len(collb)}")


if __name__ == "__main__":
    main()
