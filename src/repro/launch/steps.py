"""Step builders + ``input_specs`` stand-ins for every (arch x shape) cell.

``input_specs`` follows the dry-run contract: ShapeDtypeStruct stand-ins for
every model input — weak-type-correct, shardable (NamedShardings resolved by
the RBL logical-axis rules when a binding context is active), zero device
allocation. ``train_step`` is lowered for train shapes; ``prefill_step`` /
``decode_step`` (the ``serve_step``s) for inference shapes.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import sharding_for
from repro.models import transformer as tf
from repro.models.common import (ParamSpec, init_params, shape_structs,
                                 softmax_cross_entropy)
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init_specs, \
    adamw_update
from repro.optim.schedules import cosine_warmup


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, axes):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype),
                                sharding=sharding_for(shape, axes))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for the data inputs of one cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_kind == "tokens":
            toks = _sds((B, S), "int32", ("batch", None))
        else:   # vlm/audio: precomputed patch/frame embeddings (stub frontend)
            toks = _sds((B, S, cfg.d_model), cfg.dtype, ("batch", None, "embed"))
        return {"inputs": toks, "targets": _sds((B, S), "int32", ("batch", None))}
    if shape.kind == "prefill":
        if cfg.input_kind == "tokens":
            toks = _sds((B, S), "int32", ("batch", None))
        else:
            toks = _sds((B, S, cfg.d_model), cfg.dtype, ("batch", None, "embed"))
        return {"inputs": toks}
    # decode: one new token against a seq_len-deep cache
    if cfg.input_kind == "tokens":
        toks = _sds((B, 1), "int32", ("batch", None))
    else:
        toks = _sds((B, 1, cfg.d_model), cfg.dtype, ("batch", None, "embed"))
    return {"inputs": toks, "pos": _sds((B,), "int32", ("batch",))}


def param_structs(cfg: ModelConfig):
    return shape_structs(tf.model_specs(cfg))


def opt_structs(cfg: ModelConfig):
    return shape_structs(adamw_init_specs(tf.model_specs(cfg)))


def cache_structs(cfg: ModelConfig, shape: ShapeConfig):
    return shape_structs(tf.cache_specs(cfg, shape.global_batch,
                                        shape.seq_len))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, unroll: bool, remat: bool,
                 remat_policy: str = "full"):
    def loss_fn(params, batch):
        logits, _, aux = tf.forward_full(cfg, params, batch["inputs"],
                                         want_cache=False, unroll=unroll,
                                         remat=remat,
                                         remat_policy=remat_policy)
        loss = softmax_cross_entropy(logits, batch["targets"])
        return loss + tf.AUX_LOSS_WEIGHT * aux, (loss, aux)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamWConfig = AdamWConfig(),
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, unroll: bool = False,
                    remat: bool = True, remat_policy: str = None):
    import os
    remat_policy = remat_policy or os.environ.get("AEG_REMAT_POLICY", "full")
    loss_fn = make_loss_fn(cfg, unroll, remat, remat_policy)

    def train_step(params, opt_state: AdamWState, batch):
        (total, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        lr = cosine_warmup(opt_state.step, peak_lr, warmup, total_steps)
        params, opt_state, gm = adamw_update(opt, grads, opt_state, params, lr)
        metrics = {"loss": loss, "aux_loss": aux, "total_loss": total,
                   "lr": lr, **gm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False):
    def prefill_step(params, batch):
        logits, cache, _ = tf.forward_full(cfg, params, batch["inputs"],
                                           want_cache=True, unroll=unroll)
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, unroll: bool = False):
    def decode_step(params, cache, batch):
        pos = batch.get("pos")
        if pos is None:
            pos = jnp.zeros((batch["inputs"].shape[0],), jnp.int32)
        logits, cache = tf.forward_decode(cfg, params, batch["inputs"], pos,
                                          cache, unroll=unroll)
        return logits[:, 0], cache
    return decode_step


def sample_tokens(logits: jax.Array, greedy: bool, temperature: float,
                  key: Optional[jax.Array] = None) -> jax.Array:
    """Next-token pick shared by the host-side (dense) engine and the
    compiled paged decode program, so greedy decoding is bit-identical
    across both paths. logits: (B, V) -> (B,) int32."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("temperature sampling requires a PRNG key")
    t = max(float(temperature), 1e-6)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / t, axis=-1).astype(jnp.int32)


def make_paged_prefill_step(cfg: ModelConfig, unroll: bool = False):
    """Prefill that lands its KV directly in the paged pool: same dense
    forward as ``make_prefill_step`` (identical last-token logits, hence
    identical first sampled token), then one device-side scatter through
    the batch's block tables. Signature:
    (params, pool_k, pool_v, {"inputs": (B,S), "tables": (B,W)})
      -> (last_logits (B,V), pool_k', pool_v')."""
    def paged_prefill_step(params, pool_k, pool_v, batch):
        logits, cache, _ = tf.forward_full(cfg, params, batch["inputs"],
                                           want_cache=True, unroll=unroll)
        pool_k, pool_v = tf.scatter_prefill_cache(
            pool_k, pool_v, cache["k"], cache["v"], batch["tables"])
        return logits[:, -1], pool_k, pool_v
    return paged_prefill_step


def make_paged_decode_step(cfg: ModelConfig, window: int = 1,
                           greedy: bool = True, temperature: float = 1.0,
                           unroll: bool = False):
    """Persistent multi-token decode program for the paged pool.

    One dispatch advances every lane ``window`` tokens: a ``lax.scan``
    over the window runs forward → sample → feed-back entirely on
    device, so the host touches only (B, window) sampled ints per
    dispatch instead of a logits round-trip per token. Signature:
    (params, pool_k, pool_v, {"tokens": (B,), "pos": (B,),
                              "tables": (B,W)[, "key"]})
      -> (tokens (B,window), pool_k', pool_v')
    where batch["tokens"] is the last already-sampled token (written at
    position batch["pos"]) and the output rows are the ``window`` newly
    sampled tokens per lane."""
    def paged_decode_step(params, pool_k, pool_v, batch):
        def body(carry, key):
            tok, pk, pv, pos = carry
            logits, pk, pv = tf.forward_decode_paged(
                cfg, params, tok[:, None], pos, pk, pv, batch["tables"],
                unroll=unroll)
            nxt = sample_tokens(logits[:, 0], greedy, temperature, key)
            return (nxt, pk, pv, pos + 1), nxt

        keys = None if greedy else jax.random.split(batch["key"], window)
        carry = (batch["tokens"], pool_k, pool_v, batch["pos"])
        (_, pool_k, pool_v, _), toks = jax.lax.scan(
            body, carry, xs=keys, length=window)
        return toks.T, pool_k, pool_v
    return paged_decode_step


def step_for(cfg: ModelConfig, shape: ShapeConfig, unroll: bool):
    """(callable, example-args builder) for one dry-run cell."""
    if shape.kind == "train":
        fn = make_train_step(cfg, unroll=unroll)
        args = (param_structs(cfg), opt_structs(cfg), input_specs(cfg, shape))
        donate = (0, 1)
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, unroll=unroll)
        args = (param_structs(cfg), input_specs(cfg, shape))
        donate = ()
    else:
        fn = make_decode_step(cfg, unroll=unroll)
        args = (param_structs(cfg), cache_structs(cfg, shape),
                input_specs(cfg, shape))
        donate = (1,)
    return fn, args, donate
