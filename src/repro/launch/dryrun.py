import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax locks
the device count on first init, and the production meshes need 512 host
placeholder devices. (Smoke tests and benchmarks import repro normally and
see 1 device; only this entrypoint forces 512.)

Per cell we record: compile wall-time, ``memory_analysis()`` (proves the
per-device footprint), ``cost_analysis()`` (FLOPs / bytes for the roofline),
and the collective-op byte totals parsed from the optimized HLO (the
collective roofline term). Artifacts land in results/dryrun/ as JSON.
"""

import argparse
import json
import pathlib
import re
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parents[3]
RESULTS = REPO / "results" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?)")
_COLL_RE = re.compile(
    r"=\s*[^=]*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _line_result_bytes(line: str) -> int:
    """Total bytes of the result type(s) on an HLO def line."""
    eq = line.find("=")
    rest = line[eq + 1:]
    # result types come before the opcode name; grab leading shape literals
    # (covers tuples): stop at the first identifier that isn't a shape.
    total = 0
    for m in _SHAPE_RE.finditer(rest):
        # only count shapes that appear before the opening paren of operands
        par = rest.find("(")
        # tuples start with '(' immediately — find the opcode paren instead:
        # shapes inside the leading tuple are before the opcode word; simplest
        # robust rule: count shapes up to the first lowercase opcode token
        # followed by '('. We approximate by counting shapes that occur
        # before the first ' %' operand reference.
        first_operand = rest.find("%")
        if first_operand != -1 and m.start() > first_operand:
            break
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def parse_collectives(hlo: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO text.

    Operands are printed by name only, so we first build a per-computation
    symbol table (name -> result bytes) and then resolve each collective's
    operand list against it. Async pairs (-start/-done) are counted once.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    sym: dict[str, int] = {}
    for line in hlo.splitlines():
        s = line.rstrip()
        d = _DEF_RE.match(s)
        if d:
            sym[d.group(1)] = _line_result_bytes(s)
        m = _COLL_RE.search(s)
        if not m or m.group(2) == "-done":
            continue
        op = m.group(1)
        args = s[m.end():]
        depth = 1
        end = len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        names = _OPERAND_RE.findall(args[:end])
        out[op] += sum(sym.get(n, 0) for n in names)
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _compile_once(cfg, shape, mesh, rules, unroll: bool):
    """Lower + compile one step; return (compiled, seconds)."""
    import jax

    from repro.distributed.sharding import axis_rules
    from repro.launch import steps as st

    t0 = time.time()
    with axis_rules(mesh, rules):
        fn, args, donate = st.step_for(cfg, shape, unroll=unroll)
        with mesh:
            compiled = jax.jit(fn, donate_argnums=donate) \
                .lower(*args).compile()
    return compiled, time.time() - t0


def _cost_rec(compiled) -> dict:
    cost = compiled.cost_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collectives": parse_collectives(compiled.as_text()),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             unroll: bool = False, force: bool = False,
             save: bool = True, rules_variant: str = "") -> dict:
    """One dry-run cell.

    Default ("extrapolate") protocol — required because (a) this container
    has one core, so full-unroll compiles of 40L models take many minutes,
    and (b) XLA's cost model counts a while-loop body once regardless of
    trip count, so scan-over-layers FLOPs are L-times under-reported:

      A. full-config *scan-over-layers* compile  -> the shardability +
         memory proof (memory_analysis, collective schedule, compile ok);
      B. 1-layer and 2-layer *unrolled* compiles -> exact per-layer
         FLOPs/bytes/collectives; totals extrapolate as X1 + (L-1)(X2-X1).

    ``unroll=True`` (--mode full) instead compiles the fully unrolled model
    and reports its exact cost analysis; used to validate the extrapolation
    (see EXPERIMENTS.md §Dry-run cross-check).
    """
    import dataclasses

    import jax

    from repro.configs import SHAPES, applicable_shapes, get_config
    from repro.launch.mesh import make_production_mesh

    mesh_tag = "pod512" if multi_pod else "pod256"
    suffix = "__full" if unroll else ""
    if rules_variant:
        suffix += f"__{rules_variant}"
    out_path = RESULTS / mesh_tag / f"{arch}__{shape_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name not in applicable_shapes(cfg):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "skipped": True,
               "reason": "long_500k reserved for sub-quadratic archs"}
        if save:
            out_path.parent.mkdir(parents=True, exist_ok=True)
            out_path.write_text(json.dumps(rec, indent=2))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_variant or {"train": "train", "prefill": "prefill",
                              "decode": "decode"}[shape.kind]
    L = cfg.num_layers

    if unroll:                                   # --mode full (validation)
        compiled, secs = _compile_once(cfg, shape, mesh, rules, unroll=True)
        proof_mem = compiled.memory_analysis()
        c = _cost_rec(compiled)
        totals = {"flops": c["flops"], "bytes": c["bytes"],
                  "coll_bytes": c["collectives"]["total_bytes"],
                  "coll_counts": c["collectives"]["counts"]}
        per_layer = {}
        t_proof = secs
    else:
        # A: full-config proof compile (scan over layers)
        compiled, t_proof = _compile_once(cfg, shape, mesh, rules,
                                          unroll=False)
        proof_mem = compiled.memory_analysis()
        # B: exact per-layer accounting from 1L/2L unrolled compiles
        c1, s1 = _compile_once(dataclasses.replace(cfg, num_layers=1),
                               shape, mesh, rules, unroll=True)
        c2, s2 = _compile_once(dataclasses.replace(cfg, num_layers=2),
                               shape, mesh, rules, unroll=True)
        r1, r2 = _cost_rec(c1), _cost_rec(c2)
        secs = t_proof + s1 + s2

        def extra(k):
            return r1[k] + (L - 1) * (r2[k] - r1[k])

        cb1 = r1["collectives"]["total_bytes"]
        cb2 = r2["collectives"]["total_bytes"]
        coll_by_kind = {
            k: r1["collectives"]["bytes"][k] + (L - 1) *
               (r2["collectives"]["bytes"][k] - r1["collectives"]["bytes"][k])
            for k in r1["collectives"]["bytes"]}
        totals = {"flops": extra("flops"), "bytes": extra("bytes"),
                  "coll_bytes": cb1 + (L - 1) * (cb2 - cb1),
                  "coll_bytes_by_kind": coll_by_kind}
        per_layer = {"flops_1L": r1["flops"], "flops_2L": r2["flops"],
                     "bytes_1L": r1["bytes"], "bytes_2L": r2["bytes"],
                     "coll_1L": cb1, "coll_2L": cb2,
                     "coll_counts_2L": r2["collectives"]["counts"]}

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "kind": shape.kind,
        "devices": int(mesh.devices.size),
        "mode": "full_unroll" if unroll else "extrapolated",
        "compile_seconds": round(secs, 2),
        "proof_compile_seconds": round(t_proof, 2),
        "flops_per_device": totals["flops"],
        "bytes_per_device": totals["bytes"],
        "collective_bytes_per_device": totals["coll_bytes"],
        "collective_detail": totals.get("coll_bytes_by_kind",
                                        totals.get("coll_counts")),
        "per_layer": per_layer,
        "memory": {
            "argument_bytes": proof_mem.argument_size_in_bytes,
            "output_bytes": proof_mem.output_size_in_bytes,
            "temp_bytes": proof_mem.temp_size_in_bytes,
            "alias_bytes": proof_mem.alias_size_in_bytes,
        },
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "global_batch": shape.global_batch,
            "seq_len": shape.seq_len,
        },
    }
    print(f"[dryrun] {arch} x {shape_name} x {mesh_tag} ({rec['mode']}): "
          f"compile={secs:.1f}s flops/dev={totals['flops']:.3e} "
          f"coll/dev={totals['coll_bytes']/1e6:.1f}MB "
          f"temp={proof_mem.temp_size_in_bytes/1e9:.2f}GB")
    print("  memory_analysis:", proof_mem)
    if save:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
    return rec


def _all_cells():
    from repro.configs import ARCHES, SHAPES
    for arch in ARCHES:
        for shape in SHAPES:
            yield arch, shape


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in crash-isolated subprocesses")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--mode", choices=("extrapolate", "full"),
                    default="extrapolate")
    ap.add_argument("--rules", default="",
                    help="rule-set variant override (e.g. train_zero1)")
    args = ap.parse_args()

    if args.all:
        fails = []
        meshes = [False, True] if args.both_meshes or not args.multipod \
            else [True]
        for arch, shape in _all_cells():
            for mp in meshes:
                tag = "pod512" if mp else "pod256"
                suffix = "__full" if args.mode == "full" else ""
                out = RESULTS / tag / f"{arch}__{shape}{suffix}.json"
                if out.exists() and not args.force:
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mode", args.mode]
                if mp:
                    cmd.append("--multipod")
                if args.force:
                    cmd.append("--force")
                r = subprocess.run(cmd, cwd=str(REPO),
                                   env={**os.environ,
                                        "PYTHONPATH": str(REPO / "src")})
                if r.returncode != 0:
                    fails.append((arch, shape, tag))
                    print(f"[dryrun] FAILED {arch} x {shape} x {tag}")
        if fails:
            print("FAILURES:", fails)
            return 1
        print("[dryrun] all cells green")
        return 0

    rec = run_cell(args.arch, args.shape, args.multipod,
                   unroll=(args.mode == "full"), force=args.force,
                   rules_variant=args.rules)
    return 0 if rec else 1


if __name__ == "__main__":
    sys.exit(main())
