"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state. Single-pod: 16x16 = 256 chips, axes
("data", "model"). Multi-pod: 2x16x16 = 512 chips, axes
("pod", "data", "model") — the "pod" axis composes with "data" for batch
sharding (DCN-friendly DP across pods; ICI-bound TP stays inside a pod).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over however many host devices exist (tests only)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes, axis_types=(AxisType.Auto,) * len(axes))
