"""Serving driver: network-attached inference service (the paper's mode).

Starts the CRC-framed socket server, provisions the ResNet-18 case study
(or an LM engine with --lm), fires batched client requests at it —
optionally from several concurrent connections, each pipelining v2
request-id frames — and reports latency CV + dispatcher telemetry.

  PYTHONPATH=src python -m repro.launch.serve --requests 64
  PYTHONPATH=src python -m repro.launch.serve --requests 64 --clients 4
  PYTHONPATH=src python -m repro.launch.serve --lm --requests 8
  PYTHONPATH=src python -m repro.launch.serve --fleet --requests 48

--fleet runs the elastic-operations demo: a FleetController scales the
live tile mesh up and back down, hot-swaps the weight image (probe +
atomic flip), and survives a tile-group kill — all under the same
client traffic, with every response checked against a reference.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.configs.resnet18 import CONFIG as RESNET
from repro.core import rctc
from repro.models import resnet as rn
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import DeadlineScheduler
from repro.serving.server import Client, InferenceServer


def serve_resnet(requests: int, batch: int, clients: int,
                 pipeline: int, batch_window: int = 8) -> None:
    cfg = RESNET.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params),
                                        batch=batch)
    server = InferenceServer(batch_window=batch_window)
    addr = server.start()
    print(f"[serve] listening on {addr}")
    try:
        c0 = Client(addr)
        print("[serve] provision:", c0.provision(image, prog.encode()))
        # distribute --requests exactly: first `requests % clients`
        # connections take one extra
        shares = [requests // clients + (1 if c < requests % clients else 0)
                  for c in range(clients)]

        def run_client(cid: int, counts: list) -> None:
            client = c0 if cid == 0 else Client(addr)
            rng = np.random.RandomState(cid)
            per_client = shares[cid]
            done = 0
            try:
                for _ in range(0, per_client, pipeline):
                    rids = []
                    for _ in range(min(pipeline, per_client - done)):
                        x = rng.rand(batch, cfg.image_size, cfg.image_size,
                                     3).astype(np.float32)
                        rids.append(client.infer_async(input=x))
                    for rid in rids:
                        client.result(rid)
                        done += 1
            finally:
                counts[cid] = done
                if cid != 0:
                    client.close()

        counts = [0] * clients
        t0 = time.perf_counter()
        threads = [threading.Thread(target=run_client, args=(cid, counts))
                   for cid in range(clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        n = sum(counts)
        tel = c0.telemetry()
        srv = tel.get("serving", {})
        print(f"[serve] {n} requests x batch {batch} over {clients} "
              f"client(s) (pipeline depth {pipeline}): "
              f"{n*batch/dt:.1f} img/s; "
              f"CV={tel.get('cv_percent', 0):.2f}% "
              f"p99={tel.get('p99', 0)*1e3:.2f}ms; "
              f"dispatcher processed={srv.get('processed')} "
              f"rejected={srv.get('rejected')} shed={srv.get('shed')} "
              f"batched={srv.get('batched', {}).get('requests', 0)}reqs/"
              f"{srv.get('batched', {}).get('dispatches', 0)}dispatches "
              f"queue_wait_p95="
              f"{srv.get('queue_wait', {}).get('p95', 0)*1e3:.2f}ms")
        c0.close()
    finally:
        server.stop()


def serve_fleet(requests: int, groups: int = 2, peak: int = 8) -> None:
    """Elastic fleet demo: scale cycle + kill/heal + hot swap under
    sustained traffic, every response bit-compared to a single-device
    reference."""
    from repro.core import rhal, rimfs
    from repro.core.fleet import FleetController

    depth, n = 8, 24
    prog = rctc.compile_gemm_chain(depth, n)
    files = rctc.gemm_chain_weights(depth, n)
    image = rimfs.pack(files)
    server = InferenceServer(mesh=rhal.TileMesh(groups), max_queue=256)
    addr = server.start()
    print(f"[fleet] listening on {addr}, mesh={groups} groups")
    fleet = FleetController(server)
    ok = bad = 0
    try:
        client = Client(addr, retries=10, backoff=0.02, retry_seed=0)
        client.provision(image, prog.encode())
        x = np.random.RandomState(0).randn(n, n).astype(np.float32)
        ref = client.infer(input=x)

        def burst(count: int, label: str) -> None:
            nonlocal ok, bad
            t0 = time.perf_counter()
            for _ in range(count):
                out = client.infer(input=x)
                if all(np.array_equal(ref[k], out[k]) for k in ref):
                    ok += 1
                else:
                    bad += 1
            print(f"[fleet] {label}: {count} requests, "
                  f"{(time.perf_counter() - t0) / count * 1e3:.2f}ms avg, "
                  f"bit_identical={bad == 0}")

        share = max(4, requests // 4)
        burst(share, f"baseline @{groups}")
        rep = fleet.scale_to(peak)
        print(f"[fleet] scaled {rep['from']} -> {rep['to']} in "
              f"{rep['seconds'] * 1e3:.1f}ms")
        burst(share, f"scaled @{peak}")
        state = fleet.swap_weights(rimfs.pack(files), label="repack")
        print(f"[fleet] hot swap: {state}")
        burst(share, "post-swap")
        server.mesh.kill(peak - 1)
        rep = fleet.tick()
        print(f"[fleet] killed group {peak - 1}; tick -> "
              f"{rep['action']}")
        burst(share, "post-heal")
        rep = fleet.scale_to(groups)
        print(f"[fleet] scaled back -> {rep['to']} "
              f"(cached_mesh={rep.get('cached_mesh')})")
        print(f"[fleet] done: ok={ok} mismatched={bad} "
              f"events={dict(fleet.summary()['events'])}")
        client.close()
    finally:
        fleet.stop()
        server.stop()


def serve_lm(requests: int) -> None:
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    sched = DeadlineScheduler()
    eng = ServingEngine(cfg, params, max_batch=4, max_seq=128,
                        scheduler=sched)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, (16,))
                    .astype(np.int32), max_new=8)
            for i in range(requests)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    s = eng.telemetry.summary(warmup=2)
    print(f"[serve-lm] {requests} prompts, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s); decode-step "
          f"CV={s.get('cv_percent', 0):.2f}%; shed={sched.shed_count}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--clients", type=int, default=1,
                    help="concurrent client connections")
    ap.add_argument("--pipeline", type=int, default=4,
                    help="in-flight pipelined requests per connection")
    ap.add_argument("--batch-window", type=int, default=8,
                    help="dispatcher coalescing window (1 disables)")
    ap.add_argument("--lm", action="store_true")
    ap.add_argument("--fleet", action="store_true",
                    help="elastic fleet demo: scale cycle, hot swap, "
                         "kill/heal under traffic")
    ap.add_argument("--groups", type=int, default=2,
                    help="--fleet: starting mesh size")
    ap.add_argument("--peak", type=int, default=8,
                    help="--fleet: scale-cycle peak mesh size")
    args = ap.parse_args()
    if args.fleet:
        serve_fleet(args.requests, groups=args.groups, peak=args.peak)
    elif args.lm:
        serve_lm(args.requests)
    else:
        serve_resnet(args.requests, args.batch, args.clients,
                     args.pipeline, batch_window=args.batch_window)


if __name__ == "__main__":
    main()
