"""Serving driver: network-attached inference service (the paper's mode).

Starts the CRC-framed socket server, provisions the ResNet-18 case study
(or an LM engine with --lm), fires batched client requests at it, and
reports the latency CV telemetry.

  PYTHONPATH=src python -m repro.launch.serve --requests 64
  PYTHONPATH=src python -m repro.launch.serve --lm --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.configs.resnet18 import CONFIG as RESNET
from repro.core import rctc
from repro.models import resnet as rn
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.server import Client, InferenceServer


def serve_resnet(requests: int, batch: int) -> None:
    cfg = RESNET.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params),
                                        batch=batch)
    server = InferenceServer()
    addr = server.start()
    print(f"[serve] listening on {addr}")
    try:
        client = Client(addr)
        print("[serve] provision:", client.provision(image, prog.encode()))
        rng = np.random.RandomState(0)
        t0 = time.perf_counter()
        for _ in range(requests):
            x = rng.rand(batch, cfg.image_size, cfg.image_size, 3) \
                .astype(np.float32)
            out = client.infer(input=x)
        dt = time.perf_counter() - t0
        tel = client.telemetry()
        print(f"[serve] {requests} requests x batch {batch}: "
              f"{requests*batch/dt:.1f} img/s; "
              f"CV={tel.get('cv_percent', 0):.2f}% "
              f"p99={tel.get('p99', 0)*1e3:.2f}ms")
        client.close()
    finally:
        server.stop()


def serve_lm(requests: int) -> None:
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    eng = ServingEngine(cfg, params, max_batch=4, max_seq=128)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, (16,))
                    .astype(np.int32), max_new=8)
            for i in range(requests)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    s = eng.telemetry.summary(warmup=2)
    print(f"[serve-lm] {requests} prompts, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s); decode-step "
          f"CV={s.get('cv_percent', 0):.2f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lm", action="store_true")
    args = ap.parse_args()
    if args.lm:
        serve_lm(args.requests)
    else:
        serve_resnet(args.requests, args.batch)


if __name__ == "__main__":
    main()
