"""Regenerate the generated sections of EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import json
import pathlib
import re

from repro.launch.roofline import RESULTS, analyze, load, markdown_table

REPO = pathlib.Path(__file__).resolve().parents[3]
EXP = REPO / "EXPERIMENTS.md"


def dryrun_table() -> str:
    hdr = ("| arch | shape | mesh | compile s | flops/dev | bytes/dev | "
           "coll/dev | temp GB | args GB |\n" + "|---|" * 9 + "\n")
    rows = []
    for mesh in ("pod256", "pod512"):
        for p in sorted((RESULTS / "dryrun" / mesh).glob("*.json")):
            if "__full" in p.name or "__train_zero1" in p.name:
                continue
            r = json.loads(p.read_text())
            if r.get("skipped"):
                rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | — | — "
                            f"| — | — | — | SKIP ({r['reason']}) |")
                continue
            m = r["memory"]
            rows.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | "
                f"{r['compile_seconds']:.0f} | {r['flops_per_device']:.2e} | "
                f"{r['bytes_per_device']:.2e} | "
                f"{r['collective_bytes_per_device']:.2e} | "
                f"{m['temp_bytes']/1e9:.2f} | "
                f"{m['argument_bytes']/1e9:.2f} |")
    return hdr + "\n".join(rows) + "\n"


def main() -> None:
    text = EXP.read_text()
    roof = markdown_table(load("pod256", include_skips=True))
    dry = dryrun_table()
    text = re.sub(r"<!-- ROOFLINE_TABLE -->",
                  "<!-- ROOFLINE_TABLE -->\n\n" + roof, text, count=1) \
        if "| arch | shape | compute s" not in text else text
    text = re.sub(r"<!-- DRYRUN_TABLE -->",
                  "<!-- DRYRUN_TABLE -->\n\n" + dry, text, count=1) \
        if "| arch | shape | mesh |" not in text else text
    EXP.write_text(text)
    print("EXPERIMENTS.md updated "
          f"({len(roof.splitlines())} roofline rows, "
          f"{len(dry.splitlines())} dry-run rows)")


if __name__ == "__main__":
    main()
