"""Checkpointing on the RIMFS image format — CRC-verified, async, restartable.

A checkpoint IS a RIMFS image (flat, aligned, per-file CRC-32): training
state flattens to named arrays, packs to one blob, and is written atomically
(tmp + rename). ``CheckpointManager`` adds async background saves (compute
continues while the previous step's state serializes — the standard
large-fleet trick), retention, and latest-good discovery with CRC fallback:
a torn/corrupt checkpoint is detected by CRC and the previous one is used —
the node-failure recovery path exercised in tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import json
import pathlib
import threading
from typing import Any, Optional

import numpy as np

import jax

from repro.core import rimfs as rimfs_mod

_SEP = "::"


def _flatten(tree: Any, prefix: str = "") -> dict:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = prefix + jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path, tree: Any, step: int,
                    extra: Optional[dict] = None) -> int:
    """Pack `tree` into a RIMFS image at `path` (atomic)."""
    path = pathlib.Path(path)
    flat = _flatten(tree)
    meta = {"step": int(step), "keys": sorted(flat), "extra": extra or {}}
    flat["__meta__"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    img = rimfs_mod.pack(flat)
    tmp = path.with_suffix(".tmp")
    tmp.write_bytes(img)
    tmp.replace(path)
    return len(img)


def load_checkpoint(path, like: Any) -> tuple:
    """Restore into the structure of `like` (arrays or ShapeDtypeStructs).
    Returns (tree, step, extra). CRC-verifies every array."""
    fs = rimfs_mod.mount_file(path)
    fs.verify()
    meta = json.loads(fs.read("__meta__").tobytes().decode())
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for kpath, leaf in leaves:
        key = jax.tree_util.keystr(kpath)
        arr = fs.read(key)
        out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, meta["step"], meta["extra"]


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def _path(self, step: int) -> pathlib.Path:
        return self.dir / f"ckpt_{step:08d}.rimfs"

    def save(self, tree: Any, step: int, extra: Optional[dict] = None,
             block: bool = False) -> None:
        # snapshot to host BEFORE backgrounding (device buffers may be
        # donated by the next step)
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            save_checkpoint(self._path(step), host_tree, step, extra)
            self._gc()

        self.wait()
        if self.async_save and not block:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            work()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("ckpt_*.rimfs"))
        for p in ckpts[:-self.keep]:
            p.unlink(missing_ok=True)

    def all_steps(self) -> list:
        return sorted(int(p.stem.split("_")[1])
                      for p in self.dir.glob("ckpt_*.rimfs"))

    def restore_latest(self, like: Any) -> Optional[tuple]:
        """Latest checkpoint that passes CRC; corrupt ones are skipped
        (node-failure / torn-write recovery)."""
        self.wait()
        for step in reversed(self.all_steps()):
            try:
                return load_checkpoint(self._path(step), like)
            except Exception:
                continue
        return None
