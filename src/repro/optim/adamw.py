"""AdamW with global-norm clipping; moments sharded like params (ZeRO-style).

Pure JAX (no optax on the box). Moment specs inherit each parameter's logical
axes, so the RBL resolver shards optimizer state exactly like the weights —
on FSDP-sharded params this is ZeRO-3 behaviour for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, is_spec, spec_tree_map


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: Any            # scalar int32
    m: Any               # fp32 tree like params
    v: Any               # fp32 tree like params


def adamw_init_specs(param_specs) -> AdamWState:
    """Spec tree for the optimizer state (materialize via init_params).

    Moment axes rename ``fsdp`` -> ``opt_shard``: under the default rules
    both map to the data axis (ZeRO-3), but the ``train_zero1`` rule set
    replicates params over data while keeping moments sharded (ZeRO-1) —
    the right trade for models whose weights fit per-device, since it
    removes the 2x-params forward/backward all-gather traffic.
    """
    def mom(s: ParamSpec) -> ParamSpec:
        axes = tuple("opt_shard" if a == "fsdp" else a for a in s.axes)
        return ParamSpec(s.shape, "float32", axes, "zeros")
    return AdamWState(
        step=ParamSpec((), "int32", (), "zeros"),
        m=spec_tree_map(mom, param_specs),
        v=spec_tree_map(mom, param_specs),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params,
                 lr: jax.Array):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, AdamWState(step, new_m, new_v), metrics
