from repro.optim.adamw import AdamWConfig, adamw_init_specs, adamw_update  # noqa: F401
from repro.optim.schedules import cosine_warmup  # noqa: F401
