"""INT8 post-training quantization for the RCB deployment path.

The paper deploys ResNet-18 with INT8 inputs (§3.4). We reproduce the flow:
activation scales come from a calibration run *through the runtime itself*
(the eager executor probes every buffer of the fp32 RCB program), weights
are per-output-channel symmetric INT8, convolutions accumulate in INT32 and
requantize with fused ``x_scale * w_scale_c`` vectors.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.resnet18 import ResNetConfig
from repro.core import rbl as rbl_mod
from repro.core import rctc, rimfs as rimfs_mod
from repro.core.executor import Executor
from repro.core.rcb import Op


def per_channel_scales(w: np.ndarray, axis: int = -1) -> np.ndarray:
    """Symmetric per-output-channel scales for HWIO conv weights."""
    reduce_axes = tuple(i for i in range(w.ndim) if i != (axis % w.ndim))
    amax = np.max(np.abs(w), axis=reduce_axes)
    return np.maximum(amax, 1e-8) / 127.0


def quantize_weight(w: np.ndarray, scales: np.ndarray) -> np.ndarray:
    q = np.round(w / scales.reshape((1,) * (w.ndim - 1) + (-1,)))
    return np.clip(q, -127, 127).astype(np.int8)


def calibrate(cfg: ResNetConfig, folded: dict, calib_x: np.ndarray) -> dict:
    """Run the fp32 RCB program through the eager executor and record
    per-symbol abs-max (the runtime IS the calibration harness)."""
    prog, image = rctc.compile_resnet18(cfg, folded,
                                        batch=calib_x.shape[0])
    fs = rimfs_mod.mount(image)
    bound = rbl_mod.bind(prog, rimfs=fs,
                         inputs={"input": calib_x.astype(np.float32)})
    probe: dict = {}
    Executor().run(bound, probe=probe)
    return probe


def quantize_resnet(cfg: ResNetConfig, folded: dict,
                    calib_x: np.ndarray) -> dict:
    """Produce the INT8 pack consumed by rctc.compile_resnet18(int8=...)."""
    probe = calibrate(cfg, folded, calib_x)
    prog, _ = rctc.compile_resnet18(cfg, folded, batch=calib_x.shape[0])

    weights: dict[str, np.ndarray] = {}
    requant: dict[str, np.ndarray] = {}
    act_scales: dict[str, float] = {}
    for op in prog.ops():
        if op.op != Op.CONV2D:
            continue
        x_sym, w_key = op.srcs[0], op.srcs[1]
        sx = max(probe.get(x_sym, 1.0), 1e-8) / 127.0
        w = np.asarray(folded[w_key])
        sw = per_channel_scales(w)
        weights[w_key] = quantize_weight(w, sw)
        requant[w_key] = (sx * sw).astype(np.float32)
        act_scales[w_key] = float(sx)
    return {"weights": weights, "requant": requant,
            "act_scales": act_scales}


def top1_agreement(p_fp: np.ndarray, p_q: np.ndarray) -> float:
    return float(np.mean(np.argmax(p_fp, -1) == np.argmax(p_q, -1)))
