"""Runtime Hardware Abstraction Layer — the ``hal_driver_t`` vtable.

The paper isolates all hardware heterogeneity behind a C struct of function
pointers covering four primitive families (register ops, DMA, sync, cache
coherency). The TPU adaptation keeps the strict boundary — the executor only
ever calls vtable slots — and re-bases the primitives on the XLA execution
model:

  register ops       -> buffer-table ops (alloc/free/bind_const)
  initiate/wait DMA  -> host<->device transfers (device_put / device_get)
  dispatch           -> compute-op dispatch (per-op eager, or traced-fused)
  poll/fence         -> block_until_ready barriers
  cache flush/inval  -> buffer donation hints (XLA owns coherency; donation
                        is the user-visible control point on TPU)

Two drivers ship:
  * ``EagerDriver``  — dispatches every op as its own device executable with
    a host sync in between: the OS-mediated analogue (per-op fixed cost,
    like Vitis AI's ioctl-per-DMA path).
  * ``TraceDriver``  — records the same calls symbolically so the executor
    can stage one fused XLA program per RCB program: the baremetal analogue
    (one dispatch per step, zero host round-trips inside).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oplib
from repro.core.rcb import Op


@dataclasses.dataclass(frozen=True)
class DeviceConstants:
    """Roofline constants for the target device (TPU v5e defaults)."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12          # FLOP/s per chip
    hbm_bandwidth: float = 819e9             # B/s per chip
    ici_link_bandwidth: float = 50e9         # B/s per link
    hbm_bytes: float = 16e9


@dataclasses.dataclass
class HalDriver:
    """The vtable. Integrating a new backend == filling these slots."""
    name: str
    alloc: Callable[[tuple, str], Any]
    free: Callable[[Any], None]
    bind_const: Callable[[Any], Any]
    initiate_dma: Callable[[Any, str], Any]     # (host_buf, direction) -> buf
    wait_dma: Callable[[Any], Any]
    dispatch_compute: Callable[[Op, list, dict], Any]
    collective: Callable[[str, Any, dict], Any]
    fence: Callable[[list], None]
    poll: Callable[[Any], bool]
    donate: Callable[[Any], Any]
    constants: DeviceConstants = DeviceConstants()
    stats: dict = dataclasses.field(default_factory=dict)
    # Optional compiled-dispatch slot (core/linker.py): resolve one opcode
    # to a specialized positional handler ``fn(*srcs) -> out`` ONCE at link
    # time, so the hot loop pays no table lookup / decode / sync per op.
    # ``None`` means the backend has no compiled path; the linker then falls
    # back to per-op ``dispatch_compute``.
    link_compute: Optional[Callable[[Op, dict], Callable]] = None

    def _count(self, key: str, n: int = 1):
        self.stats[key] = self.stats.get(key, 0) + n


# ---------------------------------------------------------------------------
# Eager driver (OS-mediated analogue): one device round-trip per primitive.
# ---------------------------------------------------------------------------

def make_eager_driver(device: Optional[jax.Device] = None) -> HalDriver:
    device = device or jax.devices()[0]

    def alloc(shape, dtype):
        d._count("alloc")
        return jax.device_put(jnp.zeros(shape, jnp.dtype(dtype)), device)

    def free(buf):
        d._count("free")
        if hasattr(buf, "delete"):
            try:
                buf.delete()
            except Exception:
                pass

    def bind_const(value):
        return jax.device_put(jnp.asarray(value), device)

    def initiate_dma(host_buf, direction):
        d._count("dma")
        if direction == "d2h":
            return np.asarray(host_buf)            # device -> host pull
        return jax.device_put(jnp.asarray(host_buf), device)

    def wait_dma(buf):
        d._count("dma_wait")
        return jax.block_until_ready(buf) if hasattr(buf, "block_until_ready") \
            else buf

    def dispatch_compute(op, srcs, attrs):
        d._count("dispatch")
        out = oplib.compute(op, srcs, attrs)
        return jax.block_until_ready(out)          # per-op host sync

    def collective(kind, x, attrs):
        d._count("collective")
        return x                                    # single-device eager

    def fence(bufs):
        d._count("fence")
        for b in bufs:
            if hasattr(b, "block_until_ready"):
                b.block_until_ready()

    def poll(buf):
        d._count("poll")
        return True

    def donate(buf):
        return buf

    def link_compute(op, attrs):
        # Compiled dispatch: one jitted executable per (op, attrs) site,
        # staged once at link time.  Calls hit XLA's cached fast path and
        # dispatch asynchronously — the per-op host sync of the interpreted
        # eager path is replaced by syncs at FENCE ops / program exit (the
        # paper's move: per-op fixed cost paid once per stream).
        fn = oplib.lookup(op)
        return jax.jit(lambda *srcs: fn(srcs, attrs))

    d = HalDriver("eager_cpu", alloc, free, bind_const, initiate_dma,
                  wait_dma, dispatch_compute, collective, fence, poll, donate,
                  link_compute=link_compute)
    return d


# ---------------------------------------------------------------------------
# Trace driver (baremetal analogue): records ops symbolically for fusion.
# ---------------------------------------------------------------------------

def make_trace_driver() -> HalDriver:
    """Dispatch slots operate on tracers; no device sync anywhere. The
    executor stages the whole RCB program through this driver inside one
    ``jax.jit``, yielding a single fused executable."""

    def alloc(shape, dtype):
        return jnp.zeros(shape, jnp.dtype(dtype))

    def free(buf):
        return None

    def bind_const(value):
        return jnp.asarray(value)

    def initiate_dma(host_buf, direction):
        return jnp.asarray(host_buf)

    def wait_dma(buf):
        return buf                                  # no sync under trace

    def dispatch_compute(op, srcs, attrs):
        d._count("dispatch")
        return oplib.compute(op, srcs, attrs)       # stays symbolic

    def collective(kind, x, attrs):
        return x

    def fence(bufs):
        return None

    def poll(buf):
        return True

    def donate(buf):
        return buf

    def link_compute(op, attrs):
        # Under trace everything is symbolic already; the specialized
        # handler is just the pre-resolved oplib entry (no jit, no sync).
        fn = oplib.lookup(op)
        return lambda *srcs: fn(srcs, attrs)

    d = HalDriver("trace_xla", alloc, free, bind_const, initiate_dma,
                  wait_dma, dispatch_compute, collective, fence, poll, donate,
                  link_compute=link_compute)
    return d
