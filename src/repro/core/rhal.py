"""Runtime Hardware Abstraction Layer — the ``hal_driver_t`` vtable.

The paper isolates all hardware heterogeneity behind a C struct of function
pointers covering four primitive families (register ops, DMA, sync, cache
coherency). The TPU adaptation keeps the strict boundary — the executor only
ever calls vtable slots — and re-bases the primitives on the XLA execution
model:

  register ops       -> buffer-table ops (alloc/free/bind_const)
  initiate/wait DMA  -> host<->device transfers (device_put / device_get)
  dispatch           -> compute-op dispatch (per-op eager, or traced-fused)
  poll/fence         -> block_until_ready barriers
  cache flush/inval  -> buffer donation hints (XLA owns coherency; donation
                        is the user-visible control point on TPU)

Two drivers ship:
  * ``EagerDriver``  — dispatches every op as its own device executable with
    a host sync in between: the OS-mediated analogue (per-op fixed cost,
    like Vitis AI's ioctl-per-DMA path).
  * ``TraceDriver``  — records the same calls symbolically so the executor
    can stage one fused XLA program per RCB program: the baremetal analogue
    (one dispatch per step, zero host round-trips inside).

Two memory/transfer extensions back the compiled data-movement path
(DESIGN.md §6):

  * ``DeviceArena`` — one up-front device slab suballocated by offset with
    RIMFS-matching 128 B alignment. On TPU/XLA the slab is *modeled* (XLA
    owns physical device memory), but the arena reproduces the paper's
    deterministic offset discipline: the linker's residency plan, the
    high-water mark, fragmentation and the free-list are all real and
    testable, and on a raw-pointer backend the same offsets would index an
    actual slab.
  * split-phase DMA — ``dma_async`` returns a ``DmaTicket`` immediately;
    ``dma_wait`` redeems it. Issue and wait are separate vtable slots so
    the linker can hoist issues ahead of use (prefetch H2D of op *k+1*
    under op *k*'s compute) and sink waits to the drain point (D2H of op
    *k−1* completes under op *k*). The blocking ``initiate_dma``/
    ``wait_dma`` pair remains the interpreted per-op baseline.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oplib
from repro.core.integrity import IntegrityConfig, IntegrityError, payload_crc
from repro.core.rcb import Op

ARENA_ALIGN = 128                 # matches rimfs.ALIGN: one DMA lane quantum
DEFAULT_ARENA_BYTES = 1 << 30     # modeled slab size for the eager driver


@dataclasses.dataclass(frozen=True)
class DeviceConstants:
    """Roofline constants for the target device (TPU v5e defaults)."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12          # FLOP/s per chip
    hbm_bandwidth: float = 819e9             # B/s per chip
    ici_link_bandwidth: float = 50e9         # B/s per link
    hbm_bytes: float = 16e9


class ArenaError(RuntimeError):
    pass


class DmaError(RuntimeError):
    """Split-phase DMA protocol violation (e.g. a ticket redeemed twice)."""


class TileFailure(RuntimeError):
    """A tile group's hardware went away mid-program (fault injection /
    elasticity). Raised by every vtable slot of a killed ``TileGroup``."""


class DeviceArena:
    """Offset-based suballocator over one up-front device slab.

    First-fit over a sorted free-list with neighbour coalescing on free;
    every range is aligned to ``align`` (128 B — RIMFS lane width). With
    ``debug=True`` every alloc/free re-verifies the full invariant set: live
    ranges pairwise disjoint, live and free ranges disjoint, everything
    aligned and in-bounds.
    """

    def __init__(self, capacity: int = DEFAULT_ARENA_BYTES,
                 align: int = ARENA_ALIGN, debug: bool = False):
        if capacity <= 0 or capacity % align:
            raise ArenaError(f"capacity {capacity} not a multiple of {align}")
        self.capacity = capacity
        self.align = align
        self.debug = debug
        self._free: list[tuple[int, int]] = [(0, capacity)]  # (offset, size)
        self._live: dict[int, int] = {}                      # offset -> size
        self.bytes_in_use = 0
        self.high_water = 0
        self.n_allocs = 0
        self.poisoned = False          # quarantined after a watchdog kill

    # ------------------------------------------------------------------ api
    def _round(self, nbytes: int) -> int:
        nbytes = max(1, int(nbytes))
        return (nbytes + self.align - 1) // self.align * self.align

    def quarantine(self) -> None:
        """Poison the arena: a hung/killed owner may have left any live
        range half-written, so no range is handed out again until the
        pinned contents are re-validated against RIMFS CRCs
        (``TileMesh.revive``) — ``alloc`` raises until then."""
        self.poisoned = True

    def clear_quarantine(self) -> None:
        self.poisoned = False

    def alloc(self, nbytes: int) -> int:
        """Reserve an aligned range; returns its slab offset."""
        if self.poisoned:
            # raised as TileFailure so the stage-re-queue machinery
            # treats a quarantined arena exactly like the dead group
            # that owns it (failover to a survivor, not a hard error)
            raise TileFailure(
                "arena quarantined: owner was preempted as hung — "
                "re-validate resident contents before reuse")
        size = self._round(nbytes)
        for i, (off, avail) in enumerate(self._free):
            if avail >= size:
                if avail == size:
                    self._free.pop(i)
                else:
                    self._free[i] = (off + size, avail - size)
                self._live[off] = size
                self.bytes_in_use += size
                self.high_water = max(self.high_water, self.bytes_in_use)
                self.n_allocs += 1
                if self.debug:
                    self.check()
                return off
        raise ArenaError(
            f"arena exhausted: need {size}B, in_use={self.bytes_in_use}B "
            f"of {self.capacity}B ({len(self._free)} free ranges)")

    def free(self, offset: int) -> None:
        """Return a range to the free-list (coalescing with neighbours)."""
        size = self._live.pop(offset, None)
        if size is None:
            raise ArenaError(f"free of unallocated offset {offset}")
        self.bytes_in_use -= size
        i = bisect.bisect_left(self._free, (offset, 0))
        # coalesce right
        if i < len(self._free) and offset + size == self._free[i][0]:
            size += self._free[i][1]
            self._free.pop(i)
        # coalesce left
        if i > 0 and self._free[i - 1][0] + self._free[i - 1][1] == offset:
            offset, size = (self._free[i - 1][0],
                            self._free[i - 1][1] + size)
            self._free[i - 1] = (offset, size)
        else:
            self._free.insert(i, (offset, size))
        if self.debug:
            self.check()

    def live_ranges(self) -> list:
        return sorted((o, s) for o, s in self._live.items())

    def check(self) -> None:
        """Assert the full disjointness/alignment invariant set."""
        ranges = ([(o, s, "live") for o, s in self._live.items()]
                  + [(o, s, "free") for o, s in self._free])
        ranges.sort()
        prev_end, prev_kind = 0, None
        covered = 0
        for off, size, kind in ranges:
            if off % self.align or size % self.align:
                raise ArenaError(f"unaligned {kind} range ({off}, {size})")
            if off < prev_end:
                raise ArenaError(
                    f"{kind} range at {off} overlaps previous "
                    f"{prev_kind} range ending at {prev_end}")
            prev_end, prev_kind = off + size, kind
            covered += size
        if prev_end > self.capacity or covered != self.capacity:
            raise ArenaError("arena ranges do not tile the slab")

    def reset(self) -> None:
        self._free = [(0, self.capacity)]
        self._live.clear()
        self.bytes_in_use = 0


@dataclasses.dataclass
class DmaTicket:
    """Split-phase transfer handle: issued by ``dma_async``, redeemed by
    ``dma_wait``. ``prefetched`` marks issues the linker hoisted ahead of
    the consuming op (the overlap-eligible bytes telemetry counts).
    ``redeemed`` is flipped by the first ``dma_wait`` — a second redemption
    raises ``DmaError`` (on a raw-pointer backend the descriptor is recycled
    at wait time, so a double wait would observe another transfer's state).

    Integrity plane (DESIGN.md §11): ``crc`` is the CRC-32 of the source
    payload stamped at ISSUE time, before the engine touches the bytes;
    ``src`` retains the source buffer so a mismatch at redeem can re-issue
    the transfer in place (bounded by the driver's
    ``integrity.dma_retries``) before escalating to ``IntegrityError``.
    ``crc is None`` marks an unverifiable transfer (d2h pulls, symbolic
    trace tickets) — those redeem unchecked; device-side corruption is
    instead caught by RIMFS CRC re-validation.
    """
    buf: Any
    direction: str
    nbytes: int
    prefetched: bool = False
    redeemed: bool = False
    crc: Optional[int] = None
    src: Any = None
    retries: int = 0

    def redeem(self) -> None:
        """Mark redemption; exactly-once is enforced, not assumed."""
        if self.redeemed:
            raise DmaError(
                f"DmaTicket({self.direction}, {self.nbytes}B) redeemed "
                f"twice — dma_wait already consumed this descriptor")
        self.redeemed = True


@dataclasses.dataclass
class HalDriver:
    """The vtable. Integrating a new backend == filling these slots."""
    name: str
    alloc: Callable[[tuple, str], Any]
    free: Callable[[Any], None]
    bind_const: Callable[[Any], Any]
    initiate_dma: Callable[[Any, str], Any]     # (host_buf, direction) -> buf
    wait_dma: Callable[[Any], Any]
    dispatch_compute: Callable[[Op, list, dict], Any]
    collective: Callable[[str, Any, dict], Any]
    fence: Callable[[list], None]
    poll: Callable[[Any], bool]
    donate: Callable[[Any], Any]
    constants: DeviceConstants = DeviceConstants()
    stats: dict = dataclasses.field(default_factory=dict)
    # Optional compiled-dispatch slot (core/linker.py): resolve one opcode
    # to a specialized positional handler ``fn(*srcs) -> out`` ONCE at link
    # time, so the hot loop pays no table lookup / decode / sync per op.
    # ``None`` means the backend has no compiled path; the linker then falls
    # back to per-op ``dispatch_compute``.
    link_compute: Optional[Callable[[Op, dict], Callable]] = None
    # Optional split-phase DMA slots (compiled data-movement path). A
    # backend filling both lets the linker pipeline transfers; ``None``
    # falls back to the blocking initiate_dma/wait_dma pair.
    dma_async: Optional[Callable[[Any, str], DmaTicket]] = None
    dma_wait: Optional[Callable[[DmaTicket], Any]] = None
    # Optional batched issue: one engine call for a whole transfer stream
    # (the prefetch prologue, a resident-image upload). Falls back to
    # per-buffer dma_async when absent.
    dma_async_batch: Optional[Callable[[list, str], list]] = None
    # Optional device arena backing alloc/free and RIMFS residency.
    arena: Optional[DeviceArena] = None
    # Integrity policy: DMA payload CRC stamping/verification + bounded
    # retry (DESIGN.md §11). Shared by reference with the closures the
    # factory builds, so flipping ``integrity.enabled`` at runtime (the
    # CRC-on/off benchmark row) takes effect immediately.
    integrity: IntegrityConfig = dataclasses.field(
        default_factory=IntegrityConfig)
    # Per-driver compiled-handler memo (core/linker.py): identical
    # (opcode, attrs) sites across links — e.g. every tile of a
    # partitioned program — share ONE specialized handler instead of
    # re-resolving/re-staging per link.
    link_cache: dict = dataclasses.field(default_factory=dict)

    def _count(self, key: str, n: int = 1):
        self.stats[key] = self.stats.get(key, 0) + n


def _on_device(buf, device) -> bool:
    """True iff a jax Array is wholly resident on ``device``."""
    try:
        return buf.devices() == {device}
    except Exception:
        return False


def _nbytes_of(shape, dtype) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n * np.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# Eager driver (OS-mediated analogue): one device round-trip per primitive.
# ---------------------------------------------------------------------------

def make_eager_driver(device: Optional[jax.Device] = None,
                      arena_bytes: int = DEFAULT_ARENA_BYTES,
                      debug_arena: bool = False) -> HalDriver:
    device = device or jax.devices()[0]
    arena = DeviceArena(arena_bytes, debug=debug_arena)
    # id(buf) -> arena offset for arena-backed allocations. An id is only
    # recorded while its buffer is registered, and re-allocation overwrites
    # the entry, so recycled ids cannot alias a stale offset.
    offsets: dict[int, int] = {}

    def _register(buf, nbytes):
        offsets[id(buf)] = arena.alloc(nbytes)
        return buf

    def alloc(shape, dtype):
        d._count("alloc")
        buf = jax.device_put(jnp.zeros(shape, jnp.dtype(dtype)), device)
        return _register(buf, _nbytes_of(shape, dtype))

    def free(buf):
        d._count("free")
        off = offsets.pop(id(buf), None)
        if off is not None:
            arena.free(off)         # offset really returns to the free-list
        if hasattr(buf, "delete"):
            try:
                buf.delete()
            except Exception:
                pass

    def bind_const(value):
        return jax.device_put(jnp.asarray(value), device)

    def initiate_dma(host_buf, direction):
        d._count("dma")
        d._count("dma_bytes", int(getattr(host_buf, "nbytes", 0)))
        if direction == "d2h":
            return np.asarray(host_buf)            # device -> host pull
        return jax.device_put(jnp.asarray(host_buf), device)

    def wait_dma(buf):
        d._count("dma_wait")
        return jax.block_until_ready(buf) if hasattr(buf, "block_until_ready") \
            else buf

    def _stamp(ticket, host_buf):
        """Stamp the source payload's CRC-32 onto the ticket at ISSUE
        time (before any engine touch) and retain the source buffer for
        in-place retry. d2h is never stamped: the reference bytes only
        exist device-side, and reading them at issue would force the
        host sync split-phase DMA exists to avoid — device-side
        corruption is covered by RIMFS CRC re-validation instead."""
        if d.integrity.enabled and ticket.direction != "d2h":
            ticket.crc = payload_crc(host_buf)
            ticket.src = host_buf
        return ticket

    def dma_async(host_buf, direction, prefetched=False):
        """Issue half: returns a ticket immediately, no host sync.

        h2d/d2d enqueue a device_put (asynchronous under XLA); d2h starts
        the device->host copy in the background. Completion is observed at
        ``dma_wait`` (d2h materialization) or, for device-side consumers,
        by XLA data-flow ordering — the host blocks only at FENCE/exit.
        """
        nbytes = int(getattr(host_buf, "nbytes", 0))
        d._count("dma_async")
        d._count("dma_bytes", nbytes)
        if prefetched:
            d._count("dma_overlapped_bytes", nbytes)
        if direction == "d2h":
            if hasattr(host_buf, "copy_to_host_async"):
                host_buf.copy_to_host_async()
            return DmaTicket(host_buf, "d2h", nbytes, prefetched)
        if direction == "d2d" and isinstance(host_buf, jax.Array) \
                and _on_device(host_buf, device):
            # modeled inter-tile hop: the source already lives on this
            # physical device, so the "transfer" is pure accounting — a
            # device_put here is host-side overhead per cut edge that a
            # zero-copy interconnect would never pay. Bytes/stats are
            # still counted above; cross-device or host-sourced d2d
            # still stages through device_put below.
            return _stamp(DmaTicket(host_buf, direction, nbytes,
                                    prefetched), host_buf)
        buf = jax.device_put(jnp.asarray(host_buf), device)
        return _stamp(DmaTicket(buf, direction, nbytes, prefetched),
                      host_buf)

    def dma_wait_(ticket):
        d._count("dma_ticket_wait")
        ticket.redeem()                            # double-wait raises
        if ticket.direction == "d2h":
            return np.asarray(ticket.buf)          # materialize on host
        if ticket.crc is None or not d.integrity.enabled:
            return ticket.buf                      # ordered by data flow
        # endpoint verification: delivered payload vs issue-time CRC,
        # with a bounded in-place re-issue from the retained source
        # before escalating (DESIGN.md §11)
        d._count("dma_crc_checked")
        buf = ticket.buf
        for attempt in range(d.integrity.dma_retries + 1):
            if payload_crc(buf) == ticket.crc:
                if attempt:
                    ticket.retries = attempt
                    d._count("dma_retry_recovered")
                ticket.buf = buf
                return buf
            d._count("dma_crc_mismatch")
            if attempt >= d.integrity.dma_retries:
                break
            d._count("dma_retry")
            buf = jax.device_put(jnp.asarray(ticket.src), device)
        raise IntegrityError(
            f"DMA payload CRC mismatch ({ticket.direction}, "
            f"{ticket.nbytes}B) after {d.integrity.dma_retries} "
            f"in-place retries", kind="dma_crc")

    def dma_async_batch(host_bufs, direction, prefetched=False):
        """One engine call for a whole transfer stream: n buffers move
        under a single descriptor (paper §5.3 batching), paying the
        issue fixed cost once instead of once per block."""
        sizes = [int(getattr(h, "nbytes", 0)) for h in host_bufs]
        d._count("dma_async", len(host_bufs))
        d._count("dma_batch")
        d._count("dma_bytes", sum(sizes))
        if prefetched:
            d._count("dma_overlapped_bytes", sum(sizes))
        if direction == "d2h":
            for h in host_bufs:
                if hasattr(h, "copy_to_host_async"):
                    h.copy_to_host_async()
            return [DmaTicket(h, "d2h", nb, prefetched)
                    for h, nb in zip(host_bufs, sizes)]
        bufs = jax.device_put(list(host_bufs), device)
        return [_stamp(DmaTicket(b, direction, nb, prefetched), h)
                for b, h, nb in zip(bufs, host_bufs, sizes)]

    def dispatch_compute(op, srcs, attrs):
        d._count("dispatch")
        out = oplib.compute(op, srcs, attrs)
        return jax.block_until_ready(out)          # per-op host sync

    def collective(kind, x, attrs):
        d._count("collective")
        return x                                    # single-device eager

    def fence(bufs):
        d._count("fence")
        for b in bufs:
            if hasattr(b, "block_until_ready"):
                b.block_until_ready()

    def poll(buf):
        d._count("poll")
        return True

    def donate(buf):
        return buf

    def link_compute(op, attrs):
        # Compiled dispatch: one jitted executable per (op, attrs) site,
        # staged once at link time.  Calls hit XLA's cached fast path and
        # dispatch asynchronously — the per-op host sync of the interpreted
        # eager path is replaced by syncs at FENCE ops / program exit (the
        # paper's move: per-op fixed cost paid once per stream).
        if op in oplib.OP_KERNELS:
            # Kernel opcodes resolve through the registry so the linked
            # handler picks up autotuned block params and the pallas→ref
            # fallback ladder (kernels/registry.py); the registry's own
            # wrappers are already jitted.
            from repro.kernels import registry
            return registry.linked_handler(oplib.OP_KERNELS[op], attrs)
        fn = oplib.lookup(op)
        return jax.jit(lambda *srcs: fn(srcs, attrs))

    d = HalDriver("eager_cpu", alloc, free, bind_const, initiate_dma,
                  wait_dma, dispatch_compute, collective, fence, poll, donate,
                  link_compute=link_compute, dma_async=dma_async,
                  dma_wait=dma_wait_, dma_async_batch=dma_async_batch,
                  arena=arena)
    return d


# ---------------------------------------------------------------------------
# Trace driver (baremetal analogue): records ops symbolically for fusion.
# ---------------------------------------------------------------------------

def make_trace_driver() -> HalDriver:
    """Dispatch slots operate on tracers; no device sync anywhere. The
    executor stages the whole RCB program through this driver inside one
    ``jax.jit``, yielding a single fused executable."""

    def alloc(shape, dtype):
        return jnp.zeros(shape, jnp.dtype(dtype))

    def free(buf):
        return None

    def bind_const(value):
        return jnp.asarray(value)

    def initiate_dma(host_buf, direction):
        return jnp.asarray(host_buf)

    def wait_dma(buf):
        return buf                                  # no sync under trace

    def dma_async(host_buf, direction, prefetched=False):
        # symbolic ticket: the staged program IS the overlap (XLA schedules
        # transfers and compute from one dataflow graph)
        return DmaTicket(jnp.asarray(host_buf), direction, 0, prefetched)

    def dma_wait_(ticket):
        ticket.redeem()                            # double-wait raises
        return ticket.buf

    def dma_async_batch(host_bufs, direction, prefetched=False):
        return [DmaTicket(jnp.asarray(h), direction, 0, prefetched)
                for h in host_bufs]

    def dispatch_compute(op, srcs, attrs):
        d._count("dispatch")
        return oplib.compute(op, srcs, attrs)       # stays symbolic

    def collective(kind, x, attrs):
        return x

    def fence(bufs):
        return None

    def poll(buf):
        return True

    def donate(buf):
        return buf

    def link_compute(op, attrs):
        # Under trace everything is symbolic already; the specialized
        # handler is just the pre-resolved oplib entry (no jit, no sync).
        if op in oplib.OP_KERNELS:
            from repro.kernels import registry
            return registry.linked_handler(oplib.OP_KERNELS[op], attrs)
        fn = oplib.lookup(op)
        return lambda *srcs: fn(srcs, attrs)

    d = HalDriver("trace_xla", alloc, free, bind_const, initiate_dma,
                  wait_dma, dispatch_compute, collective, fence, poll, donate,
                  link_compute=link_compute, dma_async=dma_async,
                  dma_wait=dma_wait_, dma_async_batch=dma_async_batch)
    return d


# ---------------------------------------------------------------------------
# Tile mesh (multi-tile-group execution, DESIGN.md §7)
# ---------------------------------------------------------------------------

_GUARDED_SLOTS = ("alloc", "free", "bind_const", "initiate_dma", "wait_dma",
                  "dispatch_compute", "collective", "fence", "poll",
                  "dma_async", "dma_wait", "dma_async_batch")


@dataclasses.dataclass
class TileGroup:
    """One tile group: an independent HalDriver (own arena, own DMA
    engines, own stats) plus a liveness flag the mesh's fault model flips.
    """
    gid: int
    driver: HalDriver
    alive: bool = True


def _guard_group(group: TileGroup) -> None:
    """Wrap every vtable slot of the group's driver so a killed group
    raises ``TileFailure`` at the next hardware touch — the modeled
    analogue of a tile array segment dropping off the interconnect.
    The liveness flag is read at CALL time, so programs linked before the
    failure (including their per-site compiled handlers) fail too."""
    driver = group.driver

    def guard(fn):
        def wrapped(*args, **kwargs):
            if not group.alive:
                raise TileFailure(f"tile group {group.gid} is down")
            return fn(*args, **kwargs)
        return wrapped

    for slot in _GUARDED_SLOTS:
        fn = getattr(driver, slot)
        if fn is not None:
            setattr(driver, slot, guard(fn))
    link_compute = driver.link_compute
    if link_compute is not None:
        driver.link_compute = lambda op, attrs: guard(link_compute(op,
                                                                   attrs))


class TileMesh:
    """N modeled tile-group drivers with inter-tile split-phase streams.

    The paper runs ResNet-18 over a 28-tile AIE array with tile groups
    pipelining layer stages; here each group is an independent RHAL driver
    (own ``DeviceArena``, own DMA counters) and cut-edge activations move
    between groups through split-phase ``DmaTicket`` streams — issued the
    moment the producer stage completes, redeemed when the consumer stage
    starts, so the transfer rides under whatever executes in between.
    ``edge_stats`` accounts movement bytes per (src, dst) cut edge.
    """

    def __init__(self, n_groups: int, driver_factory=None,
                 arena_bytes: int = DEFAULT_ARENA_BYTES):
        if n_groups < 1:
            raise ValueError(f"need >= 1 tile group, got {n_groups}")
        factory = driver_factory or (
            lambda gid: make_eager_driver(arena_bytes=arena_bytes))
        self._factory = factory        # retained for partial reshapes
        self.groups: list[TileGroup] = []
        for gid in range(n_groups):
            group = TileGroup(gid, factory(gid))
            _guard_group(group)
            self.groups.append(group)
        # (src_gid, dst_gid) -> {"bytes", "transfers", "syms"}
        self.edge_stats: dict[tuple, dict] = {}
        # gid of the group currently executing a partitioned stage.
        # Written only by the dispatcher thread (partition.execute), read
        # by the watchdog to target a hung dispatch's group — a benign
        # single-writer race by design.
        self.active_gid: Optional[int] = None

    # ----------------------------------------------------------------- api
    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def gids(self) -> range:
        return range(len(self.groups))

    def group(self, gid: int) -> TileGroup:
        return self.groups[gid]

    def alive(self, gid: int) -> bool:
        return self.groups[gid].alive

    def kill(self, gid: int) -> None:
        """Fault injection / watchdog preemption: the group fails at its
        next hardware touch, and its arena is QUARANTINED — a killed
        owner may have left any buffer half-written, so no range is
        handed out again until ``revive`` re-validates the pinned
        contents against RIMFS CRCs."""
        group = self.groups[gid]
        group.alive = False
        if group.driver.arena is not None:
            group.driver.arena.quarantine()

    def revive(self, gid: int, rimfs=None) -> None:
        """Bring a killed group back. With ``rimfs`` given, every file
        the group's driver holds resident is CRC-compared against the
        image before the arena's quarantine lifts — a corrupted weight
        copy raises ``IntegrityError`` instead of silently serving.
        Without ``rimfs`` (no residency to check) the quarantine lifts
        unverified — fault-injection tests own that risk explicitly."""
        group = self.groups[gid]
        arena = group.driver.arena
        if arena is not None and arena.poisoned:
            if rimfs is not None:
                entry = rimfs._resident.get(id(group.driver))
                ri = entry[1] if entry is not None \
                    and entry[0]() is group.driver else None
                if ri is not None and not ri.revalidate():
                    raise IntegrityError(
                        f"tile group {gid}: resident weights fail CRC "
                        f"re-validation — arena stays quarantined",
                        kind="residency_crc")
            arena.clear_quarantine()
        group.alive = True

    def spawn_replacement(self, gid: int) -> TileGroup:
        """Build (but do NOT install) a fresh guarded tile group for slot
        ``gid`` — the expensive half of a *partial reshape*. The caller
        binds / pins / links against the new group's driver off the
        dispatcher thread, then splices it in with ``install_group``
        between requests. The incumbent group keeps serving (or keeps
        failing over) untouched until the splice."""
        group = TileGroup(gid, self._factory(gid))
        _guard_group(group)
        return group

    def install_group(self, group: TileGroup) -> TileGroup:
        """Splice a replacement group into its slot, returning the
        incumbent. O(1) pointer swap — the partial-reshape analogue of
        the whole-mesh flip, intended to run as a dispatcher control op
        so no stage is mid-flight across the swap. Surviving groups'
        drivers (and their pinned weights and DMA counters) are not
        touched."""
        if not (0 <= group.gid < len(self.groups)):
            raise ValueError(f"group gid {group.gid} outside mesh "
                             f"[0, {len(self.groups)})")
        old = self.groups[group.gid]
        self.groups[group.gid] = group
        return old

    @property
    def primary(self) -> HalDriver:
        """First live group's driver (weight residency / serving anchor)."""
        for g in self.groups:
            if g.alive:
                return g.driver
        raise TileFailure("no live tile group in mesh")

    def stream(self, sym: str, buf, src_gid: int, dst_gid: int):
        """Issue one cut-edge transfer src->dst, split-phase.

        Returns a ``DmaTicket`` the consumer group redeems (``dma_wait``)
        when its stage starts — or the transferred buffer directly when
        the destination driver has no async DMA slots (blocking fallback).
        Movement bytes are accounted per directed edge either way.
        """
        driver = self.groups[dst_gid].driver
        if driver.dma_async is not None:
            out = driver.dma_async(buf, "d2d", prefetched=True)
        else:
            out = driver.wait_dma(driver.initiate_dma(buf, "d2d"))
        # account only issues that actually went out (a dead destination
        # raises above — a phantom transfer must not inflate the edge)
        st = self.edge_stats.setdefault(
            (src_gid, dst_gid), {"bytes": 0, "transfers": 0, "syms": set()})
        st["bytes"] += int(getattr(buf, "nbytes", 0))
        st["transfers"] += 1
        st["syms"].add(sym)
        return out

    def moved_bytes(self) -> int:
        return sum(st["bytes"] for st in self.edge_stats.values())
