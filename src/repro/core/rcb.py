"""Runtime Control Blocks — the paper's "Control as Data" representation.

An RCB is *not* executable code: it is a binary data structure holding a
linear sequence of low-level operations (register-write analogues, DMA
triggers, compute dispatches, fences) that encode the complete execution
semantics of an ML workload. A generic engine (core/executor.py) runs RCBs
through the RHAL vtable without knowing anything about the model.

Faithfulness to the paper:
  * RCBs are hardware-independent; tensor references are *symbolic* IDs
    resolved by the Runtime Binding Layer at load time (never raw pointers).
  * Each RCB has a Header (type / size / dependency info) and an Operation
    Payload (structured op sequence).
  * RCBs serialize to a flat binary format with CRC-32 integrity (the same
    IEEE 0x04C11DB7 polynomial the paper uses on its network messages), so
    a model really is provisioned as *data* over the wire.

TPU adaptation (see DESIGN.md §2): the op vocabulary is re-based on the XLA
execution model — buffer ops, fused-compute dispatches and collectives
replace AIE CSR writes — while the encoding stays linear and symbolic.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import struct
import zlib
from typing import Any, Iterable, Optional

MAGIC = b"RCB1"
MAGIC_V2 = b"RCB2"
PROG_MAGIC = b"AEGP"
PROG_VERSION = 2          # current wire version; v1 decode kept for compat


class Op(enum.IntEnum):
    """Operation vocabulary (linear, hardware-agnostic)."""
    NOP = 0
    # --- buffer table / "register" ops ------------------------------------
    ALLOC = 1            # dst, attrs: shape,dtype — scratch allocation
    FREE = 2             # dst
    BIND_CONST = 3       # dst, attrs: value — small immediate constant
    # --- DMA ops (RHAL initiate_dma/wait_dma) ------------------------------
    DMA_H2D = 10         # dst, src(file id in RIMFS)
    DMA_D2H = 11         # dst(host id), src
    DMA_D2D = 12         # dst, src
    # --- compute dispatches (RHAL dispatch_compute) ------------------------
    GEMM = 20            # dst, a, b, attrs: transpose flags, acc dtype
    CONV2D = 21          # dst, x, w, attrs: stride, padding
    DENSE = 22           # dst, x, w, b?
    ADD = 23             # dst, a, b
    RELU = 24            # dst, x
    SOFTMAX = 25         # dst, x
    MAXPOOL = 26         # dst, x, attrs: window, stride, padding
    AVGPOOL_GLOBAL = 27  # dst, x
    SCALE_SHIFT = 28     # dst, x, scale, shift  (folded batchnorm)
    QUANTIZE = 29        # dst, x, attrs: scale (fp -> int8)
    DEQUANT = 30         # dst, x, attrs: scale (int32/int8 -> fp)
    RESHAPE = 31         # dst, x, attrs: shape
    GEMM_I8 = 32         # dst, a(int8), b(int8) -> int32 accum
    CONV2D_I8 = 33       # dst, x(int8), w(int8), attrs -> int32 accum
    PASSTHROUGH = 34     # dst, x — identity (paper's transfer microbenchmark)
    SCALE_SHIFT_RELU = 35  # dst, x, scale, shift — fused (core/opt.py F1)
    ADD_RELU = 36        # dst, a, b — fused (core/opt.py F2)
    # --- LM block glue (per-layer RCTC lowering, DESIGN.md §13) -------------
    RMSNORM = 37         # dst, x, w, attrs: eps
    ROPE = 38            # dst, x(B,S,H,D), positions(B,S), attrs: theta
    SILU_MUL = 39        # dst, gate, x — silu(gate) * x (swiglu / z-gate)
    # --- graph artifacts (compiled ADF-graph analogue) ----------------------
    GRAPH_EXEC = 40      # dsts, srcs, attrs: artifact id (jitted step fn)
    # --- linked kernel dispatches (kernels/registry.py handlers) ------------
    ATTENTION = 41       # dst, q, k, v, attrs: causal, impl
    MATMUL_INT8 = 42     # dst, x(int8), w(int8), scale, attrs: out_dtype
    SSM_SCAN = 43        # dst, da, bx, c, attrs: impl
    WKV6 = 44            # dst, r, k, v, lw, u, attrs: impl
    # --- distribution -------------------------------------------------------
    COLLECTIVE = 50      # dst, src, attrs: kind, axis
    # --- synchronization (RHAL fence/poll) ----------------------------------
    FENCE = 60           #
    POLL = 61            # src, attrs: expected completion flag
    HALT = 62            #


@dataclasses.dataclass(frozen=True)
class RCBOp:
    op: Op
    dsts: tuple = ()          # symbolic tensor ids (str)
    srcs: tuple = ()
    attrs: dict = dataclasses.field(default_factory=dict)

    def encode(self) -> bytes:
        meta = json.dumps(
            {"d": list(self.dsts), "s": list(self.srcs), "a": self.attrs},
            separators=(",", ":")).encode()
        return struct.pack("<HI", int(self.op), len(meta)) + meta

    @staticmethod
    def decode(buf: memoryview, off: int) -> tuple["RCBOp", int]:
        op, n = struct.unpack_from("<HI", buf, off)
        off += 6
        meta = json.loads(bytes(buf[off:off + n]).decode())
        off += n
        return RCBOp(Op(op), tuple(meta["d"]), tuple(meta["s"]),
                     meta["a"]), off


@dataclasses.dataclass(frozen=True)
class TensorDesc:
    """Symbol-table entry: logical tensor -> physical requirements.

    ``kind``: weight (RIMFS-backed) | input | output | scratch.
    ``axes``: logical axis names consumed by RBL's sharding resolution.
    """
    name: str
    shape: tuple
    dtype: str
    kind: str
    axes: tuple = ()

    def encode(self) -> bytes:
        meta = json.dumps({"n": self.name, "sh": list(self.shape),
                           "dt": self.dtype, "k": self.kind,
                           "ax": list(self.axes)},
                          separators=(",", ":")).encode()
        return struct.pack("<I", len(meta)) + meta

    @staticmethod
    def decode(buf: memoryview, off: int) -> tuple["TensorDesc", int]:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        m = json.loads(bytes(buf[off:off + n]).decode())
        off += n
        return TensorDesc(m["n"], tuple(m["sh"]), m["dt"], m["k"],
                          tuple(m["ax"] or ())), off


# ---------------------------------------------------------------------------
# Binary-v2 encoding: interned symbol table + packed op records.
#
# v1 serializes per-op metadata as JSON — a per-op parse cost on every load.
# v2 (DESIGN.md §3) interns every string once in a program-level symbol
# table; ops, tensor descriptors and attrs then reference u32 indices and
# pack through fixed structs, so decode is pure struct unpacking.  CRC-32
# integrity is unchanged: per-block CRCs plus a whole-program CRC (which
# covers the symbol table, so a corrupted symtab is rejected before parse).
# ---------------------------------------------------------------------------

_ST_OP2 = struct.Struct("<HBBI")        # opcode, n_dsts, n_srcs, attr_idx
_ST_U32 = struct.Struct("<I")
_ST_U16 = struct.Struct("<H")
_ST_F64 = struct.Struct("<d")
_ST_BLK2 = struct.Struct("<4sIIHI")     # magic, block_id, plen, n_ops, type
_ST_PROG = struct.Struct("<4sHIHII")
# decode fast paths: u32-array structs per element count, and direct
# constructors that skip the frozen-dataclass __setattr__ round trip
_U32S = [struct.Struct(f"<{n}I") for n in range(17)]
_U16S_CACHE: dict = {}
_OP_OF = Op._value2member_map_


def _u32s(n: int) -> struct.Struct:
    return _U32S[n] if n < 17 else struct.Struct(f"<{n}I")


def _u16s(n: int) -> struct.Struct:
    s = _U16S_CACHE.get(n)
    if s is None:
        s = _U16S_CACHE[n] = struct.Struct(f"<{n}H")
    return s




class _SymTab:
    """Order-preserving string interner (encode side), plus an attr-dict
    pool: identical attr dicts (stride/padding packs repeat across layers)
    serialize ONCE and ops reference them by u32 index."""

    def __init__(self):
        self.index: dict[str, int] = {}
        self.strings: list[str] = []
        self.attr_index: dict[bytes, int] = {}
        self.attr_blobs: list[bytes] = []

    def add(self, s: str) -> int:
        i = self.index.get(s)
        if i is None:
            i = self.index[s] = len(self.strings)
            self.strings.append(s)
        return i

    def add_attrs(self, attrs: dict) -> int:
        out = [bytes((len(attrs),))]
        for k, v in attrs.items():
            out.append(_ST_U32.pack(self.add(k)))
            _enc_value(out, v, self)
        blob = b"".join(out)
        i = self.attr_index.get(blob)
        if i is None:
            i = self.attr_index[blob] = len(self.attr_blobs)
            self.attr_blobs.append(blob)
        return i

    def encode(self) -> bytes:
        """Lengths-array layout: one struct unpack recovers every string
        boundary, so decode is a single pass over a flat utf-8 blob."""
        raws = [s.encode() for s in self.strings]
        n = len(raws)
        out = [_ST_U32.pack(n), _u16s(n).pack(*(len(r) for r in raws))]
        out += raws
        out.append(_ST_U32.pack(len(self.attr_blobs)))
        out += self.attr_blobs
        return b"".join(out)


def _decode_symtab(data, buf: memoryview,
                   off: int) -> tuple[list, list, int]:
    (n,) = _ST_U32.unpack_from(data, off)
    off += 4
    lens = _u16s(n).unpack_from(data, off)
    off += 2 * n
    syms = []
    append = syms.append
    total = sum(lens)
    blob = str(data[off:off + total], "utf-8")
    if len(blob) == total:              # pure-ASCII: char slicing is valid
        p = 0
        for ln in lens:
            append(blob[p:p + ln])
            p += ln
        off += total
    else:
        for ln in lens:
            append(data[off:off + ln].decode())
            off += ln
    (n_attrs,) = _ST_U32.unpack_from(data, off)
    off += 4
    pool = []
    for _ in range(n_attrs):
        na = data[off]
        off += 1
        attrs = {}
        for _ in range(na):
            (k,) = _ST_U32.unpack_from(data, off)
            attrs[syms[k]], off = _dec_value(data, off + 4, syms)
        pool.append(attrs)
    return syms, pool, off


def _enc_varint(out: list, n: int) -> None:
    u = (n << 1) ^ -1 if n < 0 else (n << 1)       # zigzag, arbitrary width
    while u > 0x7F:
        out.append(bytes((0x80 | (u & 0x7F),)))
        u >>= 7
    out.append(bytes((u,)))


def _dec_varint(buf, off: int) -> tuple[int, int]:
    u, shift = 0, 0
    while True:
        b = buf[off]
        off += 1
        u |= (b & 0x7F) << shift
        if not b & 0x80:
            break
        shift += 7
    return (~(u >> 1) if u & 1 else (u >> 1)), off


def _enc_value(out: list, v, st: _SymTab) -> None:
    """Tag-based attr value encoding. Tuples canonicalize to lists — the
    same canonicalization v1's JSON round-trip applies."""
    if v is None:
        out.append(b"\x00")
    elif v is False:
        out.append(b"\x01")
    elif v is True:
        out.append(b"\x02")
    elif isinstance(v, int):
        out.append(b"\x03")
        _enc_varint(out, v)
    elif isinstance(v, float):
        out.append(b"\x04")
        out.append(_ST_F64.pack(v))
    elif isinstance(v, str):
        out.append(b"\x05")
        out.append(_ST_U32.pack(st.add(v)))
    elif isinstance(v, (list, tuple)):
        out.append(b"\x06")
        out.append(_ST_U32.pack(len(v)))
        for item in v:
            _enc_value(out, item, st)
    elif isinstance(v, dict):
        out.append(b"\x07")
        out.append(_ST_U32.pack(len(v)))
        for k, item in v.items():
            out.append(_ST_U32.pack(st.add(k)))
            _enc_value(out, item, st)
    else:
        raise TypeError(f"unencodable attr value {v!r}")


def _dec_value(buf, off: int, syms: list):
    tag = buf[off]
    off += 1
    if tag == 0:
        return None, off
    if tag == 1:
        return False, off
    if tag == 2:
        return True, off
    if tag == 3:
        return _dec_varint(buf, off)
    if tag == 4:
        return _ST_F64.unpack_from(buf, off)[0], off + 8
    if tag == 5:
        return syms[_ST_U32.unpack_from(buf, off)[0]], off + 4
    if tag == 6:
        (n,) = _ST_U32.unpack_from(buf, off)
        off += 4
        items = []
        for _ in range(n):
            v, off = _dec_value(buf, off, syms)
            items.append(v)
        return items, off
    if tag == 7:
        (n,) = _ST_U32.unpack_from(buf, off)
        off += 4
        d = {}
        for _ in range(n):
            (k,) = _ST_U32.unpack_from(buf, off)
            off += 4
            d[syms[k]], off = _dec_value(buf, off, syms)
        return d, off
    raise ValueError(f"bad value tag {tag}")


def _enc_op_v2(op: "RCBOp", st: _SymTab) -> bytes:
    out = [_ST_OP2.pack(int(op.op), len(op.dsts), len(op.srcs),
                        st.add_attrs(op.attrs))]
    for ref in op.dsts:
        out.append(_ST_U32.pack(st.add(ref)))
    for ref in op.srcs:
        out.append(_ST_U32.pack(st.add(ref)))
    return b"".join(out)


def _enc_tensors_v2(tensors: dict, st: _SymTab) -> bytes:
    """Struct-of-arrays tensor section: all fixed fields in one u32 array,
    all dims in a second — the decode side recovers every descriptor with
    TWO struct calls total instead of two per tensor."""
    fixed: list[int] = []
    dims: list[int] = []
    axes_out: list[bytes] = []
    for t in tensors.values():
        fixed += (st.add(t.name), st.add(t.dtype), st.add(t.kind),
                  len(t.shape), len(t.axes))
        dims += list(t.shape)
        for ax in t.axes:
            _enc_value(axes_out, ax, st)
    return b"".join([_u32s(len(fixed)).pack(*fixed),
                     _ST_U32.pack(len(dims)),
                     _u32s(len(dims)).pack(*dims)] + axes_out)


def _dec_tensors_v2(data, off: int, n_t: int,
                    syms: list) -> tuple[dict, int]:
    fixed = _u32s(5 * n_t).unpack_from(data, off)
    off += 20 * n_t
    (n_dims,) = _ST_U32.unpack_from(data, off)
    off += 4
    dims = _u32s(n_dims).unpack_from(data, off)
    off += 4 * n_dims
    tensors: dict = {}
    p = 0                                  # cursor into dims
    f = 0                                  # cursor into fixed
    for _ in range(n_t):
        ni, di, ki, ndim, naxes = fixed[f:f + 5]
        f += 5
        if naxes:
            axes = []
            for _ in range(naxes):
                v, off = _dec_value(data, off, syms)
                axes.append(v)
            axes = tuple(axes)
        else:
            axes = ()
        t = TensorDesc.__new__(TensorDesc)
        d = t.__dict__
        name = d["name"] = syms[ni]
        d["shape"] = dims[p:p + ndim]
        d["dtype"] = syms[di]
        d["kind"] = syms[ki]
        d["axes"] = axes
        p += ndim
        tensors[name] = t
    return tensors, off


def _enc_block_v2(blk: "RCB", st: _SymTab) -> bytes:
    payload = b"".join(_enc_op_v2(op, st) for op in blk.ops)
    deps = [_ST_U16.pack(len(blk.deps))]
    deps += [_ST_U32.pack(d) for d in blk.deps]
    header = _ST_BLK2.pack(MAGIC_V2, blk.block_id, len(payload),
                           len(blk.ops), st.add(blk.block_type)) \
        + b"".join(deps)
    crc = zlib.crc32(header + payload) & 0xFFFFFFFF
    return header + payload + _ST_U32.pack(crc)


def _dec_block_v2(data, buf: memoryview, off: int, syms: list,
                  pool: list) -> tuple["RCB", int]:
    magic, block_id, plen, n_ops, type_idx = _ST_BLK2.unpack_from(data, off)
    if magic != MAGIC_V2:
        raise ValueError(f"bad RCB v2 magic {magic!r}")
    p = off + _ST_BLK2.size
    (n_deps,) = _ST_U16.unpack_from(data, p)
    p += 2
    deps = _u32s(n_deps).unpack_from(data, p)
    p += 4 * n_deps
    body_end = p + plen
    (crc,) = _ST_U32.unpack_from(data, body_end)
    if crc != (zlib.crc32(buf[off:body_end]) & 0xFFFFFFFF):
        raise ValueError(f"RCB {block_id}: CRC mismatch")
    ops = []
    append = ops.append
    unpack_op = _ST_OP2.unpack_from
    op_of = _OP_OF
    getsym = syms.__getitem__
    for _ in range(n_ops):
        opcode, n_d, n_s, ai = unpack_op(data, p)
        p += 8
        n_refs = n_d + n_s
        refs = _u32s(n_refs).unpack_from(data, p)
        p += 4 * n_refs
        o = RCBOp.__new__(RCBOp)
        d = o.__dict__
        d["op"] = op_of[opcode]
        d["dsts"] = tuple(map(getsym, refs[:n_d]))
        d["srcs"] = tuple(map(getsym, refs[n_d:]))
        # pooled dicts are shared between ops with identical attrs —
        # decoded programs are immutable data (DESIGN.md §3)
        d["attrs"] = pool[ai]
        append(o)
    blk = RCB.__new__(RCB)
    blk.__dict__.update(block_id=block_id, block_type=syms[type_idx],
                        deps=deps, ops=tuple(ops))
    return blk, body_end + 4


@dataclasses.dataclass(frozen=True)
class RCB:
    """Header + operation payload."""
    block_id: int
    block_type: str                 # "layer" | "transfer" | "control" | ...
    deps: tuple = ()                # block ids this RCB waits on
    ops: tuple = ()                 # tuple[RCBOp]

    def encode(self) -> bytes:
        payload = b"".join(op.encode() for op in self.ops)
        hdr_meta = json.dumps({"t": self.block_type, "deps": list(self.deps)},
                              separators=(",", ":")).encode()
        header = struct.pack("<4sIIHI", MAGIC, self.block_id, len(payload),
                             len(self.ops), len(hdr_meta)) + hdr_meta
        crc = zlib.crc32(header + payload) & 0xFFFFFFFF
        return header + payload + struct.pack("<I", crc)

    @staticmethod
    def decode(buf: memoryview, off: int = 0) -> tuple["RCB", int]:
        magic, block_id, plen, n_ops, hlen = struct.unpack_from(
            "<4sIIHI", buf, off)
        if magic != MAGIC:
            raise ValueError(f"bad RCB magic {magic!r}")
        hdr_end = off + 18 + hlen
        body_end = hdr_end + plen
        # integrity FIRST — nothing inside the block is parsed before the
        # CRC over header+payload checks out (torn/corrupt provisioning).
        (crc,) = struct.unpack_from("<I", buf, body_end)
        actual = zlib.crc32(bytes(buf[off:body_end])) & 0xFFFFFFFF
        if crc != actual:
            raise ValueError(f"RCB {block_id}: CRC mismatch "
                             f"({crc:#x} != {actual:#x})")
        meta = json.loads(bytes(buf[off + 18:hdr_end]).decode())
        ops = []
        o = hdr_end
        for _ in range(n_ops):
            op, o = RCBOp.decode(buf, o)
            ops.append(op)
        return RCB(block_id, meta["t"], tuple(meta["deps"]),
                   tuple(ops)), body_end + 4


@dataclasses.dataclass
class RCBProgram:
    """A full workload: symbol table + ordered RCBs + artifact registry.

    ``artifacts`` maps GRAPH_EXEC ids to python callables (the "compiled ADF
    graph" analogues — jitted step functions). They are not serialized; on
    deserialization the binding layer re-attaches them by name, exactly like
    the paper re-attaches precompiled AIE kernels referenced from RCBs.
    """
    name: str
    tensors: dict           # name -> TensorDesc
    blocks: list            # list[RCB]
    artifacts: dict = dataclasses.field(default_factory=dict)

    def crc(self) -> int:
        """Whole-program CRC-32 over the canonical v2 encoding, lazily
        computed and cached — the identity key for compile caches (two
        programs with the same CRC stage to the same executable, so e.g.
        the batch-bucket cache in core/executor.py is shared across
        re-binds of the same program). Artifacts are not covered (they are
        not serialized), but artifact-bearing programs are excluded from
        batch staging by ``linker.batch_analysis`` anyway."""
        c = getattr(self, "_crc", None)
        if c is None:
            # the v2 encoding already ends with the whole-program CRC —
            # reuse it rather than re-hashing (and NEVER hash the full
            # encoding including its trailer: crc32(body || crc32(body))
            # is the same constant for every message)
            (c,) = struct.unpack("<I", self.encode()[-4:])
            self._crc = c
        return c

    # ------------------------------------------------------------- binary io
    def encode(self, version: int = PROG_VERSION) -> bytes:
        """Serialize.  v2 (default): interned symtab + packed op records.
        v1 kept for cross-version tests and the encode/decode benchmark."""
        if version == 1:
            return self._encode_v1()
        if version != 2:
            raise ValueError(f"unknown RCBProgram version {version}")
        st = _SymTab()
        # ops/tensors are encoded first so the symtab they intern into is
        # complete before it is itself serialized
        tensec = _enc_tensors_v2(self.tensors, st)
        blocks = b"".join(_enc_block_v2(b, st) for b in self.blocks)
        symtab = st.encode()
        name = self.name.encode()
        hdr = _ST_PROG.pack(PROG_MAGIC, 2, len(name), len(self.tensors),
                            len(self.blocks), len(symtab))
        body = hdr + name + symtab + tensec + blocks
        return body + _ST_U32.pack(zlib.crc32(body) & 0xFFFFFFFF)

    def _encode_v1(self) -> bytes:
        tensec = b"".join(t.encode() for t in self.tensors.values())
        blocks = b"".join(b.encode() for b in self.blocks)
        name = self.name.encode()
        hdr = struct.pack("<4sHIHII", PROG_MAGIC, 1, len(name),
                          len(self.tensors), len(self.blocks), len(tensec))
        body = hdr + name + tensec + blocks
        return body + struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)

    @staticmethod
    def decode(data: bytes) -> "RCBProgram":
        """Version-sniffing decode: v1 and v2 wire formats both accepted.

        Integrity FIRST, for both versions: the whole-program CRC (which
        covers the v2 symbol table) is verified before any section parses.
        """
        buf = memoryview(data)
        magic, ver, nlen, n_t, n_b, seclen = struct.unpack_from(
            "<4sHIHII", buf)
        if magic != PROG_MAGIC:
            raise ValueError(f"bad program magic {magic!r}")
        (crc,) = struct.unpack_from("<I", buf, len(data) - 4)
        if crc != (zlib.crc32(data[:-4]) & 0xFFFFFFFF):
            raise ValueError("RCBProgram CRC mismatch")
        off = struct.calcsize("<4sHIHII")
        name = bytes(buf[off:off + nlen]).decode()
        off += nlen
        tensors = {}
        blocks = []
        if ver == 1:
            for _ in range(n_t):
                t, off = TensorDesc.decode(buf, off)
                tensors[t.name] = t
            for _ in range(n_b):
                b, off = RCB.decode(buf, off)
                blocks.append(b)
        elif ver == 2:
            syms, pool, off = _decode_symtab(data, buf, off)
            tensors, off = _dec_tensors_v2(data, off, n_t, syms)
            for _ in range(n_b):
                b, off = _dec_block_v2(data, buf, off, syms, pool)
                blocks.append(b)
        else:
            raise ValueError(f"unknown RCBProgram version {ver}")
        return RCBProgram(name, tensors, blocks)

    # ------------------------------------------------------------- utilities
    def ops(self) -> Iterable[RCBOp]:
        for b in self.blocks:
            for op in b.ops:
                yield op

    def validate(self) -> None:
        """Static checks: every symbolic ref has a descriptor; deps exist."""
        ids = {b.block_id for b in self.blocks}
        for b in self.blocks:
            for d in b.deps:
                if d not in ids:
                    raise ValueError(f"RCB {b.block_id}: missing dep {d}")
        for op in self.ops():
            for ref in (*op.dsts, *op.srcs):
                if ref not in self.tensors:
                    raise ValueError(f"unbound symbolic ref {ref!r}")
