"""Runtime Control Blocks — the paper's "Control as Data" representation.

An RCB is *not* executable code: it is a binary data structure holding a
linear sequence of low-level operations (register-write analogues, DMA
triggers, compute dispatches, fences) that encode the complete execution
semantics of an ML workload. A generic engine (core/executor.py) runs RCBs
through the RHAL vtable without knowing anything about the model.

Faithfulness to the paper:
  * RCBs are hardware-independent; tensor references are *symbolic* IDs
    resolved by the Runtime Binding Layer at load time (never raw pointers).
  * Each RCB has a Header (type / size / dependency info) and an Operation
    Payload (structured op sequence).
  * RCBs serialize to a flat binary format with CRC-32 integrity (the same
    IEEE 0x04C11DB7 polynomial the paper uses on its network messages), so
    a model really is provisioned as *data* over the wire.

TPU adaptation (see DESIGN.md §2): the op vocabulary is re-based on the XLA
execution model — buffer ops, fused-compute dispatches and collectives
replace AIE CSR writes — while the encoding stays linear and symbolic.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import struct
import zlib
from typing import Any, Iterable, Optional

MAGIC = b"RCB1"
PROG_MAGIC = b"AEGP"


class Op(enum.IntEnum):
    """Operation vocabulary (linear, hardware-agnostic)."""
    NOP = 0
    # --- buffer table / "register" ops ------------------------------------
    ALLOC = 1            # dst, attrs: shape,dtype — scratch allocation
    FREE = 2             # dst
    BIND_CONST = 3       # dst, attrs: value — small immediate constant
    # --- DMA ops (RHAL initiate_dma/wait_dma) ------------------------------
    DMA_H2D = 10         # dst, src(file id in RIMFS)
    DMA_D2H = 11         # dst(host id), src
    DMA_D2D = 12         # dst, src
    # --- compute dispatches (RHAL dispatch_compute) ------------------------
    GEMM = 20            # dst, a, b, attrs: transpose flags, acc dtype
    CONV2D = 21          # dst, x, w, attrs: stride, padding
    DENSE = 22           # dst, x, w, b?
    ADD = 23             # dst, a, b
    RELU = 24            # dst, x
    SOFTMAX = 25         # dst, x
    MAXPOOL = 26         # dst, x, attrs: window, stride, padding
    AVGPOOL_GLOBAL = 27  # dst, x
    SCALE_SHIFT = 28     # dst, x, scale, shift  (folded batchnorm)
    QUANTIZE = 29        # dst, x, attrs: scale (fp -> int8)
    DEQUANT = 30         # dst, x, attrs: scale (int32/int8 -> fp)
    RESHAPE = 31         # dst, x, attrs: shape
    GEMM_I8 = 32         # dst, a(int8), b(int8) -> int32 accum
    CONV2D_I8 = 33       # dst, x(int8), w(int8), attrs -> int32 accum
    PASSTHROUGH = 34     # dst, x — identity (paper's transfer microbenchmark)
    # --- graph artifacts (compiled ADF-graph analogue) ----------------------
    GRAPH_EXEC = 40      # dsts, srcs, attrs: artifact id (jitted step fn)
    # --- distribution -------------------------------------------------------
    COLLECTIVE = 50      # dst, src, attrs: kind, axis
    # --- synchronization (RHAL fence/poll) ----------------------------------
    FENCE = 60           #
    POLL = 61            # src, attrs: expected completion flag
    HALT = 62            #


@dataclasses.dataclass(frozen=True)
class RCBOp:
    op: Op
    dsts: tuple = ()          # symbolic tensor ids (str)
    srcs: tuple = ()
    attrs: dict = dataclasses.field(default_factory=dict)

    def encode(self) -> bytes:
        meta = json.dumps(
            {"d": list(self.dsts), "s": list(self.srcs), "a": self.attrs},
            separators=(",", ":")).encode()
        return struct.pack("<HI", int(self.op), len(meta)) + meta

    @staticmethod
    def decode(buf: memoryview, off: int) -> tuple["RCBOp", int]:
        op, n = struct.unpack_from("<HI", buf, off)
        off += 6
        meta = json.loads(bytes(buf[off:off + n]).decode())
        off += n
        return RCBOp(Op(op), tuple(meta["d"]), tuple(meta["s"]),
                     meta["a"]), off


@dataclasses.dataclass(frozen=True)
class TensorDesc:
    """Symbol-table entry: logical tensor -> physical requirements.

    ``kind``: weight (RIMFS-backed) | input | output | scratch.
    ``axes``: logical axis names consumed by RBL's sharding resolution.
    """
    name: str
    shape: tuple
    dtype: str
    kind: str
    axes: tuple = ()

    def encode(self) -> bytes:
        meta = json.dumps({"n": self.name, "sh": list(self.shape),
                           "dt": self.dtype, "k": self.kind,
                           "ax": list(self.axes)},
                          separators=(",", ":")).encode()
        return struct.pack("<I", len(meta)) + meta

    @staticmethod
    def decode(buf: memoryview, off: int) -> tuple["TensorDesc", int]:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        m = json.loads(bytes(buf[off:off + n]).decode())
        off += n
        return TensorDesc(m["n"], tuple(m["sh"]), m["dt"], m["k"],
                          tuple(m["ax"] or ())), off


@dataclasses.dataclass(frozen=True)
class RCB:
    """Header + operation payload."""
    block_id: int
    block_type: str                 # "layer" | "transfer" | "control" | ...
    deps: tuple = ()                # block ids this RCB waits on
    ops: tuple = ()                 # tuple[RCBOp]

    def encode(self) -> bytes:
        payload = b"".join(op.encode() for op in self.ops)
        hdr_meta = json.dumps({"t": self.block_type, "deps": list(self.deps)},
                              separators=(",", ":")).encode()
        header = struct.pack("<4sIIHI", MAGIC, self.block_id, len(payload),
                             len(self.ops), len(hdr_meta)) + hdr_meta
        crc = zlib.crc32(header + payload) & 0xFFFFFFFF
        return header + payload + struct.pack("<I", crc)

    @staticmethod
    def decode(buf: memoryview, off: int = 0) -> tuple["RCB", int]:
        magic, block_id, plen, n_ops, hlen = struct.unpack_from(
            "<4sIIHI", buf, off)
        if magic != MAGIC:
            raise ValueError(f"bad RCB magic {magic!r}")
        hdr_end = off + 18 + hlen
        body_end = hdr_end + plen
        # integrity FIRST — nothing inside the block is parsed before the
        # CRC over header+payload checks out (torn/corrupt provisioning).
        (crc,) = struct.unpack_from("<I", buf, body_end)
        actual = zlib.crc32(bytes(buf[off:body_end])) & 0xFFFFFFFF
        if crc != actual:
            raise ValueError(f"RCB {block_id}: CRC mismatch "
                             f"({crc:#x} != {actual:#x})")
        meta = json.loads(bytes(buf[off + 18:hdr_end]).decode())
        ops = []
        o = hdr_end
        for _ in range(n_ops):
            op, o = RCBOp.decode(buf, o)
            ops.append(op)
        return RCB(block_id, meta["t"], tuple(meta["deps"]),
                   tuple(ops)), body_end + 4


@dataclasses.dataclass
class RCBProgram:
    """A full workload: symbol table + ordered RCBs + artifact registry.

    ``artifacts`` maps GRAPH_EXEC ids to python callables (the "compiled ADF
    graph" analogues — jitted step functions). They are not serialized; on
    deserialization the binding layer re-attaches them by name, exactly like
    the paper re-attaches precompiled AIE kernels referenced from RCBs.
    """
    name: str
    tensors: dict           # name -> TensorDesc
    blocks: list            # list[RCB]
    artifacts: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- binary io
    def encode(self) -> bytes:
        tensec = b"".join(t.encode() for t in self.tensors.values())
        blocks = b"".join(b.encode() for b in self.blocks)
        name = self.name.encode()
        hdr = struct.pack("<4sHIHII", PROG_MAGIC, 1, len(name),
                          len(self.tensors), len(self.blocks), len(tensec))
        body = hdr + name + tensec + blocks
        return body + struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)

    @staticmethod
    def decode(data: bytes) -> "RCBProgram":
        buf = memoryview(data)
        magic, ver, nlen, n_t, n_b, tlen = struct.unpack_from("<4sHIHII", buf)
        if magic != PROG_MAGIC:
            raise ValueError(f"bad program magic {magic!r}")
        (crc,) = struct.unpack_from("<I", buf, len(data) - 4)
        if crc != (zlib.crc32(data[:-4]) & 0xFFFFFFFF):
            raise ValueError("RCBProgram CRC mismatch")
        off = struct.calcsize("<4sHIHII")
        name = bytes(buf[off:off + nlen]).decode()
        off += nlen
        tensors = {}
        for _ in range(n_t):
            t, off = TensorDesc.decode(buf, off)
            tensors[t.name] = t
        blocks = []
        for _ in range(n_b):
            b, off = RCB.decode(buf, off)
            blocks.append(b)
        return RCBProgram(name, tensors, blocks)

    # ------------------------------------------------------------- utilities
    def ops(self) -> Iterable[RCBOp]:
        for b in self.blocks:
            for op in b.ops:
                yield op

    def validate(self) -> None:
        """Static checks: every symbolic ref has a descriptor; deps exist."""
        ids = {b.block_id for b in self.blocks}
        for b in self.blocks:
            for d in b.deps:
                if d not in ids:
                    raise ValueError(f"RCB {b.block_id}: missing dep {d}")
        for op in self.ops():
            for ref in (*op.dsts, *op.srcs):
                if ref not in self.tensors:
                    raise ValueError(f"unbound symbolic ref {ref!r}")
