"""Runtime Binding Layer — symbolic -> physical resolution.

RBL turns a symbolic RCBProgram into an executable one:

  * **Data binding** — weight symbols resolve to zero-copy RIMFS views
    (host "physical addresses") which the driver DMAs to device memory;
    caller-supplied inputs bind to their symbols; scratch is allocated.
  * **Address resolution** — on a mesh, each TensorDesc's logical axes are
    resolved to a ``NamedSharding`` by the shape-aware rule engine
    (distributed/sharding.py): a tensor's shard layout IS its physical
    address space on a pod.
  * **Dependency & buffer management** — liveness intervals over the linear
    op stream; the executor frees each scratch buffer after its last use, so
    pipelines of RCBs reuse memory exactly like the paper's buffer manager.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.rcb import Op, RCBProgram, TensorDesc
from repro.core.rimfs import RIMFS
from repro.distributed.sharding import sharding_for


@dataclasses.dataclass
class BoundProgram:
    program: RCBProgram
    buffers: dict                    # symbol -> host/device buffer
    last_use: dict                   # symbol -> linear op index of last read
    shardings: dict                  # symbol -> Optional[NamedSharding]
    missing_inputs: tuple            # input symbols the caller must feed


def liveness(program: RCBProgram) -> dict:
    """Last linear-op index at which each symbol is read."""
    last: dict[str, int] = {}
    for i, op in enumerate(program.ops()):
        for s in op.srcs:
            last[s] = i
    return last


def scratch_free_lists(program: RCBProgram,
                       last_use: Optional[dict] = None) -> list:
    """Per-linear-op-index tuples of scratch symbols whose last read is that
    op — the precomputed release schedule the linker bakes into each thunk
    (the interpreted path derives the same decisions from ``last_use`` one
    dict probe per operand per step; linked pays nothing until the actual
    release point)."""
    last_use = liveness(program) if last_use is None else last_use
    n_ops = sum(len(b.ops) for b in program.blocks)
    frees: list[list] = [[] for _ in range(n_ops)]
    for sym, idx in last_use.items():
        t = program.tensors.get(sym)
        if t is not None and t.kind == "scratch":
            frees[idx].append(sym)
    return [tuple(f) for f in frees]


def resolve_shardings(program: RCBProgram) -> dict:
    out = {}
    for name, t in program.tensors.items():
        if t.axes:
            out[name] = sharding_for(t.shape, t.axes)
        else:
            out[name] = None
    return out


def bind(program: RCBProgram,
         rimfs: Optional[RIMFS] = None,
         inputs: Optional[dict] = None,
         driver=None,
         verify_weights: bool = False) -> BoundProgram:
    """Produce a fully resolved program (the paper's Binding phase)."""
    program.validate()
    inputs = inputs or {}
    buffers: dict[str, Any] = {}
    missing = []
    for name, t in program.tensors.items():
        if t.kind == "weight":
            if rimfs is None:
                raise ValueError(f"weight {name!r} needs a RIMFS image")
            if verify_weights:
                rimfs.verify(name)
            view = rimfs.read(name)                 # zero-copy host view
            if driver is not None:
                buffers[name] = driver.initiate_dma(view, "h2d")
            else:
                buffers[name] = view
        elif t.kind == "input":
            if name in inputs:
                buffers[name] = inputs[name]
            else:
                missing.append(name)
        # outputs/scratch are produced during execution
    return BoundProgram(program, buffers, liveness(program),
                        resolve_shardings(program), tuple(missing))


def rebind(bound: BoundProgram, **updates) -> BoundProgram:
    """Elastic re-binding: same control stream, new physical resources.

    Because control is *data*, moving a workload to a different mesh or a
    replacement worker never re-traces model code — only this function runs.
    """
    buffers = dict(bound.buffers)
    buffers.update(updates.get("buffers", {}))
    return BoundProgram(bound.program, buffers, bound.last_use,
                        resolve_shardings(bound.program),
                        bound.missing_inputs)
