"""Runtime Binding Layer — symbolic -> physical resolution.

RBL turns a symbolic RCBProgram into an executable one:

  * **Data binding** — weight symbols resolve to zero-copy RIMFS views
    (host "physical addresses") which the driver DMAs to device memory;
    caller-supplied inputs bind to their symbols; scratch is allocated.
  * **Address resolution** — on a mesh, each TensorDesc's logical axes are
    resolved to a ``NamedSharding`` by the shape-aware rule engine
    (distributed/sharding.py): a tensor's shard layout IS its physical
    address space on a pod.
  * **Dependency & buffer management** — liveness intervals over the linear
    op stream; the executor frees each scratch buffer after its last use, so
    pipelines of RCBs reuse memory exactly like the paper's buffer manager.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.rcb import Op, RCBProgram, TensorDesc
from repro.core.rimfs import RIMFS
from repro.distributed.sharding import sharding_for


@dataclasses.dataclass
class BoundProgram:
    program: RCBProgram
    buffers: dict                    # symbol -> host/device buffer
    last_use: dict                   # symbol -> linear op index of last read
    shardings: dict                  # symbol -> Optional[NamedSharding]
    missing_inputs: tuple            # input symbols the caller must feed


def liveness(program: RCBProgram) -> dict:
    """Last linear-op index at which each symbol is read."""
    last: dict[str, int] = {}
    for i, op in enumerate(program.ops()):
        for s in op.srcs:
            last[s] = i
    return last


def explicitly_freed(program: RCBProgram) -> set:
    """Symbols released by an explicit FREE op (driver-managed lifetime)."""
    return {op.dsts[0] for op in program.ops()
            if op.op is Op.FREE and op.dsts}


def scratch_free_lists(program: RCBProgram,
                       last_use: Optional[dict] = None) -> list:
    """Per-linear-op-index tuples of scratch symbols whose last read is that
    op — the precomputed release schedule the linker bakes into each thunk
    (the interpreted path derives the same decisions from ``last_use`` one
    dict probe per operand per step; linked pays nothing until the actual
    release point).

    Symbols with an explicit FREE op are excluded: their release belongs
    to the driver (which must see the real buffer to return its arena
    range — a reference-drop at last read would hand FREE a cleared slot
    and leak the range)."""
    last_use = liveness(program) if last_use is None else last_use
    explicit = explicitly_freed(program)
    n_ops = sum(len(b.ops) for b in program.blocks)
    frees: list[list] = [[] for _ in range(n_ops)]
    for sym, idx in last_use.items():
        t = program.tensors.get(sym)
        if t is not None and t.kind == "scratch" and sym not in explicit:
            frees[idx].append(sym)
    return [tuple(f) for f in frees]


def resolve_shardings(program: RCBProgram) -> dict:
    out = {}
    for name, t in program.tensors.items():
        if t.axes:
            out[name] = sharding_for(t.shape, t.axes)
        else:
            out[name] = None
    return out


def bind(program: RCBProgram,
         rimfs: Optional[RIMFS] = None,
         inputs: Optional[dict] = None,
         driver=None,
         verify_weights: bool = False,
         weights: Optional[dict] = None) -> BoundProgram:
    """Produce a fully resolved program (the paper's Binding phase).

    ``weights`` supplies already-resolved weight buffers directly —
    re-binding a slice of an earlier bind (e.g. a tile program of a
    partitioned workload whose weights resolved at the original bind)
    needs no image round-trip."""
    program.validate()
    inputs = inputs or {}
    weights = weights or {}
    buffers: dict[str, Any] = {}
    missing = []
    # With a driver, weights resolve through the image's per-driver
    # residency cache: the first bind pins THIS PROGRAM's weight files
    # device-side ONCE (split-phase upload into the arena; later binds of
    # other programs extend the pinned set incrementally); every later
    # bind — including rebind() after elasticity events and repeated
    # ServingEngine construction — reuses the pinned buffers and moves
    # zero bytes. CRC verification, when requested, happens BEFORE any
    # byte is uploaded or cached.
    weight_names = [n for n, t in program.tensors.items()
                    if t.kind == "weight"]
    unresolved = [n for n in weight_names if n not in weights]
    resident = None
    if unresolved and rimfs is None:
        raise ValueError(f"weight {unresolved[0]!r} needs a RIMFS image")
    if verify_weights:
        for name in unresolved:
            rimfs.verify(name)
    if driver is not None and rimfs is not None and unresolved:
        resident = rimfs.resident(driver, names=unresolved)
    for name, t in program.tensors.items():
        if t.kind == "weight":
            if name in weights:
                buffers[name] = weights[name]       # caller-resolved
            elif resident is not None:
                buffers[name] = resident[name]      # pinned device buffer
            else:
                buffers[name] = rimfs.read(name)    # zero-copy host view
        elif t.kind == "input":
            if name in inputs:
                buffers[name] = inputs[name]
            else:
                missing.append(name)
        # outputs/scratch are produced during execution
    return BoundProgram(program, buffers, liveness(program),
                        resolve_shardings(program), tuple(missing))


def rebind(bound: BoundProgram, **updates) -> BoundProgram:
    """Elastic re-binding: same control stream, new physical resources.

    Because control is *data*, moving a workload to a different mesh or a
    replacement worker never re-traces model code — only this function runs.
    """
    buffers = dict(bound.buffers)
    buffers.update(updates.get("buffers", {}))
    return BoundProgram(bound.program, buffers, bound.last_use,
                        resolve_shardings(bound.program),
                        bound.missing_inputs)
