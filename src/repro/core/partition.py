"""Partitioned multi-tile execution — the paper's array-of-tiles shape.

The paper's efficiency claim is a *multi-tile* claim: ResNet-18 runs over a
28-tile AIE array with RTPM orchestrating tile groups, each group owning a
contiguous run of layers and streaming its boundary activations to the next
group over the interconnect. This pass reproduces that deployment shape on
RHAL terms (DESIGN.md §7):

  * ``partition`` cuts a bound program into per-tile-group ``TileProgram``s
    at layer granularity — RCB block boundaries when the program has enough
    blocks, balanced linear-op splits otherwise. Cut analysis runs over the
    same linear def/read stream the RBL liveness machinery walks: a symbol
    defined in group *f* and read in group *g* > *f* is a **cut edge**, and
    becomes an output of *f*'s subprogram and an input of *g*'s.
  * Each ``TileProgram`` is a complete, self-validating ``RCBProgram`` —
    binding it against a tile group's driver reuses the whole existing
    stack unchanged: RIMFS residency pins only that group's weights into
    that group's arena, and linking yields the group's own static
    ``ResidencyPlan`` (per-group arena offsets, high-water, prefetch/drain
    schedule).
  * ``execute`` drives the pipelined schedule over a ``TileMesh``: when
    stage *k* completes on group *g*, every cut-edge tensor it produced is
    issued split-phase toward its consumer groups (``TileMesh.stream``),
    and the ticket is redeemed only when the consuming stage starts — so
    group *g−1*'s activation stream rides under group *g*'s compute. With
    an RTPM ``Platform`` attached, every group is a heartbeat-monitored
    worker and a failed stage re-queues on a surviving group (re-binding
    the same control stream against the survivor's driver — control-as-data
    elasticity, paper §5.2).

Differential conformance across run_interpreted / run / fuse /
run_partitioned — bit-identical outputs at every tile-group count — is
enforced by tests/test_conformance.py.
"""
from __future__ import annotations

import bisect
import collections
import dataclasses
import time
import weakref
from typing import Any, Iterable, Iterator, Optional

from repro.core import rbl as rbl_mod
from repro.core import rhal as rhal_mod
from repro.core.rcb import Op, RCB, RCBProgram, TensorDesc
from repro.core.rhal import DmaTicket, TileFailure, TileMesh, _nbytes_of


# Per-tile bind cache bound: a tile legitimately binds against its own
# group's driver plus (during failover) a few survivors — anything past
# this is a discarded mesh whose buffers must not be retained.
_BIND_CACHE_CAP = 8


@dataclasses.dataclass(frozen=True)
class CutEdge:
    """One cut-edge tensor: produced by group ``src``, consumed by group
    ``dst``; ``nbytes`` is the per-execution movement this edge costs."""
    sym: str
    src: int
    dst: int
    nbytes: int


@dataclasses.dataclass
class TileProgram:
    """One tile group's slice of the workload.

    ``program`` is a standalone RCBProgram: cut-in symbols are re-kinded
    ``input`` (they arrive over inter-tile DMA), cut-out symbols ``output``
    (they stay live to stage exit so the mesh can stream them). Binding is
    cached per driver, so repeated executions re-link nothing.
    """
    gid: int
    program: RCBProgram
    cut_ins: tuple            # symbols arriving over inter-tile streams
    cut_outs: tuple           # symbols streamed to later groups
    input_syms: tuple         # global input symbols this tile consumes
    output_syms: tuple        # global output symbols this tile defines
    weight_syms: tuple
    _bound: dict = dataclasses.field(default_factory=dict, repr=False)

    def bind(self, driver, rimfs=None,
             weights: Optional[dict] = None) -> rbl_mod.BoundProgram:
        """Bind (and cache) against one tile group's driver — weights pin
        into THAT group's arena via the RIMFS residency cache, or resolve
        from ``weights`` (the original bind's buffers) without an image."""
        entry = self._bound.get(id(driver))
        if entry is not None and entry[0]() is driver:
            return entry[1]
        # The cached BoundProgram's linked form holds its driver strongly,
        # so dead-driver weakrefs can't fire — bound FIFO eviction keeps
        # a long elasticity run (fresh mesh per failure) from retaining
        # every discarded mesh's buffers. Re-binding an evicted driver is
        # pure resolution, so eviction never affects results.
        while len(self._bound) >= _BIND_CACHE_CAP:
            self._bound.pop(next(iter(self._bound)))
        bound = rbl_mod.bind(self.program, rimfs=rimfs, driver=driver,
                             weights=weights)
        self._bound[id(driver)] = (weakref.ref(driver), bound)
        return bound

    def residency(self, driver):
        """The group's static ResidencyPlan, once linked (None before)."""
        entry = self._bound.get(id(driver))
        linked = getattr(entry[1], "_linked", None) if entry else None
        return linked.residency if linked is not None else None


@dataclasses.dataclass
class PartitionedProgram:
    """The partition: ordered tile programs + the cut-edge tensor table."""
    bound: rbl_mod.BoundProgram        # the original single-device binding
    tiles: list                        # list[TileProgram], stage order
    edges: tuple                       # tuple[CutEdge]

    @property
    def n_groups(self) -> int:
        return len(self.tiles)

    def edges_from(self, gid: int) -> list:
        return [e for e in self.edges if e.src == gid]

    def cut_bytes(self) -> int:
        """Planned inter-tile movement per execution (sum over edges)."""
        return sum(e.nbytes for e in self.edges)


# ---------------------------------------------------------------------------
# Cut-point selection
# ---------------------------------------------------------------------------

def _contiguous_split(weights: list, k: int) -> list:
    """Balanced contiguous split of ``weights`` into <= k non-empty runs."""
    n = len(weights)
    k = max(1, min(k, n))
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)
    total = prefix[-1]
    cuts = [0]
    for g in range(1, k):
        ideal = total * g / k
        j = bisect.bisect_left(prefix, ideal)
        j = max(j, cuts[-1] + 1)           # every group stays non-empty
        j = min(j, n - (k - g))            # leave room for the rest
        cuts.append(j)
    cuts.append(n)
    return [(cuts[i], cuts[i + 1]) for i in range(len(cuts) - 1)]


def _reads(op) -> tuple:
    """Symbols an op consumes. FREE's dst is a *read* for cut purposes:
    the op needs the live buffer (to return its range), it defines
    nothing."""
    return op.srcs + (op.dsts if op.op is Op.FREE else ())


def _defs(op) -> tuple:
    return () if op.op is Op.FREE else op.dsts


def _group_blocks(prog: RCBProgram, n_groups: int) -> list:
    """Per-group block lists: layer-granularity cuts at RCB block
    boundaries when the program has enough blocks, balanced linear-op
    splits (re-blocked as one "partition" RCB per group) otherwise."""
    if len(prog.blocks) >= n_groups:
        spans = _contiguous_split([len(b.ops) for b in prog.blocks],
                                  n_groups)
        out = []
        for start, end in spans:
            group = prog.blocks[start:end]
            ids = {b.block_id for b in group}
            out.append([dataclasses.replace(
                b, deps=tuple(d for d in b.deps if d in ids))
                for b in group])
        return out
    flat = [op for b in prog.blocks for op in b.ops]
    spans = _contiguous_split([1] * len(flat), n_groups)
    return [[RCB(g, "partition", (), tuple(flat[start:end]))]
            for g, (start, end) in enumerate(spans)]


# ---------------------------------------------------------------------------
# The partition pass
# ---------------------------------------------------------------------------

def partition(bound: rbl_mod.BoundProgram,
              n_groups: int) -> PartitionedProgram:
    """Split a bound program into ``n_groups`` tile-group stages.

    Cuts are contiguous over the linear op stream, so every cross-group
    data dependency points forward: the producing group marks the symbol
    an output, every consuming group an input, and the pair becomes a
    ``CutEdge`` in the movement table. A symbol redefined across the cut
    (e.g. a recurrent cache) edges from its *latest* producer — the scan
    below tracks the last defining group per symbol, exactly the liveness
    walk RBL's interval analysis performs.
    """
    prog = bound.program
    groups = _group_blocks(prog, max(1, int(n_groups)))
    n = len(groups)

    group_ops = [[op for b in blocks for op in b.ops] for blocks in groups]
    cut_ins: list = [set() for _ in range(n)]
    cut_outs: list = [set() for _ in range(n)]
    edge_set: dict = {}
    last_def: dict = {}
    for g, ops in enumerate(group_ops):
        for op in ops:
            for sym in _reads(op):
                dg = last_def.get(sym)
                if dg is not None and dg != g:
                    cut_ins[g].add(sym)
                    cut_outs[dg].add(sym)
                    t = prog.tensors[sym]
                    edge_set[(sym, dg, g)] = _nbytes_of(t.shape, t.dtype)
            for sym in _defs(op):
                last_def[sym] = g

    tiles: list = []
    for g, blocks in enumerate(groups):
        ops = group_ops[g]
        defs_g = {s for op in ops for s in _defs(op)}
        syms = {s for op in ops for s in (*op.dsts, *op.srcs)}
        tensors: dict = {}
        for name in prog.tensors:              # keep original symtab order
            if name not in syms:
                continue
            t = prog.tensors[name]
            if t.kind == "weight":
                kind = "weight"
            elif name in cut_outs[g] or (t.kind == "output"
                                         and name in defs_g):
                kind = "output"
            elif name in cut_ins[g] or t.kind == "input":
                kind = "input"
            else:
                kind = t.kind
            tensors[name] = t if t.kind == kind \
                else dataclasses.replace(t, kind=kind)
        sub = RCBProgram(f"{prog.name}.tile{g}", tensors, blocks,
                         dict(prog.artifacts))
        sub.validate()
        tiles.append(TileProgram(
            gid=g, program=sub,
            cut_ins=tuple(s for s in tensors if s in cut_ins[g]),
            cut_outs=tuple(s for s in tensors if s in cut_outs[g]),
            input_syms=tuple(s for s, t in tensors.items()
                             if t.kind == "input" and s not in cut_ins[g]),
            output_syms=tuple(s for s in tensors if s in defs_g
                              and prog.tensors[s].kind == "output"),
            weight_syms=tuple(s for s, t in tensors.items()
                              if t.kind == "weight")))
    edges = tuple(CutEdge(sym, src, dst, nb)
                  for (sym, src, dst), nb in edge_set.items())
    return PartitionedProgram(bound, tiles, edges)


def ensure_partition(bound: rbl_mod.BoundProgram,
                     n_groups: int) -> PartitionedProgram:
    """The per-group-count partition cache: cut once per (bound, n_groups),
    reuse forever. Shared by ``Executor.run_partitioned`` and the fleet
    controller's mesh pre-warm, so a scale event re-cuts nothing the
    serving path already paid for."""
    cache = getattr(bound, "_partitions", None)
    if cache is None:
        cache = bound._partitions = {}
    part = cache.get(n_groups)
    if part is None:
        part = cache[n_groups] = partition(bound, n_groups)
    return part


def prewarm(part: PartitionedProgram, mesh: TileMesh, rimfs=None) -> None:
    """Bind + link every tile against its mesh group's driver ahead of
    traffic, so the first request after a mesh flip pays no residency
    upload or link cost on the dispatcher thread. Safe to run off the
    dispatcher: it touches only the new mesh's drivers and per-tile bind
    caches (idempotent inserts)."""
    from repro.core.executor import Executor   # local: avoids import cycle
    base = part.bound.buffers
    for tile in part.tiles:
        driver = mesh.group(tile.gid).driver
        bt = tile.bind(driver, rimfs,
                       weights=None if rimfs is not None else
                       {s: base[s] for s in tile.weight_syms if s in base})
        Executor(driver=driver).link(bt)


# ---------------------------------------------------------------------------
# The pipelined schedule driver
# ---------------------------------------------------------------------------

def prewarm_group(part: PartitionedProgram, driver, gid: int,
                  rimfs=None) -> None:
    """Bind + link ONE tile's subprogram against a replacement group's
    driver (partial reshape): only the new driver's arena is populated —
    surviving groups' residency, bind caches and DMA counters are never
    touched, so replacing one straggler moves exactly one stage's weight
    bytes and zero bytes for everyone else."""
    from repro.core.executor import Executor   # local: avoids import cycle
    base = part.bound.buffers
    tile = part.tiles[gid]
    bt = tile.bind(driver, rimfs,
                   weights=None if rimfs is not None else
                   {s: base[s] for s in tile.weight_syms if s in base})
    Executor(driver=driver).link(bt)


def execute(part: PartitionedProgram, mesh: TileMesh,
            inputs: Optional[dict] = None, rimfs=None,
            platform=None, stage_times: Optional[list] = None) -> dict:
    """Run the partitioned schedule over a tile mesh.

    Stage *k* (tile group *k*) redeems its cut-in tickets, executes its
    linked subprogram on its own driver, then issues its cut-out streams
    split-phase — the issue returns immediately, so the transfer toward
    group *k+1* overlaps whatever runs next. With a ``platform``, each
    group is a heartbeat-monitored worker ("tile<g>"); a ``TileFailure``
    triggers a liveness sweep (live groups answer the poll, the dead one
    can't) and the stage re-queues on the first surviving group, re-bound
    against that group's driver. Missing tickets after a failover are
    re-streamed from the producer's retained buffer.
    """
    from repro.core.executor import Executor   # local: avoids import cycle
    if mesh.n_groups < part.n_groups:
        raise ValueError(f"mesh has {mesh.n_groups} groups, partition "
                         f"needs {part.n_groups}")
    feed = dict(part.bound.buffers)
    if inputs:
        feed.update(inputs)
    for sym in part.bound.missing_inputs:
        if sym not in feed:
            raise ValueError(f"missing input {sym!r}")

    hb = platform.heartbeats if platform is not None else None
    if hb is not None:
        for gid in mesh.gids:          # registration doubles as a poll:
            if mesh.alive(gid):        # only responsive groups beat
                hb.beat(f"tile{gid}", 0)
            else:
                hb.register_silent(f"tile{gid}")

    env: dict = {}                 # cut-out sym -> producer's raw buffer
    tickets: dict = {}             # (sym, dst_gid) -> in-flight ticket
    outs: dict = {}
    for stage_idx, tile in enumerate(part.tiles):
        gid = tile.gid
        tried: set = set()
        while True:
            group = mesh.group(gid)
            mesh.active_gid = gid      # watchdog target for a hung stage
            ist0 = {k: group.driver.stats.get(k, 0)
                    for k in ("dma_retry", "dma_crc_mismatch")} \
                if platform is not None else None
            try:
                # stage busy time starts at ticket redemption: a group
                # whose inbound transfers stall (congested link, sick
                # endpoint) is slow in a way its compute alone won't
                # show — the fleet's straggler EWMA must see it
                t0 = time.perf_counter()
                stage_in = {s: feed[s] for s in tile.input_syms
                            if s in feed}
                for sym in tile.cut_ins:
                    t = tickets.pop((sym, gid), None)
                    if t is None:              # failover: re-stream from
                        src = next(           # the producer's buffer
                            e.src for e in part.edges
                            if e.sym == sym and e.dst == tile.gid)
                        t = mesh.stream(sym, env[sym], src, gid)
                    stage_in[sym] = group.driver.dma_wait(t) \
                        if type(t) is DmaTicket else t
                bound_t = tile.bind(
                    group.driver, rimfs,
                    # no image at hand: the original bind already
                    # resolved the weights — reuse those buffers
                    weights=None if rimfs is not None else
                    {s: feed[s] for s in tile.weight_syms if s in feed})
                result = Executor(driver=group.driver).run(
                    bound_t, inputs=stage_in)
                stage_dt = time.perf_counter() - t0
                if stage_times is not None:
                    # per-stage busy time (occupancy accounting for the
                    # benchmark's bubble-fraction column)
                    stage_times.append((gid, stage_dt))
                if ist0 is not None:
                    # corruptions the driver caught + retried this stage
                    # surface as telemetry counters (DESIGN.md §11)
                    for key, kind in (("dma_retry", "dma_retry"),
                                      ("dma_crc_mismatch",
                                       "integrity_error")):
                        d = group.driver.stats.get(key, 0) - ist0[key]
                        if d:
                            platform.post(kind, {"n": d, "group": gid})
                break
            except TileFailure:
                tried.add(gid)
                mesh.active_gid = None
                if rimfs is not None:
                    # post-mortem integrity sweep: a tile-group death may
                    # have interrupted a write-side path — re-verify the
                    # store's CRCs before any survivor re-binds from it
                    rimfs.fsck(strict=False)
                if platform is not None:
                    platform.post("tile_failure",
                                  {"group": gid, "stage": stage_idx})
                    if rimfs is not None:
                        platform.post("rimfs_fsck",
                                      {"phase": "tile_failure"})
                if platform is not None:
                    # liveness sweep: live groups answer the poll, the
                    # dead one cannot — the deadline policy judges
                    for g2 in mesh.gids:
                        if mesh.alive(g2):
                            hb.beat(f"tile{g2}", stage_idx)
                    verdict = hb.check()
                    platform.post("worker_failed",
                                  {"workers": verdict["failed"],
                                   "stage": stage_idx})
                survivors = [g2 for g2 in mesh.gids
                             if mesh.alive(g2) and g2 not in tried]
                if not survivors:
                    raise
                if platform is not None:
                    platform.post("stage_requeued",
                                  {"stage": stage_idx, "from": gid,
                                   "to": survivors[0]})
                gid = survivors[0]
        for sym in tile.output_syms:
            if sym in result:
                outs[sym] = result[sym]
        for edge in part.edges_from(tile.gid):
            buf = result.get(edge.sym)
            if buf is None:
                continue
            env[edge.sym] = buf                # retained for re-streams
            if mesh.alive(edge.dst):
                try:                           # issue NOW, redeem at use
                    tickets[(edge.sym, edge.dst)] = mesh.stream(
                        edge.sym, buf, gid, edge.dst)
                except TileFailure:
                    pass                       # consumer re-queues later
        mesh.active_gid = None
        if hb is not None:
            hb.beat(f"tile{gid}", stage_idx + 1)
        if platform is not None:
            # per-group busy seconds feed the fleet controller's stage
            # EWMA (straggler verdicts for partial reshapes, §14)
            platform.post("stage_complete",
                          {"stage": stage_idx, "group": gid,
                           "seconds": stage_dt})
    return outs


# ---------------------------------------------------------------------------
# Streaming pipeline fill (batch of independent inputs over the tile array)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Sample:
    """One in-flight input's pipeline state."""
    idx: int
    feed: dict
    stage: int = 0
    tickets: dict = dataclasses.field(
        default_factory=dict)            # (sym, dst_gid) -> in-flight ticket
    outs: dict = dataclasses.field(default_factory=dict)


def execute_stream(part: PartitionedProgram, mesh: TileMesh,
                   inputs_iter: Iterable, rimfs=None, depth: int = 4,
                   fused: bool = True,
                   stats: Optional[dict] = None) -> Iterator[dict]:
    """Software-pipeline a STREAM of inputs over the partitioned schedule.

    ``execute`` runs one sample through all stages back-to-back, so with
    G groups every group idles G-1/G of the time — the negative scaling
    the partition benchmark's latency-mode rows show. This driver keeps
    the array full instead: per clock tick every in-flight sample
    advances exactly one stage, so group *g* runs sample *i* while group
    *g+1* runs sample *i−1* — the paper's layer-pipelined dataflow
    shape, AIE4ML-style.

    With ``fused=True`` (default) each tile stage executes as ONE staged
    XLA dispatch (``Executor.fuse`` of the tile subprogram, cached on the
    tile's BoundProgram) instead of the per-op linked thunk loop — the
    per-op dispatch fixed cost is paid once per *stage*, which is what
    lets the pipelined stream beat the single-device linked loop on a
    host where tile compute shares cores with dispatch. ``fused=False``
    keeps the linked path (full driver vtable semantics: arena
    accounting, per-op stats, fault injection at every op). Both modes
    are bit-identical to serial execution (tests/test_conformance.py).

    Cut-edge tensors stay split-phase and become **double-buffered**: the
    ticket group *g* issued for sample *i* this tick coexists with the
    ticket sample *i−1* redeems at group *g+1* next tick, one in flight
    per (edge, sample) — never redeemed before the consuming stage
    starts, so the inter-tile stream always rides under compute.

    ``depth`` bounds in-flight samples (admission is one per tick, so the
    pipeline fills gradually and never holds more than ``depth`` samples'
    buffers); ``depth >= part.n_groups`` keeps every group busy at steady
    state. Outputs yield lazily in submission order — a slow consumer
    back-pressures admission naturally because the generator only
    advances between ``next()`` calls.

    ``stats`` (optional dict) is filled with per-group busy seconds
    (``busy`` — host time inside each stage's dispatch, including any
    sync the stage performs), tick and sample counts — occupancy =
    busy/wall is the benchmark's per-stage bubble accounting. Tile
    failures propagate as ``TileFailure`` (stream mode has no re-queue
    path: a re-queued middle stage would reorder the stream's cut-edge
    tickets; callers needing elasticity run ``execute`` per sample under
    a Platform).
    """
    from repro.core.executor import Executor   # local: avoids import cycle
    if mesh.n_groups < part.n_groups:
        raise ValueError(f"mesh has {mesh.n_groups} groups, partition "
                         f"needs {part.n_groups}")
    if depth < 1:
        raise ValueError(f"in-flight depth must be >= 1, got {depth}")
    if stats is None:
        stats = {}
    stats.update({"busy": {t.gid: 0.0 for t in part.tiles},
                  "ticks": 0, "samples": 0, "depth": depth,
                  "fused": fused})
    base = part.bound.buffers
    executors = {t.gid: Executor(driver=mesh.group(t.gid).driver)
                 for t in part.tiles}
    # per-stage static schedule (hoisted out of the per-sample hot loop)
    edges_by_gid = {t.gid: part.edges_from(t.gid) for t in part.tiles}
    base_weights = None if rimfs is not None else \
        [{w: base[w] for w in t.weight_syms if w in base}
         for t in part.tiles]
    stage_fns = None
    if fused:
        # one staged executable + weight feed per stage, resolved before
        # the first sample is admitted (cached across streams on the
        # tile's BoundProgram via Executor.fuse)
        stage_fns = []
        for idx, tile in enumerate(part.tiles):
            bt = tile.bind(mesh.group(tile.gid).driver, rimfs,
                           weights=None if rimfs is not None
                           else base_weights[idx])
            fn = executors[tile.gid].fuse(bt)
            stage_fns.append((fn, executors[tile.gid].weights_from(bt)))
    busy = stats["busy"]
    n_stages = len(part.tiles)
    it = iter(inputs_iter)
    inflight: collections.deque = collections.deque()
    next_idx = 0
    exhausted = False
    while True:
        if not exhausted and len(inflight) < depth:
            try:
                inputs = next(it)
            except StopIteration:
                exhausted = True
            else:
                feed = dict(inputs) if inputs else {}
                for sym in part.bound.missing_inputs:
                    if sym not in feed and sym not in base:
                        raise ValueError(f"missing input {sym!r} "
                                         f"(stream sample {next_idx})")
                inflight.append(_Sample(next_idx, feed))
                next_idx += 1
                stats["samples"] += 1
        if not inflight:
            return
        stats["ticks"] += 1
        # One clock tick. Every sample consumes only tickets issued in a
        # PREVIOUS tick, so in-tick order is correctness-free — newest
        # first is chosen so the synchronizing tail of the pipeline (a
        # final-stage FENCE, the consumer's D2H materialization) runs
        # AFTER the younger samples' compute has been dispatched: the
        # sync then overlaps real work instead of stalling admission.
        for s in reversed(inflight):
            tile = part.tiles[s.stage]
            gid = tile.gid
            group = mesh.group(gid)
            feed = s.feed
            stage_in = {}
            for sym in tile.input_syms:
                v = feed.get(sym)
                if v is None:
                    v = base.get(sym)
                if v is not None:
                    stage_in[sym] = v
            for sym in tile.cut_ins:
                t = s.tickets.pop((sym, gid))
                stage_in[sym] = group.driver.dma_wait(t) \
                    if type(t) is DmaTicket else t
            if stage_fns is not None:
                fn, w = stage_fns[s.stage]
                t0 = time.perf_counter()
                result = fn(stage_in, w)
                busy[gid] += time.perf_counter() - t0
            else:
                bound_t = tile.bind(
                    group.driver, rimfs,
                    weights=None if rimfs is not None else
                    base_weights[s.stage])
                t0 = time.perf_counter()
                result = executors[gid].run(bound_t, inputs=stage_in)
                busy[gid] += time.perf_counter() - t0
            for sym in tile.output_syms:
                if sym in result:
                    s.outs[sym] = result[sym]
            for edge in edges_by_gid[gid]:
                buf = result.get(edge.sym)
                if buf is not None:
                    s.tickets[(edge.sym, edge.dst)] = mesh.stream(
                        edge.sym, buf, gid, edge.dst)
            s.stage += 1
        while inflight and inflight[0].stage >= n_stages:
            done = inflight.popleft()
            yield done.outs
