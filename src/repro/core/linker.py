"""Program linker — the compiled RCB dispatch + data-movement path.

The interpreted executor re-decodes every op on every step: a ~15-branch
if/elif chain, symbolic dict lookups for each operand, a liveness probe per
source — per-op fixed costs of exactly the kind the paper's control-as-data
design eliminates.  The linker pays all of them ONCE, at bind time:

  * every symbolic tensor ref resolves to an index into a dense slot array
    (no dict probes in the hot loop);
  * every opcode resolves to a pre-specialized handler through the RHAL
    ``link_compute`` vtable slot (for the eager driver: a per-site jitted
    executable dispatched asynchronously — XLA's cached fast path);
  * every scratch release point is baked in as a precomputed free-list
    (tuple of slot indices cleared right after the op that last reads them);
  * every transfer is scheduled by a static **residency plan**
    (``plan_residency``): device-resident symbols get arena offsets from a
    simulated first-fit allocation over the RBL liveness intervals, H2D
    transfers whose source is live at program entry are hoisted into a
    **prefetch prologue** (issued split-phase through the RHAL ``dma_async``
    slot before the first compute dispatch), and D2H transfers nothing
    re-reads are sunk into a **drain epilogue** — so transfers of ops k±1
    overlap op k's compute.  Blocking drivers (no ``dma_async``) keep the
    per-op initiate/wait pair.

The result is a ``LinkedProgram`` whose execution is
``prologue; for thunk in thunks: thunk(slots, rimfs); epilogue`` — see
Executor.run — and whose thunks are equally traceable under ``jax.jit``
(Executor.fuse stages the same linked form through the trace driver).
DESIGN.md §4 and §6 have the full contract.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Optional

from repro.core import rbl as rbl_mod
from repro.core.rcb import Op, RCBProgram
from repro.core.rhal import (ARENA_ALIGN, DeviceArena, DmaTicket, _nbytes_of)


@dataclasses.dataclass(frozen=True)
class ThunkMeta:
    """Side-table entry describing one thunk (tracing / probing only —
    the hot loop never reads this)."""
    block_id: int
    op: Op
    dst_slots: tuple
    dst_names: tuple


@dataclasses.dataclass(frozen=True)
class ResidencyPlan:
    """Static buffer-residency + transfer schedule for one LinkedProgram.

    Computed once at link time from the RBL liveness intervals — never per
    dispatch (DESIGN.md §6). Offsets come from a simulated first-fit
    ``DeviceArena`` walk, so ``high_water`` is exactly the peak the arena
    would reach replaying the program's alloc/free sequence.
    """
    offsets: dict            # device-resident symbol -> arena offset
    sizes: dict              # symbol -> aligned nbytes
    high_water: int          # peak arena bytes over the program
    arena_align: int
    prefetch_syms: tuple     # DMA_H2D dsts issued in the prologue
    drain_syms: tuple        # DMA_D2H dsts redeemed in the epilogue
    donated: tuple           # scratch syms whose dead range a later alloc reuses
    bytes_moved: int         # total DMA payload bytes per execution
    bytes_overlapped: int    # bytes issued split-phase (overlap-eligible)


def plan_residency(bound: rbl_mod.BoundProgram) -> ResidencyPlan:
    """Simulate device residency over the linear op stream.

    Weights pin at offset order 0..n at program entry (the RIMFS residency
    set); scratch/output ranges allocate at first definition and scratch
    frees at last read (the same schedule the thunk free-lists apply);
    outputs stay live to program exit.  Host-side symbols (inputs and
    DMA_D2H destinations) never enter the arena.
    """
    prog = bound.program
    last_use = bound.last_use
    ops = list(prog.ops())

    d2h_dsts = {op.dsts[0] for op in ops if op.op is Op.DMA_D2H}
    written_before: set = set()
    prefetch, drain = [], []
    bytes_moved = bytes_overlapped = 0
    for i, op in enumerate(ops):
        if op.op in (Op.DMA_H2D, Op.DMA_D2H, Op.DMA_D2D):
            t = prog.tensors.get(op.srcs[0])
            nbytes = _nbytes_of(t.shape, t.dtype) if t is not None else 0
            bytes_moved += nbytes
            if op.op is Op.DMA_H2D and op.srcs[0] not in written_before:
                # source is live at program entry -> issue in the prologue
                prefetch.append(op.dsts[0])
                bytes_overlapped += nbytes
            elif op.op is Op.DMA_D2H and last_use.get(op.dsts[0], -1) <= i:
                # nothing re-reads the host copy -> redeem at the drain
                drain.append(op.dsts[0])
                bytes_overlapped += nbytes
        written_before.update(op.dsts)

    def resident(sym: str) -> bool:
        t = prog.tensors.get(sym)
        return (t is not None and t.kind != "input" and sym not in d2h_dsts)

    sizes = {n: _nbytes_of(t.shape, t.dtype)
             for n, t in prog.tensors.items() if resident(n)}
    total = sum(max(ARENA_ALIGN, ((s + ARENA_ALIGN - 1) // ARENA_ALIGN)
                    * ARENA_ALIGN) for s in sizes.values())
    arena = DeviceArena(max(total, ARENA_ALIGN) + ARENA_ALIGN)
    offsets: dict[str, int] = {}
    freed_at: dict[str, tuple] = {}      # sym -> (offset, size, op index)
    donated: list = []
    for name, t in prog.tensors.items():             # weights pin first
        if t.kind == "weight" and resident(name):
            offsets[name] = arena.alloc(sizes[name])
    frees_by_idx = rbl_mod.scratch_free_lists(prog, last_use)
    for i, op in enumerate(ops):
        for dst in op.dsts:
            if op.op is not Op.FREE and resident(dst) \
                    and dst not in offsets:
                off = arena.alloc(sizes[dst])
                offsets[dst] = off
                for sym, (foff, fsz, fidx) in freed_at.items():
                    if sym not in donated and fidx < i \
                            and off < foff + fsz \
                            and foff < off + sizes[dst]:
                        donated.append(sym)          # dead range reused
        released = list(frees_by_idx[i])
        if op.op is Op.FREE and op.dsts[0] in offsets:
            released.append(op.dsts[0])
        for sym in released:
            if sym in offsets and sym not in freed_at:
                arena.free(offsets[sym])
                freed_at[sym] = (offsets[sym], arena._round(sizes[sym]), i)
    return ResidencyPlan(offsets, sizes, arena.high_water, ARENA_ALIGN,
                         tuple(prefetch), tuple(drain), tuple(donated),
                         bytes_moved, bytes_overlapped)


@dataclasses.dataclass
class LinkedProgram:
    """A BoundProgram lowered to positional, pre-resolved form."""
    program: RCBProgram
    driver: Any
    slot_of: dict                  # symbol -> dense slot index
    names: list                    # slot index -> symbol
    thunks: list                   # thunk(slots, rimfs) -> None
    metas: list                    # list[ThunkMeta], parallel to thunks
    block_spans: list              # (block_id, thunk_start, thunk_end)
    input_slots: dict              # input symbol -> slot
    weight_slots: dict             # weight symbol -> slot
    output_slots: tuple            # (symbol, slot) pairs
    missing_inputs: tuple          # (symbol, slot) the caller must feed
    free_lists: tuple              # per-thunk tuple of slot indices released
    n_compute: int                 # compute dispatches (bulk stats update)
    residency: Optional[ResidencyPlan] = None
    prologue: tuple = ()           # prefetch issue thunks (run before thunks)
    epilogue: tuple = ()           # drain redeem thunks (run after thunks)

    @property
    def n_slots(self) -> int:
        return len(self.names)

    def fresh_slots(self, buffers: dict,
                    inputs: Optional[dict] = None) -> list:
        """Dense buffer array for one execution."""
        slots: list = [None] * len(self.names)
        slot_of = self.slot_of
        for sym, buf in buffers.items():
            slots[slot_of[sym]] = buf
        if inputs:
            for sym, buf in inputs.items():
                i = slot_of.get(sym)
                if i is not None:
                    slots[i] = buf
        return slots


def _mk_compute(handler: Callable, d: int, src_idx: tuple, frees: tuple):
    """Compute thunk factory, arity-specialized for the hot loop."""
    if len(src_idx) == 1:
        (i0,) = src_idx

        def thunk(slots, rimfs):
            slots[d] = handler(slots[i0])
            for f in frees:
                slots[f] = None
    elif len(src_idx) == 2:
        i0, i1 = src_idx

        def thunk(slots, rimfs):
            slots[d] = handler(slots[i0], slots[i1])
            for f in frees:
                slots[f] = None
    elif len(src_idx) == 3:
        i0, i1, i2 = src_idx

        def thunk(slots, rimfs):
            slots[d] = handler(slots[i0], slots[i1], slots[i2])
            for f in frees:
                slots[f] = None
    else:
        def thunk(slots, rimfs):
            slots[d] = handler(*[slots[i] for i in src_idx])
            for f in frees:
                slots[f] = None
    return thunk


@dataclasses.dataclass(frozen=True)
class BatchAnalysis:
    """Verdict of the per-program batch-axis analysis (DESIGN.md §9)."""
    batchable: bool
    reason: str


def batch_analysis(bound: rbl_mod.BoundProgram) -> BatchAnalysis:
    """Decide whether a program can stage under a leading batch axis.

    The batched path executes the staged linked form under ``jax.vmap``
    (inputs mapped, weights broadcast), which is only sound for programs
    whose every op is a pure device computation per sample:

      * COLLECTIVE ops coordinate across a mesh axis — a vmapped replica
        would silently change the collective's participant set;
      * GRAPH_EXEC artifacts are opaque host callables compiled for one
        batch shape (and are not covered by the program CRC the staging
        cache keys on);
      * split-phase DMA (any H2D the residency plan hoists into the
        prefetch prologue, or D2H it sinks into the drain epilogue)
        carries per-execution host-side ticket state — the host engine
        moves ONE buffer per descriptor, not a batch-of-N.

    Everything else (compute dispatches, ALLOC/FREE, BIND_CONST, FENCE,
    POLL, non-split-phase transfers) stages cleanly. The verdict is
    cached on the BoundProgram; callers get serial fallback, not an
    error, when it is negative (Executor.run_batched).
    """
    cached = getattr(bound, "_batch_analysis", None)
    if cached is not None:
        return cached

    def analyze() -> BatchAnalysis:
        for op in bound.program.ops():
            if op.op is Op.COLLECTIVE:
                return BatchAnalysis(False, "COLLECTIVE op (mesh-axis "
                                     "semantics do not vmap)")
            if op.op is Op.GRAPH_EXEC:
                return BatchAnalysis(False, "GRAPH_EXEC artifact (opaque "
                                     "host callable, fixed batch shape)")
        plan = plan_residency(bound)
        if plan.prefetch_syms or plan.drain_syms:
            syms = (plan.prefetch_syms + plan.drain_syms)[:3]
            return BatchAnalysis(False, "host split-phase DMA (prefetch/"
                                 f"drain schedule over {list(syms)})")
        return BatchAnalysis(True, "batchable")

    verdict = analyze()
    bound._batch_analysis = verdict
    return verdict


def stage_callable(linked: LinkedProgram):
    """The staged form of a linked program: ``fn(inputs, weights) -> outs``.

    This is the function ``Executor.fuse`` jits into one XLA program, and
    the function ``Executor.run_batched`` wraps in ``jax.vmap`` (inputs
    mapped over a leading batch axis, weights broadcast) before jitting a
    per-bucket executable. Built from a TRACE-driver link, it performs no
    device work of its own — everything stays symbolic until XLA runs it.
    """
    weight_slots = linked.weight_slots
    input_slots = linked.input_slots
    thunks = linked.thunks
    output_slots = linked.output_slots
    n_slots = linked.n_slots
    prologue = linked.prologue
    epilogue = linked.epilogue

    def staged(inputs: dict, weights: dict) -> dict:
        slots: list = [None] * n_slots
        for k, i in weight_slots.items():
            slots[i] = weights[k]
        for k, i in input_slots.items():
            slots[i] = inputs[k]
        for pre in prologue:
            pre(slots, None)
        for thunk in thunks:
            thunk(slots, None)
        for epi in epilogue:
            epi(slots, None)
        return {name: slots[i] for name, i in output_slots
                if slots[i] is not None}

    return staged


def link(bound: rbl_mod.BoundProgram, driver,
         artifacts: Optional[dict] = None) -> LinkedProgram:
    """Lower a BoundProgram into a LinkedProgram against one driver.

    Linking is pure resolution — no device work happens here (the eager
    driver's per-site jits trace lazily on first execution; DMA issue
    happens when the prologue runs, not when it is built).
    """
    prog = bound.program
    names = list(prog.tensors.keys())
    slot_of = {n: i for i, n in enumerate(names)}
    frees_by_idx = rbl_mod.scratch_free_lists(prog, bound.last_use)
    link_compute = driver.link_compute
    artifacts = {**prog.artifacts, **(artifacts or {})}
    plan = plan_residency(bound)
    use_async = driver.dma_async is not None and driver.dma_wait is not None
    if not use_async:
        # blocking driver: nothing issues split-phase, so the attached
        # plan must not advertise overlap this link will never execute
        plan = dataclasses.replace(plan, prefetch_syms=(), drain_syms=(),
                                   bytes_overlapped=0)
    prefetch_syms = set(plan.prefetch_syms)
    drain_syms = set(plan.drain_syms)
    dma_async, dma_redeem = driver.dma_async, driver.dma_wait

    thunks: list = []
    metas: list = []
    block_spans: list = []
    prefetch_entries: list = []                    # (dst_slot, src_slot, sym)
    epilogue: list = []
    n_compute = 0
    free_lists: list = []
    idx = 0                                        # linear op index
    for block in prog.blocks:
        start = len(thunks)
        for op in block.ops:
            kind = op.op
            frees = tuple(slot_of[s] for s in frees_by_idx[idx])
            idx += 1
            if kind is Op.NOP or kind is Op.HALT:
                continue                           # zero dispatch cost
            dslots = tuple(slot_of[d] for d in op.dsts)
            sslots = tuple(slot_of[s] for s in op.srcs)
            attrs = op.attrs
            if kind is Op.ALLOC:
                shape = tuple(attrs["shape"])
                dtype = attrs["dtype"]
                alloc = driver.alloc
                d = dslots[0]

                def thunk(slots, rimfs, _a=alloc, _d=d, _sh=shape,
                          _dt=dtype):
                    slots[_d] = _a(_sh, _dt)
            elif kind is Op.FREE:
                free = driver.free
                d = dslots[0]

                def thunk(slots, rimfs, _f=free, _d=d):
                    _f(slots[_d])
                    slots[_d] = None
            elif kind is Op.BIND_CONST:
                bind_const = driver.bind_const
                value = attrs["value"]
                d = dslots[0]

                def thunk(slots, rimfs, _b=bind_const, _d=d, _v=value):
                    slots[_d] = _b(_v)
            elif kind is Op.DMA_H2D:
                d, s, sname = dslots[0], sslots[0], op.srcs[0]
                if use_async and op.dsts[0] in prefetch_syms:
                    # split phase: issue in the prologue (before the first
                    # compute dispatch), redeem the ticket at the op site —
                    # the transfer rides under every dispatch in between.
                    prefetch_entries.append((d, s, sname))

                    def thunk(slots, rimfs, _w=dma_redeem, _ia=dma_async,
                              _d=d, _s=s, _n=sname, _fr=frees):
                        t = slots[_d]
                        if type(t) is DmaTicket:
                            slots[_d] = _w(t)
                        else:                      # prologue skipped
                            host = slots[_s]
                            if host is None and rimfs is not None:
                                host = rimfs.read(_n)
                            slots[_d] = _w(_ia(host, "h2d"))
                        for f in _fr:
                            slots[f] = None
                elif use_async:
                    def thunk(slots, rimfs, _w=dma_redeem, _ia=dma_async,
                              _d=d, _s=s, _n=sname, _fr=frees):
                        host = slots[_s]
                        if host is None and rimfs is not None:
                            host = rimfs.read(_n)
                        slots[_d] = _w(_ia(host, "h2d"))
                        for f in _fr:
                            slots[f] = None
                else:
                    initiate, wait = driver.initiate_dma, driver.wait_dma

                    def thunk(slots, rimfs, _i=initiate, _w=wait, _d=d,
                              _s=s, _n=sname, _fr=frees):
                        host = slots[_s]
                        if host is None and rimfs is not None:
                            host = rimfs.read(_n)
                        slots[_d] = _w(_i(host, "h2d"))
                        for f in _fr:
                            slots[f] = None
            elif kind is Op.DMA_D2H and use_async \
                    and op.dsts[0] in drain_syms:
                d, s = dslots[0], sslots[0]
                # issue here, redeem in the epilogue: the device->host copy
                # of op k-1 completes under op k's compute.
                def thunk(slots, rimfs, _ia=dma_async, _d=d, _s=s,
                          _fr=frees):
                    slots[_d] = _ia(slots[_s], "d2h", prefetched=True)
                    for f in _fr:
                        slots[f] = None

                def epi(slots, rimfs, _w=dma_redeem, _d=d):
                    t = slots[_d]
                    if type(t) is DmaTicket:
                        slots[_d] = _w(t)
                epilogue.append(epi)
            elif kind is Op.DMA_D2H or kind is Op.DMA_D2D:
                direction = "d2h" if kind is Op.DMA_D2H else "d2d"
                d, s = dslots[0], sslots[0]
                if use_async:
                    def thunk(slots, rimfs, _w=dma_redeem, _ia=dma_async,
                              _d=d, _s=s, _dir=direction, _fr=frees):
                        slots[_d] = _w(_ia(slots[_s], _dir))
                        for f in _fr:
                            slots[f] = None
                else:
                    initiate, wait = driver.initiate_dma, driver.wait_dma

                    def thunk(slots, rimfs, _i=initiate, _w=wait, _d=d,
                              _s=s, _dir=direction, _fr=frees):
                        slots[_d] = _w(_i(slots[_s], _dir))
                        for f in _fr:
                            slots[f] = None
            elif kind is Op.GRAPH_EXEC:
                fn = artifacts.get(attrs["artifact"])
                if fn is None:
                    raise KeyError(
                        f"GRAPH_EXEC artifact {attrs['artifact']!r} "
                        f"not attached")
                if len(dslots) == 1:
                    d = dslots[0]

                    def thunk(slots, rimfs, _f=fn, _d=d, _s=sslots,
                              _fr=frees):
                        slots[_d] = _f(*[slots[i] for i in _s])
                        for f in _fr:
                            slots[f] = None
                else:
                    def thunk(slots, rimfs, _f=fn, _ds=dslots, _s=sslots,
                              _fr=frees):
                        outs = _f(*[slots[i] for i in _s])
                        for d, o in zip(_ds, outs):
                            slots[d] = o
                        for f in _fr:
                            slots[f] = None
            elif kind is Op.COLLECTIVE:
                coll = driver.collective
                ckind = attrs.get("kind", "all_reduce")
                d, s = dslots[0], sslots[0]

                def thunk(slots, rimfs, _c=coll, _k=ckind, _d=d, _s=s,
                          _at=attrs, _fr=frees):
                    slots[_d] = _c(_k, slots[_s], _at)
                    for f in _fr:
                        slots[f] = None
            elif kind is Op.FENCE:
                fence = driver.fence

                def thunk(slots, rimfs, _f=fence):
                    _f([b for b in slots
                        if b is not None and type(b) is not DmaTicket])
            elif kind is Op.POLL:
                poll = driver.poll
                s = sslots[0] if sslots else None

                def thunk(slots, rimfs, _p=poll, _s=s):
                    _p(slots[_s] if _s is not None else None)
            else:                                  # compute dispatch
                if link_compute is not None:
                    # (opcode, attrs) sites repeat across layers, tiles of
                    # a partitioned program, and re-links after elasticity
                    # events — resolve each distinct site ONCE per driver
                    key = (int(kind), json.dumps(attrs, sort_keys=True,
                                                 default=repr))
                    handler = driver.link_cache.get(key)
                    if handler is None:
                        handler = link_compute(kind, attrs)
                        driver.link_cache[key] = handler
                    # specialized handlers bypass dispatch_compute, so the
                    # executor bulk-updates the driver's dispatch stat;
                    # the fallback below counts itself per call
                    n_compute += 1
                else:
                    dispatch = driver.dispatch_compute

                    def handler(*srcs, _dc=dispatch, _k=kind, _at=attrs):
                        return _dc(_k, list(srcs), _at)
                thunk = _mk_compute(handler, dslots[0], sslots, frees)
            if frees and kind in (Op.ALLOC, Op.FREE, Op.BIND_CONST,
                                  Op.FENCE, Op.POLL):
                # these thunks don't apply free-lists themselves, but a POLL
                # can be a scratch symbol's last reader — chain the release
                # so linked matches the interpreted liveness plan.  (NOP/
                # HALT read nothing, so their frees are always empty.)
                inner = thunk

                def thunk(slots, rimfs, _i=inner, _fr=frees):
                    _i(slots, rimfs)
                    for f in _fr:
                        slots[f] = None
            thunks.append(thunk)
            metas.append(ThunkMeta(block.block_id, kind, dslots, op.dsts))
            free_lists.append(frees)
        block_spans.append((block.block_id, start, len(thunks)))

    prologue: list = []
    if prefetch_entries:
        batch = driver.dma_async_batch
        if batch is not None:
            # the whole prefetch stream issues under ONE engine call: n
            # transfers, one descriptor (paper §5.3 batching)
            def pro(slots, rimfs, _ia=batch, _es=tuple(prefetch_entries)):
                hosts = []
                for _, s_, n_ in _es:
                    host = slots[s_]
                    if host is None and rimfs is not None:
                        host = rimfs.read(n_)
                    hosts.append(host)
                for (d_, _, _), t in zip(_es, _ia(hosts, "h2d",
                                                  prefetched=True)):
                    slots[d_] = t
            prologue.append(pro)
        else:
            for d_, s_, n_ in prefetch_entries:
                def pro(slots, rimfs, _ia=dma_async, _d=d_, _s=s_, _n=n_):
                    host = slots[_s]
                    if host is None and rimfs is not None:
                        host = rimfs.read(_n)
                    slots[_d] = _ia(host, "h2d", prefetched=True)
                prologue.append(pro)

    input_slots = {n: slot_of[n] for n, t in prog.tensors.items()
                   if t.kind == "input"}
    weight_slots = {n: slot_of[n] for n, t in prog.tensors.items()
                    if t.kind == "weight"}
    output_slots = tuple((n, slot_of[n]) for n, t in prog.tensors.items()
                         if t.kind == "output")
    missing = tuple((n, slot_of[n]) for n in bound.missing_inputs)
    return LinkedProgram(prog, driver, slot_of, names, thunks, metas,
                         block_spans, input_slots, weight_slots,
                         output_slots, missing, tuple(free_lists),
                         n_compute, plan, tuple(prologue), tuple(epilogue))
