"""RCTC — the offline toolchain (forward translation / data packaging /
mapping generation).

Mirrors the paper's three toolchain functions:

  1. **Forward translation** — network descriptions (ResNet-18 stages, small
     pipelines, LM serve/train graphs) flatten into symbolic RCB op
     sequences. Fine-grained programs (one op per conv/relu/... — the AIE
     kernel granularity) serve the case study and microbenchmarks; LM-scale
     workloads translate to provisioning/bind/dispatch RCBs around
     GRAPH_EXEC artifacts, exactly like the paper ingests *compiled ADF
     graph artifacts* rather than re-lowering kernels.
  2. **Data packaging** — weights flatten into a RIMFS image (binary blob).
  3. **Mapping generation** — TensorDescs carry logical shapes/axes that the
     RBL resolves to physical buffers/shardings at load time.

Before emission, translated programs run through the peephole pass
(core/opt.py): fused SCALE_SHIFT_RELU / ADD_RELU slots, dead-scratch
elimination, exact quantize round-trip elision and copy coalescing —
``optimize=False`` emits the raw 1:1 translation (the benchmark baseline).
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.configs.resnet18 import ResNetConfig
from repro.core import opt as opt_mod
from repro.core import rimfs as rimfs_mod
from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc
from repro.models import resnet as resnet_mod


class _Builder:
    """Incremental RCB program builder."""

    def __init__(self, name: str):
        self.name = name
        self.tensors: dict[str, TensorDesc] = {}
        self.blocks: list[RCB] = []
        self._ops: list[RCBOp] = []
        self._bid = 0
        self._uniq = 0

    def tensor(self, name, shape, dtype, kind, axes=()):
        self.tensors[name] = TensorDesc(name, tuple(shape), dtype, kind,
                                        tuple(axes))
        return name

    def scratch(self, shape, dtype, hint="t"):
        self._uniq += 1
        return self.tensor(f"{hint}.{self._uniq}", shape, dtype, "scratch")

    def emit(self, op: Op, dsts=(), srcs=(), **attrs):
        self._ops.append(RCBOp(op, tuple(dsts), tuple(srcs), attrs))

    def close_block(self, block_type="layer", deps="prev"):
        if not self._ops:
            return
        if deps == "prev":
            deps = (self._bid - 1,) if self._bid > 0 else ()
        self.blocks.append(RCB(self._bid, block_type, tuple(deps),
                               tuple(self._ops)))
        self._bid += 1
        self._ops = []

    def build(self, artifacts: Optional[dict] = None) -> RCBProgram:
        self.close_block()
        prog = RCBProgram(self.name, self.tensors, self.blocks,
                          artifacts or {})
        prog.validate()
        return prog


# ---------------------------------------------------------------------------
# Microbenchmark programs (paper §3.4: pass-through and 64x64 matmul)
# ---------------------------------------------------------------------------

def compile_passthrough(shape, dtype="float32") -> RCBProgram:
    b = _Builder("passthrough")
    b.tensor("input", shape, dtype, "input")
    b.tensor("output", shape, dtype, "output")
    b.emit(Op.PASSTHROUGH, ["output"], ["input"])
    b.emit(Op.FENCE)
    return b.build()


def compile_transfer_chain(n: int, block_shape, dtype="float32") -> RCBProgram:
    """n independent block transfers flattened into ONE control stream —
    the Table 1 "baremetal" side: per-transfer control cost paid once for
    the whole stream instead of once per block."""
    b = _Builder(f"chain_{n}")
    for i in range(n):
        b.tensor(f"in{i}", block_shape, dtype, "input")
        b.tensor(f"out{i}", block_shape, dtype, "output")
        b.emit(Op.PASSTHROUGH, [f"out{i}"], [f"in{i}"])
    b.emit(Op.FENCE)
    return b.build()


def compile_matmul(n=64, dtype="float32", with_dma: bool = False) -> RCBProgram:
    """64x64 XGEMM (paper §3.4). ``with_dma`` adds explicit input/output
    DMA stages so the Table 4 breakdown (input transfer / kernel exec /
    output transfer) is measurable per op."""
    b = _Builder(f"xgemm_{n}")
    b.tensor("a", (n, n), dtype, "input")
    b.tensor("b", (n, n), dtype, "weight")
    b.tensor("output", (n, n), dtype, "output")
    if with_dma:
        ad = b.scratch((n, n), dtype, "a_dev")
        b.emit(Op.DMA_H2D, [ad], ["a"])
        od = b.scratch((n, n), dtype, "o_dev")
        b.emit(Op.GEMM, [od], [ad, "b"])
        b.emit(Op.DMA_D2H, ["output"], [od])
    else:
        b.emit(Op.GEMM, ["output"], ["a", "b"])
    b.emit(Op.FENCE)
    return b.build()


def compile_dma_pipeline(n_stages: int, n: int = 64, dtype="float32",
                         with_dma: bool = True) -> RCBProgram:
    """Table 4 pipelining microbench: ``n_stages`` independent
    H2D -> GEMM -> D2H stages in one control stream.

    Under the blocking per-op path every stage pays the full transfer
    round-trip; under the residency plan the linker prefetches every H2D
    in the prologue and drains every D2H in the epilogue, so stage *k*'s
    transfers ride under stage *k±1*'s compute. ``with_dma=False`` emits
    the identical compute without the transfers — the subtraction that
    isolates data-movement overhead per mode."""
    b = _Builder(f"dma_pipeline_{n_stages}" + ("" if with_dma else "_nodma"))
    b.tensor("b", (n, n), dtype, "weight")
    for i in range(n_stages):
        b.tensor(f"in{i}", (n, n), dtype, "input")
        b.tensor(f"out{i}", (n, n), dtype, "output")
        if with_dma:
            dev = b.scratch((n, n), dtype, f"dev{i}")
            b.emit(Op.DMA_H2D, [dev], [f"in{i}"])
            acc = b.scratch((n, n), dtype, f"acc{i}")
            b.emit(Op.GEMM, [acc], [dev, "b"])
            b.emit(Op.DMA_D2H, [f"out{i}"], [acc])
        else:
            b.emit(Op.GEMM, [f"out{i}"], [f"in{i}", "b"])
        b.close_block("transfer")      # one block per stage: the layer
    b.emit(Op.FENCE)                   # granularity partition cuts at
    return b.build()


def compile_transfer_pipeline(n_blocks: int, floats: int,
                              dtype="float32") -> RCBProgram:
    """Table 5 pure data movement: ``n_blocks`` independent H2D->D2H block
    transfers in one control stream (no compute). Blocking per-op DMA pays
    2*n round-trips; the residency plan issues every H2D in one batched
    prologue and drains every D2H at the epilogue."""
    b = _Builder(f"transfer_pipeline_{n_blocks}")
    for i in range(n_blocks):
        b.tensor(f"in{i}", (floats,), dtype, "input")
        b.tensor(f"out{i}", (floats,), dtype, "output")
        dev = b.scratch((floats,), dtype, f"dev{i}")
        b.emit(Op.DMA_H2D, [dev], [f"in{i}"])
        b.emit(Op.DMA_D2H, [f"out{i}"], [dev])
        b.close_block("transfer")      # per-stage blocks (partition cuts)
    b.emit(Op.FENCE)
    return b.build()


def compile_gemm_chain(depth: int, n: int = 32,
                       dtype="float32") -> RCBProgram:
    """``depth`` chained GEMM->RELU layers, one RCB block per layer — the
    minimal multi-tile workload: every layer reads the previous layer's
    activation, so every block boundary the partition pass cuts at becomes
    a cut edge streamed over the tile mesh."""
    b = _Builder(f"gemm_chain_{depth}")
    b.tensor("input", (n, n), dtype, "input")
    x = "input"
    for i in range(depth):
        w = b.tensor(f"w{i}", (n, n), dtype, "weight")
        t = b.scratch((n, n), dtype, f"g{i}")
        b.emit(Op.GEMM, [t], [x, w])
        r = b.scratch((n, n), dtype, f"r{i}")
        b.emit(Op.RELU, [r], [t])
        x = r
        b.close_block()
    b.tensor("output", (n, n), dtype, "output")
    b.emit(Op.PASSTHROUGH, ["output"], [x])
    b.emit(Op.FENCE)
    return b.build()


def gemm_chain_weights(depth: int, n: int = 32, seed: int = 0) -> dict:
    """Matching weight files for ``compile_gemm_chain`` (RIMFS image
    payload; scaled to keep activations in a stable range)."""
    rng = np.random.RandomState(seed)
    return {f"w{i}": (rng.randn(n, n) / np.sqrt(n)).astype(np.float32)
            for i in range(depth)}


def compile_conv_relu_softmax(n=1, h=8, w=8, cin=3, cout=9) -> RCBProgram:
    """The paper's data-path correctness pipeline (Conv2D->ReLU->Softmax)."""
    b = _Builder("conv_relu_softmax")
    b.tensor("input", (n, h, w, cin), "float32", "input")
    b.tensor("w_conv", (3, 3, cin, cout), "float32", "weight")
    t1 = b.scratch((n, h, w, cout), "float32")
    b.emit(Op.CONV2D, [t1], ["input", "w_conv"], stride=(1, 1),
           padding="SAME")
    t2 = b.scratch((n, h, w, cout), "float32")
    b.emit(Op.RELU, [t2], [t1])
    t3 = b.scratch((n, cout), "float32")
    b.emit(Op.AVGPOOL_GLOBAL, [t3], [t2])
    b.tensor("output", (n, cout), "float32", "output")
    b.emit(Op.SOFTMAX, ["output"], [t3])
    return b.build()


# ---------------------------------------------------------------------------
# ResNet-18 forward translation (fp32 and INT8)
# ---------------------------------------------------------------------------

def _emit_conv_bn_relu(b: _Builder, x, wname, scale, shift, out_shape,
                       stride, relu=True, int8: Optional[dict] = None,
                       x_scale: float = 1.0):
    """One conv+foldedBN(+relu) stage; int8 mode quantizes around the conv."""
    if int8 is None:
        t = b.scratch(out_shape, "float32")
        b.emit(Op.CONV2D, [t], [x, wname], stride=(stride, stride),
               padding="SAME")
    else:
        xq = b.scratch(b.tensors[x].shape, "int8")
        b.emit(Op.QUANTIZE, [xq], [x], scale=x_scale)
        ti = b.scratch(out_shape, "int32")
        b.emit(Op.CONV2D_I8, [ti], [xq, wname], stride=(stride, stride),
               padding="SAME")
        t = b.scratch(out_shape, "float32")
        # requant: int32 * (x_scale * w_scale_per_channel), then +shift
        b.emit(Op.SCALE_SHIFT, [t], [ti, int8["requant_scale"],
                                     int8["zero"]])
    t2 = b.scratch(out_shape, "float32")
    b.emit(Op.SCALE_SHIFT, [t2], [t, scale, shift])
    if not relu:
        return t2
    t3 = b.scratch(out_shape, "float32")
    b.emit(Op.RELU, [t3], [t2])
    return t3


def compile_resnet18(cfg: ResNetConfig, folded: dict, batch: int = 1,
                     int8: Optional[dict] = None, optimize: bool = True):
    """Translate ResNet-18 into (RCBProgram, RIMFS image bytes).

    ``folded``: BN-folded weights from models/resnet.fold_bn.
    ``int8``: optional quantization pack from core/quant.quantize_resnet —
    {weights int8, requant scales, activation scales} (paper deploys INT8).
    ``optimize``: run the core/opt.py peephole pass (bit-exact rules only)
    before emission; False keeps the raw per-layer translation.
    """
    b = _Builder("resnet18_int8" if int8 else "resnet18")
    img = cfg.image_size
    files: dict[str, np.ndarray] = {}

    def weight(name, arr, dtype=None):
        arr = np.asarray(arr)
        files[name] = arr
        b.tensor(name, arr.shape, str(arr.dtype), "weight")
        return name

    def act_scale(name):
        return float(int8["act_scales"][name]) if int8 else 1.0

    wsrc = int8["weights"] if int8 else folded
    b.tensor("input", (batch, img, img, 3), "float32", "input")

    def conv_pack(prefix, key):
        w = weight(key, wsrc[key])
        scale = weight(key + ".bn_scale", folded[prefix + "_scale"])
        shift = weight(key + ".bn_shift", folded[prefix + "_shift"])
        pack = None
        if int8:
            pack = {
                "requant_scale": weight(key + ".rq",
                                        int8["requant"][key]),
                "zero": weight(key + ".zero",
                               np.zeros_like(int8["requant"][key])),
            }
        return w, scale, shift, pack

    # stem
    w, sc, sh, pk = conv_pack("stem_bn", "stem_conv")
    h = img // 2
    x = _emit_conv_bn_relu(b, "input", w, sc, sh, (batch, h, h,
                                                   cfg.stem_width), 2,
                           int8=pk, x_scale=act_scale("stem_conv"))
    b.close_block()
    if img >= 64:
        t = b.scratch((batch, h // 2, h // 2, cfg.stem_width), "float32")
        b.emit(Op.MAXPOOL, [t], [x], window=(3, 3), stride=(2, 2),
               padding="SAME")
        x = t
        h = h // 2
        b.close_block()

    cin = cfg.stem_width
    for si, (n_blocks, width) in enumerate(zip(cfg.stage_sizes,
                                               cfg.stage_widths)):
        for bi in range(n_blocks):
            pre = f"s{si}b{bi}_"
            stride = 2 if (bi == 0 and si > 0) else 1
            h_out = h // stride
            shp = (batch, h_out, h_out, width)
            res = x
            w1, sc1, sh1, pk1 = conv_pack(pre + "bn1", pre + "conv1")
            y = _emit_conv_bn_relu(b, x, w1, sc1, sh1, shp, stride,
                                   int8=pk1, x_scale=act_scale(pre + "conv1"))
            w2, sc2, sh2, pk2 = conv_pack(pre + "bn2", pre + "conv2")
            y = _emit_conv_bn_relu(b, y, w2, sc2, sh2, shp, 1, relu=False,
                                   int8=pk2, x_scale=act_scale(pre + "conv2"))
            if (pre + "proj") in folded:
                wp, scp, shp_, pkp = conv_pack(pre + "proj_bn", pre + "proj")
                res = _emit_conv_bn_relu(b, x, wp, scp, shp_, shp, stride,
                                         relu=False, int8=pkp,
                                         x_scale=act_scale(pre + "proj"))
            t = b.scratch(shp, "float32")
            b.emit(Op.ADD, [t], [y, res])
            t2 = b.scratch(shp, "float32")
            b.emit(Op.RELU, [t2], [t])
            x = t2
            h = h_out
            cin = width
            b.close_block()

    t = b.scratch((batch, cin), "float32")
    b.emit(Op.AVGPOOL_GLOBAL, [t], [x])
    fw = weight("fc_w", folded["fc_w"])
    fb = weight("fc_b", folded["fc_b"])
    t2 = b.scratch((batch, cfg.num_classes), "float32")
    b.emit(Op.DENSE, [t2], [t, fw, fb])
    b.tensor("output", (batch, cfg.num_classes), "float32", "output")
    b.emit(Op.SOFTMAX, ["output"], [t2])
    b.emit(Op.FENCE)
    prog = b.build()
    if optimize:
        prog = opt_mod.optimize(prog)
    image = rimfs_mod.pack(files)
    return prog, image


# ---------------------------------------------------------------------------
# LM service translation (compiled-graph artifacts, paper's ADF ingestion)
# ---------------------------------------------------------------------------

def compile_lm_service(cfg, batch: int, seq_len: int,
                       prefill_fn, decode_fn) -> RCBProgram:
    """Wrap jitted prefill/decode steps ("compiled ADF graph artifacts")
    into an RCB service program: bind -> dispatch(prefill) -> poll ->
    dispatch(decode) -> sync."""
    b = _Builder(f"lm_{cfg.name}")
    tok_shape = (batch, seq_len) if cfg.input_kind == "tokens" \
        else (batch, seq_len, cfg.d_model)
    b.tensor("params", (0,), "float32", "input")       # pytree passthrough
    b.tensor("tokens", tok_shape, "int32" if cfg.input_kind == "tokens"
             else cfg.dtype, "input", ("batch", None))
    b.tensor("cache", (0,), "float32", "scratch")
    b.tensor("first_logits", (batch, cfg.vocab_size), "float32", "output")
    b.emit(Op.GRAPH_EXEC, ["first_logits", "cache"], ["params", "tokens"],
           artifact="prefill")
    b.emit(Op.POLL, [], ["first_logits"])
    b.close_block("prefill")
    b.tensor("next_token", (batch, 1), "int32", "input", ("batch", None))
    b.tensor("pos", (batch,), "int32", "input", ("batch",))
    b.tensor("logits", (batch, cfg.vocab_size), "float32", "output")
    b.emit(Op.GRAPH_EXEC, ["logits", "cache"],
           ["params", "cache", "next_token", "pos"], artifact="decode")
    b.emit(Op.POLL, [], ["logits"])
    b.close_block("decode")
    return b.build({"prefill": prefill_fn, "decode": decode_fn})


def compile_paged_lm_service(cfg, batch: int, max_seq: int, block_size: int,
                             num_blocks: int, prefill_fn, decode_fn,
                             greedy: bool = True,
                             temperature: float = 1.0) -> RCBProgram:
    """Paged-KV LM service program (ISSUE 8's prefill/decode
    disaggregation): the KV pool is a scratch tensor with an explicit
    block axis (num_blocks + 1 rows — the last is the null block), and
    both GRAPH_EXEC artifacts take the per-batch int32 block-table tensor
    as a device input, addressing the pool inside the compiled graphs.
    The decode artifact samples on device (greedy/temperature baked into
    the program — and into its CRC, which keys the AOT executable cache)
    and returns the window's new tokens instead of logits.
    """
    b = _Builder(f"lm_paged_{cfg.name}")
    bps = (max_seq + block_size - 1) // block_size    # table width bound
    pool_shape = (cfg.num_layers, num_blocks + 1, block_size,
                  cfg.num_kv_heads, cfg.head_dim)
    b.tensor("params", (0,), "float32", "input")      # pytree passthrough
    b.tensor("pool_k", pool_shape, cfg.dtype, "scratch")
    b.tensor("pool_v", pool_shape, cfg.dtype, "scratch")
    b.tensor("tables", (batch, bps), "int32", "input", ("batch", None))
    b.tensor("tokens", (batch, max_seq), "int32", "input", ("batch", None))
    b.tensor("first_logits", (batch, cfg.vocab_size), "float32", "output")
    b.emit(Op.GRAPH_EXEC, ["first_logits", "pool_k", "pool_v"],
           ["params", "pool_k", "pool_v", "tokens", "tables"],
           artifact="paged_prefill", block_size=block_size)
    b.emit(Op.POLL, [], ["first_logits"])
    b.close_block("prefill")
    b.tensor("next_token", (batch,), "int32", "input", ("batch",))
    b.tensor("pos", (batch,), "int32", "input", ("batch",))
    b.tensor("new_tokens", (batch, 1), "int32", "output", ("batch", None))
    b.emit(Op.GRAPH_EXEC, ["new_tokens", "pool_k", "pool_v"],
           ["params", "pool_k", "pool_v", "next_token", "pos", "tables"],
           artifact="paged_decode", block_size=block_size,
           greedy=bool(greedy), temperature=float(temperature))
    b.emit(Op.POLL, [], ["new_tokens"])
    b.close_block("decode")
    return b.build({"paged_prefill": prefill_fn, "paged_decode": decode_fn})


# ---------------------------------------------------------------------------
# Per-layer LM block translation (DESIGN.md §13: kernel-handler lowering)
# ---------------------------------------------------------------------------
#
# Unlike compile_lm_service (one opaque GRAPH_EXEC per phase), this lowering
# opens the LM layers up to the RCB tooling: every attention / scan / matmul
# in the hot path becomes its own op — kernel opcodes (ATTENTION / SSM_SCAN /
# WKV6) dispatch through the kernel registry's link_compute handlers, dense
# glue (RMSNORM / ROPE / SILU_MUL / GEMM / ADD) through the generic vtable —
# so the peephole pass, ResidencyPlan, partitioner and batch ladder all see
# inside the layers. Recurrent-family projection stages that would need a
# dozen one-off opcodes (token-shift mixing, LoRA decay, group-norm gating)
# stay as small per-stage GRAPH_EXEC glue artifacts.

def _jit_artifact(fn):
    import jax
    return jax.jit(fn)


def _rwkv_pre_artifact(cfg, keys):
    import jax.numpy as jnp
    from repro.models import rwkv6 as rwkv

    def fn(h, *ws):
        p = dict(zip(keys, ws))
        ts0 = jnp.zeros((h.shape[0], h.shape[2]), h.dtype)
        return rwkv.time_mix_pre(cfg, p, h, ts0)
    return _jit_artifact(fn)


def _rwkv_post_artifact(cfg, keys, x_dtype):
    from repro.models import rwkv6 as rwkv

    def fn(y, g, *ws):
        p = dict(zip(keys, ws))
        return rwkv.time_mix_post(cfg, p, y, g, x_dtype)
    return _jit_artifact(fn)


def _rwkv_cm_artifact(cfg, keys):
    import jax.numpy as jnp
    from repro.models import rwkv6 as rwkv

    def fn(h, *ws):
        p = dict(zip(keys, ws))
        ts0 = jnp.zeros((h.shape[0], h.shape[2]), h.dtype)
        return rwkv.channel_mix(cfg, p, h, ts0)[0]
    return _jit_artifact(fn)


def _ssm_pre_artifact(cfg, keys):
    from repro.models import mamba as mam

    def fn(h, *ws):
        p = dict(zip(keys, ws))
        return mam.ssm_kernel_inputs(cfg, p, h)
    return _jit_artifact(fn)


def _ssm_post_artifact(cfg, keys, x_dtype):
    from repro.models import mamba as mam

    def fn(y, u, z, *ws):
        p = dict(zip(keys, ws))
        return mam.ssm_output(cfg, p, y, u, z, x_dtype)
    return _jit_artifact(fn)


def _moe_artifact(cfg, keys):
    from repro.models import mlp as mlpm

    def fn(h, *ws):
        p = dict(zip(keys, ws))
        return mlpm.moe_ffn(cfg, p, h)[0]
    return _jit_artifact(fn)


def compile_transformer_block(cfg, params, batch: int, seq_len: int,
                              optimize: bool = True):
    """Translate an LM's layer stack into a per-layer RCB program.

    ``params``: stacked model params (models/transformer.model_specs layout,
    leading num_layers dim on block entries). Input tensors: ``hidden``
    (B,S,d) pre-embedded states and, for rope families, ``positions`` (B,S)
    int32. Output: ``logits`` (B,S,V). Returns (RCBProgram, RIMFS image);
    glue artifacts ride on the program like the LM service programs'.

    Family routing: dense/moe/vlm/audio lower to a fully generic opcode
    stream around ``Op.ATTENTION``; ssm (rwkv6) and hybrid (hymba) lower
    their mixers to ``Op.WKV6`` / ``Op.SSM_SCAN`` (+ attention) with
    per-stage GRAPH_EXEC glue. Sliding-window attention is exact only while
    the window covers the whole sequence.
    """
    from repro.models.transformer import split_params

    if cfg.attention == "sliding" and seq_len > cfg.sliding_window:
        raise NotImplementedError(
            f"Op.ATTENTION lowers full causal attention; sliding window "
            f"{cfg.sliding_window} < seq_len {seq_len} would diverge")

    B, S, d, V = batch, seq_len, cfg.d_model, cfg.vocab_size
    dt = cfg.dtype
    eps = float(cfg.norm_eps)
    b = _Builder(f"lm_blocks_{cfg.name}")
    files: dict[str, np.ndarray] = {}
    artifacts: dict[str, Any] = {}

    def weight(name, arr):
        arr = np.ascontiguousarray(np.asarray(arr))
        files[name] = arr
        b.tensor(name, arr.shape, str(arr.dtype), "weight")
        return name

    def layer_weights(li, pl, keys):
        return [weight(f"L{li}.{k}", pl[k]) for k in keys]

    glob, blocks = split_params(params)
    layers = [{k: np.asarray(v[li]) for k, v in blocks.items()}
              for li in range(cfg.num_layers)]

    b.tensor("hidden", (B, S, d), dt, "input", ("batch", None, None))
    need_positions = cfg.family != "ssm" and cfg.use_rope
    if need_positions:
        b.tensor("positions", (B, S), "int32", "input", ("batch", None))

    def emit_rmsnorm(x, wname, warr):
        w = weight(wname, warr)
        t = b.scratch((B, S, d), dt, "ln")
        b.emit(Op.RMSNORM, [t], [x, w], eps=eps)
        return t

    def emit_add(a, c, shape=None):
        t = b.scratch(shape or (B, S, d), dt)
        b.emit(Op.ADD, [t], [a, c])
        return t

    # -- dense attention sub-graph (also the hybrid attention branch) -------
    def emit_attention(x_h, li, pl):
        H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

        def proj(tag, nh, norm_key):
            w = weight(f"L{li}.w{tag}",
                       np.asarray(pl[f"w{tag}"]).reshape(d, nh * D))
            t = b.scratch((B, S, nh * D), dt, tag)
            b.emit(Op.GEMM, [t], [x_h, w])
            if cfg.qkv_bias and f"b{tag}" in pl:
                bias = weight(f"L{li}.b{tag}",
                              np.asarray(pl[f"b{tag}"]).reshape(nh * D))
                t = emit_add(t, bias, (B, S, nh * D))
            t4 = b.scratch((B, S, nh, D), dt)
            b.emit(Op.RESHAPE, [t4], [t], shape=[B, S, nh, D])
            if cfg.qk_norm and norm_key:
                nw = weight(f"L{li}.{norm_key}", pl[norm_key])
                t5 = b.scratch((B, S, nh, D), dt)
                b.emit(Op.RMSNORM, [t5], [t4, nw], eps=eps)
                t4 = t5
            if cfg.use_rope and tag != "v":
                t6 = b.scratch((B, S, nh, D), dt)
                b.emit(Op.ROPE, [t6], [t4, "positions"],
                       theta=float(cfg.rope_theta))
                t4 = t6
            return t4

        q = proj("q", H, "q_norm")
        k = proj("k", Hkv, "k_norm")
        v = proj("v", Hkv, None)
        att = b.scratch((B, S, H, D), dt, "att")
        b.emit(Op.ATTENTION, [att], [q, k, v], causal=True)
        af = b.scratch((B, S, H * D), dt)
        b.emit(Op.RESHAPE, [af], [att], shape=[B, S, H * D])
        wo = weight(f"L{li}.wo", np.asarray(pl["wo"]).reshape(H * D, d))
        ao = b.scratch((B, S, d), dt)
        b.emit(Op.GEMM, [ao], [af, wo])
        return ao

    def emit_swiglu(h2, li, pl):
        f = cfg.d_ff
        wg = weight(f"L{li}.mlp_gate", pl["mlp_wi_gate"])
        wu = weight(f"L{li}.mlp_up", pl["mlp_wi_up"])
        wo = weight(f"L{li}.mlp_out", pl["mlp_wo"])
        g = b.scratch((B, S, f), dt, "ffg")
        b.emit(Op.GEMM, [g], [h2, wg])
        u = b.scratch((B, S, f), dt, "ffu")
        b.emit(Op.GEMM, [u], [h2, wu])
        m = b.scratch((B, S, f), dt)
        b.emit(Op.SILU_MUL, [m], [g, u])
        o = b.scratch((B, S, d), dt)
        b.emit(Op.GEMM, [o], [m, wo])
        return o

    def emit_moe(h2, li, pl):
        keys = ["router", "we_gate", "we_up", "we_out"]
        if cfg.moe_dense_residual:
            keys += ["dense_wi_gate", "dense_wi_up", "dense_wo"]
        srcs = [h2] + layer_weights(li, pl, keys)
        y2 = b.scratch((B, S, d), dt, "moe")
        name = f"L{li}.moe"
        artifacts[name] = _moe_artifact(cfg, keys)
        b.emit(Op.GRAPH_EXEC, [y2], srcs, artifact=name)
        return y2

    def emit_mamba(h, li, pl):
        di, N = cfg.d_model, cfg.ssm_state
        pre_keys = ["m_in", "m_x", "m_dt", "m_dt_b", "m_alog"]
        srcs = [h] + layer_weights(li, pl, pre_keys)
        da = b.scratch((B, S, di, N), "float32", "da")
        bx = b.scratch((B, S, di, N), "float32", "bx")
        c = b.scratch((B, S, N), "float32", "ssc")
        u = b.scratch((B, S, di), "float32", "ssu")
        z = b.scratch((B, S, di), dt, "ssz")
        name = f"L{li}.ssm_pre"
        artifacts[name] = _ssm_pre_artifact(cfg, pre_keys)
        b.emit(Op.GRAPH_EXEC, [da, bx, c, u, z], srcs, artifact=name)
        ys = b.scratch((B, S, di), "float32", "ssy")
        b.emit(Op.SSM_SCAN, [ys], [da, bx, c])
        post_keys = ["m_d", "m_out"]
        srcs2 = [ys, u, z] + layer_weights(li, pl, post_keys)
        ym = b.scratch((B, S, d), dt, "ssm")
        name2 = f"L{li}.ssm_post"
        artifacts[name2] = _ssm_post_artifact(cfg, post_keys, dt)
        b.emit(Op.GRAPH_EXEC, [ym], srcs2, artifact=name2)
        return ym

    def emit_rwkv_layer(x, li, pl):
        K = cfg.rwkv_head_dim
        H = d // K
        h = emit_rmsnorm(x, f"L{li}.ln1", pl["ln1"])
        pre_keys = ["tm_mix", "tm_wr", "tm_wk", "tm_wv", "tm_wg",
                    "tm_w0", "tm_wa", "tm_wb"]
        srcs = [h] + layer_weights(li, pl, pre_keys)
        r = b.scratch((B, S, H, K), "float32", "wr")
        k = b.scratch((B, S, H, K), "float32", "wk")
        v = b.scratch((B, S, H, K), "float32", "wv")
        lw = b.scratch((B, S, H, K), "float32", "wlw")
        g = b.scratch((B, S, d), dt, "wg")
        name = f"L{li}.tm_pre"
        artifacts[name] = _rwkv_pre_artifact(cfg, pre_keys)
        b.emit(Op.GRAPH_EXEC, [r, k, v, lw, g], srcs, artifact=name)
        uw = weight(f"L{li}.tm_u", np.asarray(pl["tm_u"], np.float32))
        y = b.scratch((B, S, H, K), "float32", "wy")
        b.emit(Op.WKV6, [y], [r, k, v, lw, uw])
        post_keys = ["tm_ln_w", "tm_ln_b", "tm_wo"]
        srcs2 = [y, g] + layer_weights(li, pl, post_keys)
        to = b.scratch((B, S, d), dt, "tm")
        name2 = f"L{li}.tm_post"
        artifacts[name2] = _rwkv_post_artifact(cfg, post_keys, dt)
        b.emit(Op.GRAPH_EXEC, [to], srcs2, artifact=name2)
        x = emit_add(x, to)
        h2 = emit_rmsnorm(x, f"L{li}.ln2", pl["ln2"])
        cm_keys = ["cm_mix", "cm_wk", "cm_wv", "cm_wr"]
        srcs3 = [h2] + layer_weights(li, pl, cm_keys)
        y2 = b.scratch((B, S, d), dt, "cm")
        name3 = f"L{li}.cm"
        artifacts[name3] = _rwkv_cm_artifact(cfg, cm_keys)
        b.emit(Op.GRAPH_EXEC, [y2], srcs3, artifact=name3)
        return emit_add(x, y2)

    half = zero = None
    if cfg.family == "hybrid":
        act_np = rimfs_mod._dtype_of(dt)
        half = weight("c.half", np.full((1,), 0.5, act_np))
        zero = weight("c.zero", np.zeros((1,), act_np))

    x = "hidden"
    for li, pl in enumerate(layers):
        if cfg.family == "ssm":
            x = emit_rwkv_layer(x, li, pl)
        else:
            h = emit_rmsnorm(x, f"L{li}.ln1", pl["ln1"])
            ya = emit_attention(h, li, pl)
            if cfg.family == "hybrid":
                ym = emit_mamba(h, li, pl)
                s1 = emit_add(ya, ym)
                s2 = b.scratch((B, S, d), dt)
                b.emit(Op.SCALE_SHIFT, [s2], [s1, half, zero])
                x = emit_add(x, s2)
            else:
                x = emit_add(x, ya)
            h2 = emit_rmsnorm(x, f"L{li}.ln2", pl["ln2"])
            if cfg.num_experts > 0 and cfg.family != "hybrid":
                y2 = emit_moe(h2, li, pl)
            else:
                y2 = emit_swiglu(h2, li, pl)
            x = emit_add(x, y2)
        b.close_block("layer")

    xf = emit_rmsnorm(x, "final_norm", glob["final_norm"])
    b.tensor("logits", (B, S, V), dt, "output", ("batch", None, "vocab"))
    if cfg.tie_embeddings:
        ew = weight("embed", glob["embed"])                 # (V, d)
        b.emit(Op.GEMM, ["logits"], [xf, ew], tb=True)
    else:
        lw_ = weight("lm_head", glob["lm_head"])            # (d, V)
        b.emit(Op.GEMM, ["logits"], [xf, lw_])
    b.emit(Op.FENCE)
    b.close_block("head")

    prog = b.build(artifacts)
    if optimize:
        prog = opt_mod.optimize(prog)
    image = rimfs_mod.pack(files)
    return prog, image
