"""Integrity plane primitives — the unified fault taxonomy anchor.

With no OS underneath, the runtime owns every guarantee an OS (or a
filesystem, or a DMA engine with ECC) would normally provide. This module
holds the pieces every layer shares:

  * ``IntegrityError`` — the *recoverable* data-integrity fault class.
    A checksum mismatch on a DMA payload, a torn RIMFS write, a resident
    buffer that no longer matches its file CRC: all detectable, all
    recoverable by re-issuing from a trusted source. ``rimfs.RIMFSError``
    subclasses it, so the whole taxonomy (DESIGN.md §11) narrows to one
    ``except IntegrityError`` at the recovery layer.
  * ``payload_crc`` — CRC-32 over a buffer's bytes, the one checksum
    shared by RIMFS file entries, RIMFS image trailers and DMA tickets
    (a ticket's CRC can therefore be validated *against the file it was
    read from*, not only against itself).
  * ``IntegrityConfig`` — per-driver policy: verification on/off (the
    benchmarked CRC-on/off overhead row flips this) and the bounded
    in-place retry budget for corrupted transfers.

Deliberately dependency-light (stdlib + numpy only): RHAL, RIMFS and RTPM
all import it without cycles.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


class IntegrityError(RuntimeError):
    """Detected data corruption (checksum mismatch, torn write, poisoned
    residency). Recoverable by construction: every raiser has a trusted
    source to re-issue from, so catching layers retry once before
    escalating. ``kind`` tags the telemetry counter that increments."""

    def __init__(self, message: str, kind: str = "integrity"):
        super().__init__(message)
        self.kind = kind


@dataclasses.dataclass
class IntegrityConfig:
    """Driver-level integrity policy (one instance per HalDriver)."""
    enabled: bool = True       # stamp + verify DMA payload CRCs
    dma_retries: int = 2       # in-place re-issues before escalating


def payload_crc(buf) -> int:
    """CRC-32 over a buffer's raw bytes (host- or device-resident; a
    device array is materialized through ``np.asarray`` — on the modeled
    backend that is the same host view the DMA engine reads)."""
    a = np.asarray(buf)
    if not a.flags["C_CONTIGUOUS"]:
        a = np.ascontiguousarray(a)
    return zlib.crc32(a.tobytes()) & 0xFFFFFFFF
