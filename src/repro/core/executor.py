"""The generic RCB executor — cyclic Fetch-Decode-Dispatch.

The executor knows nothing about models: it walks the linear op stream and
invokes RHAL vtable slots. Three modes reproduce the paper's central
comparison on TPU terms:

  * ``interpreted`` — every op is re-decoded through the opcode switch and
    dispatched as its own device computation with a host synchronization
    after it (per-op fixed cost: the OS-mediated / Vitis-AI analogue).
    Per-op wall times are recordable, so this is also the measurement mode.
  * ``linked``  — the default ``run`` path. The program is linked ONCE
    (core/linker.py) into pre-resolved thunks over a dense slot array; the
    dispatch loop is ``for thunk in thunks: thunk(slots, rimfs)`` with
    per-site jitted handlers dispatching asynchronously, syncing only at
    FENCE ops and program exit.
  * ``fused``  — the *same* linked thunks run once under ``jax.jit`` via
    the trace driver, collapsing the whole RCB stream into one XLA
    executable (the baremetal analogue: one dispatch per step, zero host
    round-trips inside).
  * ``partitioned`` — the program is cut into per-tile-group stages
    (core/partition.py) and pipelined over a ``TileMesh`` of independent
    drivers, cut-edge activations streaming split-phase between groups
    (the paper's multi-tile AIE-array deployment shape).

Equivalence of the modes over the whole op vocabulary is enforced by
tests/test_executor.py, tests/test_linker.py and the differential
conformance matrix in tests/test_conformance.py — the paper's "same RCBs
drive different execution environments" portability property.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import linker as linker_mod
from repro.core import rhal as rhal_mod
from repro.core.rbl import BoundProgram
from repro.core.rbl import explicitly_freed as rbl_explicitly_freed
from repro.core.rcb import Op, RCBProgram
from repro.core.rhal import DmaTicket


@dataclasses.dataclass
class OpTrace:
    block_id: int
    op: Op
    seconds: float


def _probe_update(probe_dev: dict, sym: str, buf) -> None:
    """Device-side abs-max accumulation: no host round-trip per op (the
    old path forced ``np.asarray`` — a full host sync per dispatch)."""
    m = jnp.max(jnp.abs(buf))
    prev = probe_dev.get(sym)
    probe_dev[sym] = m if prev is None else jnp.maximum(prev, m)


def _probe_flush(probe: dict, probe_dev: dict) -> None:
    """Convert accumulated device scalars to host floats ONCE at exit."""
    for sym, m in probe_dev.items():
        probe[sym] = max(probe.get(sym, 0.0), float(m))


class Executor:
    def __init__(self, driver: Optional[rhal_mod.HalDriver] = None,
                 rtpm=None):
        self.driver = driver or rhal_mod.make_eager_driver()
        self.rtpm = rtpm
        self.op_traces: list[OpTrace] = []
        self.batch_stats: dict = {}      # last run_batched outcome report

    # ------------------------------------------------------------- linking
    def link(self, bound: BoundProgram) -> linker_mod.LinkedProgram:
        """Link (and cache on the BoundProgram) against this driver."""
        linked = getattr(bound, "_linked", None)
        if linked is None or linked.driver is not self.driver \
                or linked.program is not bound.program:
            linked = linker_mod.link(bound, self.driver)
            bound._linked = linked
        return linked

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, driver, op, buffers, free_after: Optional[dict],
                  idx: int, rimfs):
        """Decode + dispatch one RCBOp through the vtable (interpreted)."""
        if op.op == Op.NOP or op.op == Op.HALT:
            return
        if op.op == Op.ALLOC:
            buffers[op.dsts[0]] = driver.alloc(tuple(op.attrs["shape"]),
                                               op.attrs["dtype"])
        elif op.op == Op.FREE:
            driver.free(buffers.pop(op.dsts[0], None))
        elif op.op == Op.BIND_CONST:
            buffers[op.dsts[0]] = driver.bind_const(op.attrs["value"])
        elif op.op == Op.DMA_H2D:
            src = op.srcs[0]
            host = buffers.get(src)
            if host is None and rimfs is not None:
                host = rimfs.read(src)
            buffers[op.dsts[0]] = driver.wait_dma(
                driver.initiate_dma(host, "h2d"))
        elif op.op == Op.DMA_D2H:
            buffers[op.dsts[0]] = driver.wait_dma(
                driver.initiate_dma(buffers[op.srcs[0]], "d2h"))
        elif op.op == Op.DMA_D2D:
            buffers[op.dsts[0]] = driver.wait_dma(
                driver.initiate_dma(buffers[op.srcs[0]], "d2d"))
        elif op.op == Op.GRAPH_EXEC:
            fn = self._artifact(op.attrs["artifact"])
            outs = fn(*[buffers[s] for s in op.srcs])
            if len(op.dsts) == 1:
                buffers[op.dsts[0]] = outs
            else:
                for d, o in zip(op.dsts, outs):
                    buffers[d] = o
        elif op.op == Op.COLLECTIVE:
            buffers[op.dsts[0]] = driver.collective(
                op.attrs.get("kind", "all_reduce"), buffers[op.srcs[0]],
                op.attrs)
        elif op.op == Op.FENCE:
            driver.fence(list(buffers.values()))
        elif op.op == Op.POLL:
            driver.poll(buffers.get(op.srcs[0]) if op.srcs else None)
        else:                                    # compute dispatch
            srcs = [buffers[s] for s in op.srcs]
            buffers[op.dsts[0]] = driver.dispatch_compute(op.op, srcs,
                                                          op.attrs)
        # Buffer lifetime management (RBL liveness plan). Scratch is
        # released by reference-drop, not driver.free: eager identity ops
        # (PASSTHROUGH, single-device COLLECTIVE) alias their source, so an
        # eager delete would tear buffers still reachable under another
        # symbol. Symbols with an explicit FREE op are exempt — FREE must
        # see the real buffer to return its arena range. The linked path
        # applies the same policy via its precomputed free-lists.
        if free_after is not None:
            for s in op.srcs:
                if free_after.get(s) == idx and s not in self._explicit_free:
                    t = self._prog.tensors.get(s)
                    if t is not None and t.kind == "scratch":
                        buffers.pop(s, None)

    def _artifact(self, name: str) -> Callable:
        fn = self._prog.artifacts.get(name)
        if fn is None:
            raise KeyError(f"GRAPH_EXEC artifact {name!r} not attached")
        return fn

    # --------------------------------------------------------------- eager
    def run(self, bound: BoundProgram, inputs: Optional[dict] = None,
            rimfs=None, trace_ops: bool = False,
            probe: Optional[dict] = None) -> dict:
        """Execute the program through the linked (compiled-dispatch) path.

        ``probe``: optional dict filled with per-symbol abs-max of every
        produced buffer — used by INT8 calibration (core/quant.py). The
        abs-max accumulates on device; host conversion happens once.

        ``trace_ops=True`` falls back to the interpreted path: per-op wall
        timing needs the per-op host sync that defines that mode.
        """
        if trace_ops:
            return self.run_interpreted(bound, inputs=inputs, rimfs=rimfs,
                                        trace_ops=True, probe=probe)
        linked = self.link(bound)
        istats0 = None
        if self.rtpm is not None:
            istats0 = {k: self.driver.stats.get(k, 0)
                       for k in ("dma_retry", "dma_crc_mismatch")}
        slots = linked.fresh_slots(bound.buffers, inputs)
        for sym, i in linked.missing_inputs:
            if slots[i] is None:
                raise ValueError(f"missing input {sym!r}")
        probe_dev: Optional[dict] = None
        if probe is not None:
            probe_dev = {}
            for i, buf in enumerate(slots):
                if buf is not None:
                    _probe_update(probe_dev, linked.names[i], buf)
        for pre in linked.prologue:                # prefetch issue phase
            pre(slots, rimfs)
        if probe_dev is None and self.rtpm is None:
            for thunk in linked.thunks:            # THE hot loop
                thunk(slots, rimfs)
        else:                                      # instrumented (composable)
            thunks = linked.thunks
            metas = linked.metas
            for block_id, start, end in linked.block_spans:
                t_blk = time.perf_counter()
                for k in range(start, end):
                    thunks[k](slots, rimfs)
                    if probe_dev is not None:
                        for d in metas[k].dst_slots:
                            buf = slots[d]
                            if buf is not None and \
                                    type(buf) is not DmaTicket:
                                _probe_update(probe_dev, linked.names[d],
                                              buf)
                if self.rtpm is not None:
                    # sync the block's products so "seconds" reflects
                    # execution, not async enqueue
                    for k in range(start, end):
                        for d in metas[k].dst_slots:
                            buf = slots[d]
                            if buf is not None and hasattr(
                                    buf, "block_until_ready"):
                                buf.block_until_ready()
                    self.rtpm.post("rcb_complete",
                                   {"block": block_id,
                                    "seconds": time.perf_counter() - t_blk})
        for epi in linked.epilogue:                # drain redeem phase
            epi(slots, rimfs)
        self.driver._count("dispatch", linked.n_compute)
        plan = linked.residency
        if self.rtpm is not None and plan is not None and plan.bytes_moved:
            self.rtpm.post("dma_complete",
                           {"bytes_moved": plan.bytes_moved,
                            "bytes_overlapped": plan.bytes_overlapped})
        if istats0 is not None:
            # surface integrity-plane activity (corruptions caught and
            # retried in the driver) as telemetry counter deltas
            for key, kind in (("dma_retry", "dma_retry"),
                              ("dma_crc_mismatch", "integrity_error")):
                delta = self.driver.stats.get(key, 0) - istats0[key]
                if delta:
                    self.rtpm.post(kind, {"n": delta, "source": "executor"})
        if probe_dev is not None:
            _probe_flush(probe, probe_dev)
        out = {}
        for name, i in linked.output_slots:
            if slots[i] is not None:
                out[name] = slots[i]
        return out

    # --------------------------------------------------- interpreted baseline
    def run_interpreted(self, bound: BoundProgram,
                        inputs: Optional[dict] = None, rimfs=None,
                        trace_ops: bool = False,
                        probe: Optional[dict] = None) -> dict:
        """Interpret the program op-by-op (eager / OS-mediated analogue).

        Kept as the baseline the benchmarks compare the linked path
        against, and as the per-op measurement mode (``trace_ops``).
        """
        self._prog = bound.program
        self._explicit_free = rbl_explicitly_freed(bound.program)
        buffers = dict(bound.buffers)
        if inputs:
            buffers.update(inputs)
        for sym in bound.missing_inputs:
            if sym not in buffers:
                raise ValueError(f"missing input {sym!r}")
        probe_dev: Optional[dict] = None
        if probe is not None:
            probe_dev = {}
            for sym, buf in buffers.items():
                _probe_update(probe_dev, sym, buf)
        idx = 0
        for block in bound.program.blocks:
            t_blk = time.perf_counter()
            for op in block.ops:
                t0 = time.perf_counter()
                self._dispatch(self.driver, op, buffers, bound.last_use,
                               idx, rimfs)
                if trace_ops:
                    self.op_traces.append(
                        OpTrace(block.block_id, op.op,
                                time.perf_counter() - t0))
                if probe_dev is not None:
                    for dd in op.dsts:
                        if dd in buffers:
                            _probe_update(probe_dev, dd, buffers[dd])
                idx += 1
            if self.rtpm is not None:
                self.rtpm.post("rcb_complete",
                               {"block": block.block_id,
                                "seconds": time.perf_counter() - t_blk})
        if probe_dev is not None:
            _probe_flush(probe, probe_dev)
        return {name: buffers[name]
                for name, t in bound.program.tensors.items()
                if t.kind == "output" and name in buffers}

    # --------------------------------------------------------------- fused
    def fuse(self, bound: BoundProgram, donate_weights: bool = False):
        """Stage the whole program into one jitted callable.

        Returns ``fn(inputs: dict, weights: dict) -> outputs: dict`` — a
        single XLA program per RCB stream (the baremetal analogue). The
        staged function traces the SAME linked thunk form ``run`` executes,
        just through the trace driver.

        The jitted callable is cached on the BoundProgram (keyed by
        ``donate_weights``): re-linking and re-tracing on every call
        silently dominated any serving loop that reached for ``fuse`` —
        repeated calls now return the SAME callable, so XLA's trace cache
        actually gets hit. The cache is invalidated if the bound's
        program object is swapped out from under it.
        """
        self._prog = bound.program
        cache = getattr(bound, "_fused", None)
        if cache is None or cache[0] is not bound.program:
            cache = bound._fused = (bound.program, {})
        fn = cache[1].get(donate_weights)
        if fn is None:
            linked = linker_mod.link(bound, rhal_mod.make_trace_driver())
            staged = linker_mod.stage_callable(linked)
            donate = (1,) if donate_weights else ()
            fn = jax.jit(staged, donate_argnums=donate)
            cache[1][donate_weights] = fn
        return fn

    # -------------------------------------------------------------- batched
    #: Batch-bucket ladder: every batched dispatch stages at one of these
    #: leading-axis sizes, so the number of distinct XLA executables per
    #: program is bounded (len(buckets)), not O(#distinct request counts).
    BATCH_BUCKETS: tuple = (1, 2, 4, 8, 16)

    # (program CRC, bucket) -> AOT-compiled vmapped staged callable.
    # Module-wide on purpose: re-binds, fresh BoundPrograms and every
    # Executor instance of the same program share ONE executable per
    # bucket (the bucket fixes every input aval, so ahead-of-time
    # lower+compile replaces jit's per-call cache probe with a direct
    # executable invocation — MicroTVM-AoT-style, no tracing at dispatch).
    _batch_cache: dict = {}
    _BATCH_CACHE_CAP = 64

    @classmethod
    def aot_cache_get(cls, key):
        """Look up an AOT-compiled executable in the module-wide CRC-keyed
        cache. Keys are (program CRC, shape-descriptor tuple) — the paged
        LM engine keys its prefill/decode executables here so every engine
        over the same service program shares one executable per shape,
        under the same capacity bound as the batched-dispatch entries."""
        return cls._batch_cache.get(key)

    @classmethod
    def aot_cache_put(cls, key, fn) -> None:
        while len(cls._batch_cache) >= cls._BATCH_CACHE_CAP:
            cls._batch_cache.pop(next(iter(cls._batch_cache)))
        cls._batch_cache[key] = fn

    def _batched_callable(self, bound: BoundProgram, bucket: int):
        key = (bound.program.crc(), bucket)
        fn = Executor._batch_cache.get(key)
        if fn is None:
            while len(Executor._batch_cache) >= Executor._BATCH_CACHE_CAP:
                Executor._batch_cache.pop(
                    next(iter(Executor._batch_cache)))
            linked = linker_mod.link(bound, rhal_mod.make_trace_driver())
            staged = linker_mod.stage_callable(linked)
            # inputs map over the leading batch axis, weights broadcast;
            # avals come from the program's tensor descs (inputs) and the
            # bind's resolved buffers (weights) — same-CRC programs have
            # identical descs, so the compiled form is shareable
            in_avals = {
                n: jax.ShapeDtypeStruct((bucket,) + tuple(t.shape),
                                        np.dtype(t.dtype))
                for n, t in bound.program.tensors.items()
                if t.kind == "input"}
            w_avals = {
                n: jax.ShapeDtypeStruct(np.shape(b),
                                        np.asarray(b).dtype if
                                        not hasattr(b, "dtype") else
                                        b.dtype)
                for n, b in self.weights_from(bound).items()}
            fn = jax.jit(jax.vmap(staged, in_axes=(0, None))).lower(
                in_avals, w_avals).compile()
            Executor._batch_cache[key] = fn
        return fn

    def _bucket_for(self, n: int) -> int:
        """Smallest ladder bucket >= n (pad-to-bucket), or the largest
        bucket when n exceeds the ladder (the caller chunks)."""
        for b in self.BATCH_BUCKETS:
            if b >= n:
                return b
        return self.BATCH_BUCKETS[-1]

    def run_batched(self, bound: BoundProgram, inputs_list,
                    rimfs=None, max_bucket: Optional[int] = None) -> list:
        """Execute one program over a batch of independent requests.

        The program is staged ONCE per batch bucket (sizes 1/2/4/8/16,
        via ``jax.vmap`` over a leading axis on the input slots with
        weights broadcast, AOT-compiled) and the request list is chunked
        greedily onto the ladder: full largest-bucket chunks first, then
        the remainder pads up to the smallest covering bucket — padded
        lanes replicate the chunk's last request and are sliced away from
        the results (pad-to-bucket + slice-back). ``max_bucket`` clamps
        the ladder top (e.g. to a serving batch window).

        Execution is two-phase: every chunk is DISPATCHED first (the
        compiled calls are asynchronous), then results materialize in
        request order — so chunk *k*'s host-side stacking and slice-back
        overlap chunk *k−1*'s device compute, and a multi-chunk batch
        runs at sustained pipeline throughput rather than
        dispatch-sync-dispatch. Returns one output dict per request in
        request order, outputs materialized on host (each output tensor
        crosses d2h ONCE per chunk; per-request entries are zero-copy
        views of the batched buffer); per-lane outputs are bit-identical
        to serial ``run`` (tests/test_conformance.py).

        Programs the batch analysis rejects (split-phase DMA, collectives,
        GRAPH_EXEC — see ``linker.batch_analysis``) fall back to serial
        linked execution, same results, no batch amortization.
        ``self.batch_stats`` reports what happened either way.
        """
        reqs = list(inputs_list)
        verdict = linker_mod.batch_analysis(bound)
        self.batch_stats = {"batchable": verdict.batchable,
                            "reason": verdict.reason,
                            "requests": len(reqs), "buckets": [],
                            "padded": 0}
        if not reqs:
            return []
        if not verdict.batchable:
            return [self.run(bound, inputs=req, rimfs=rimfs)
                    for req in reqs]
        prep = getattr(bound, "_batch_prep", None)
        if prep is None or prep[0] is not bound.program:
            prep = bound._batch_prep = (
                bound.program,
                tuple(n for n, t in bound.program.tensors.items()
                      if t.kind == "input"),
                self.weights_from(bound))
        _, input_syms, weights = prep
        top = self.BATCH_BUCKETS[-1] if max_bucket is None \
            else max(1, min(max_bucket, self.BATCH_BUCKETS[-1]))
        # phase 1: stack + dispatch every chunk (no sync anywhere)
        pending: list = []                 # (pos, take, {sym: device out})
        pos = 0
        while pos < len(reqs):
            rem = len(reqs) - pos
            take = top if rem >= top else rem
            # a non-ladder max_bucket stages its own chunk size rather
            # than padding past the caller's clamp
            bucket = min(self._bucket_for(take), top)
            chunk = reqs[pos:pos + take]
            stacked = {}
            for sym in input_syms:
                vals = []
                for req in chunk:
                    v = req.get(sym) if req else None
                    if v is None:
                        v = bound.buffers.get(sym)
                    if v is None:
                        raise ValueError(f"missing input {sym!r} in "
                                         f"batched request {pos}")
                    vals.append(np.asarray(v))
                vals.extend([vals[-1]] * (bucket - take))   # pad lanes
                stacked[sym] = np.stack(vals)      # host-side: one memcpy
            fn = self._batched_callable(bound, bucket)
            pending.append((pos, take, fn(stacked, weights)))
            self.batch_stats["buckets"].append(bucket)
            self.batch_stats["padded"] += bucket - take
            pos += take
        # phase 2: materialize in order — ONE d2h per output tensor per
        # chunk, zero-copy per-lane views (per-lane device slicing would
        # dispatch a device op per request, the exact fixed cost this
        # path amortizes); blocking on chunk k overlaps chunk k+1's
        # in-flight compute
        results: list = [None] * len(reqs)
        for cpos, take, outs in pending:
            hosts = {k: np.asarray(v) for k, v in outs.items()}
            for j in range(take):
                results[cpos + j] = {k: h[j] for k, h in hosts.items()}
        return results

    # --------------------------------------------------------- partitioned
    def run_partitioned(self, bound: BoundProgram,
                        inputs: Optional[dict] = None, rimfs=None,
                        mesh=None, n_groups: int = 2,
                        platform=None) -> dict:
        """Execute over a tile mesh: the program is cut into per-group
        stages (core/partition.py), each stage runs linked on its own
        group's driver, and cut-edge tensors stream split-phase between
        groups — stage *k*'s activations move while stage *k+1* sets up.

        ``mesh`` defaults to a fresh ``TileMesh(n_groups)``; a
        ``platform`` (rtpm.Platform) adds heartbeat-monitored workers and
        stage re-queue on tile failure. The partition is cached on the
        BoundProgram per group count, so repeated executions re-cut
        nothing.
        """
        from repro.core import partition as partition_mod
        if mesh is None:
            mesh = rhal_mod.TileMesh(n_groups)
        part = partition_mod.ensure_partition(bound, mesh.n_groups)
        return partition_mod.execute(part, mesh, inputs=inputs,
                                     rimfs=rimfs, platform=platform)

    # ------------------------------------------------------------- helpers
    def weights_from(self, bound: BoundProgram) -> dict:
        return {n: b for n, b in bound.buffers.items()
                if bound.program.tensors[n].kind == "weight"}
