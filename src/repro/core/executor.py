"""The generic RCB executor — cyclic Fetch-Decode-Dispatch.

The executor knows nothing about models: it walks the linear op stream and
invokes RHAL vtable slots. Two modes reproduce the paper's central
comparison on TPU terms:

  * ``eager``  — every op is dispatched as its own device computation with a
    host synchronization after it (per-op fixed cost: the OS-mediated /
    Vitis-AI analogue). Per-op wall times are recorded for the benchmark
    harness.
  * ``fused``  — the *same* program and the *same* dispatch loop run once
    under ``jax.jit`` via the trace driver, collapsing the whole RCB stream
    into one XLA executable (the baremetal analogue: one dispatch per step,
    zero host round-trips inside).

Equivalence of the two modes over the whole op vocabulary is enforced by
tests/test_executor.py — the paper's "same RCBs drive different execution
environments" portability property.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import rhal as rhal_mod
from repro.core.rbl import BoundProgram
from repro.core.rcb import Op, RCBProgram


@dataclasses.dataclass
class OpTrace:
    block_id: int
    op: Op
    seconds: float


class Executor:
    def __init__(self, driver: Optional[rhal_mod.HalDriver] = None,
                 rtpm=None):
        self.driver = driver or rhal_mod.make_eager_driver()
        self.rtpm = rtpm
        self.op_traces: list[OpTrace] = []

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, driver, op, buffers, free_after: Optional[dict],
                  idx: int, rimfs):
        """Decode + dispatch one RCBOp through the vtable."""
        if op.op == Op.NOP or op.op == Op.HALT:
            return
        if op.op == Op.ALLOC:
            buffers[op.dsts[0]] = driver.alloc(tuple(op.attrs["shape"]),
                                               op.attrs["dtype"])
        elif op.op == Op.FREE:
            driver.free(buffers.pop(op.dsts[0], None))
        elif op.op == Op.BIND_CONST:
            buffers[op.dsts[0]] = driver.bind_const(op.attrs["value"])
        elif op.op == Op.DMA_H2D:
            src = op.srcs[0]
            host = buffers.get(src)
            if host is None and rimfs is not None:
                host = rimfs.read(src)
            buffers[op.dsts[0]] = driver.wait_dma(
                driver.initiate_dma(host, "h2d"))
        elif op.op == Op.DMA_D2H:
            buffers[op.dsts[0]] = driver.wait_dma(
                driver.initiate_dma(buffers[op.srcs[0]], "d2h"))
        elif op.op == Op.DMA_D2D:
            buffers[op.dsts[0]] = driver.wait_dma(
                driver.initiate_dma(buffers[op.srcs[0]], "d2d"))
        elif op.op == Op.GRAPH_EXEC:
            fn = self._artifact(op.attrs["artifact"])
            outs = fn(*[buffers[s] for s in op.srcs])
            if len(op.dsts) == 1:
                buffers[op.dsts[0]] = outs
            else:
                for d, o in zip(op.dsts, outs):
                    buffers[d] = o
        elif op.op == Op.COLLECTIVE:
            buffers[op.dsts[0]] = driver.collective(
                op.attrs.get("kind", "all_reduce"), buffers[op.srcs[0]],
                op.attrs)
        elif op.op == Op.FENCE:
            driver.fence(list(buffers.values()))
        elif op.op == Op.POLL:
            driver.poll(buffers.get(op.srcs[0]) if op.srcs else None)
        else:                                    # compute dispatch
            srcs = [buffers[s] for s in op.srcs]
            buffers[op.dsts[0]] = driver.dispatch_compute(op.op, srcs,
                                                          op.attrs)
        # buffer lifetime management (RBL liveness plan)
        if free_after is not None:
            for s in op.srcs:
                if free_after.get(s) == idx:
                    t = self._prog.tensors.get(s)
                    if t is not None and t.kind == "scratch":
                        driver.free(buffers.pop(s, None))

    def _artifact(self, name: str) -> Callable:
        fn = self._prog.artifacts.get(name)
        if fn is None:
            raise KeyError(f"GRAPH_EXEC artifact {name!r} not attached")
        return fn

    # --------------------------------------------------------------- eager
    def run(self, bound: BoundProgram, inputs: Optional[dict] = None,
            rimfs=None, trace_ops: bool = False,
            probe: Optional[dict] = None) -> dict:
        """Interpret the program op-by-op (eager / OS-mediated analogue).

        ``probe``: optional dict filled with per-symbol abs-max of every
        produced buffer — used by INT8 calibration (core/quant.py).
        """
        self._prog = bound.program
        buffers = dict(bound.buffers)
        if inputs:
            buffers.update(inputs)
        for sym in bound.missing_inputs:
            if sym not in buffers:
                raise ValueError(f"missing input {sym!r}")
        if probe is not None:
            for sym, buf in buffers.items():
                probe[sym] = max(probe.get(sym, 0.0),
                                 float(np.max(np.abs(np.asarray(buf)))))
        idx = 0
        for block in bound.program.blocks:
            t_blk = time.perf_counter()
            for op in block.ops:
                t0 = time.perf_counter()
                self._dispatch(self.driver, op, buffers, bound.last_use,
                               idx, rimfs)
                if trace_ops:
                    self.op_traces.append(
                        OpTrace(block.block_id, op.op,
                                time.perf_counter() - t0))
                if probe is not None:
                    for dd in op.dsts:
                        if dd in buffers:
                            probe[dd] = max(
                                probe.get(dd, 0.0),
                                float(np.max(np.abs(np.asarray(buffers[dd])))))
                idx += 1
            if self.rtpm is not None:
                self.rtpm.post("rcb_complete",
                               {"block": block.block_id,
                                "seconds": time.perf_counter() - t_blk})
        return {name: buffers[name]
                for name, t in bound.program.tensors.items()
                if t.kind == "output" and name in buffers}

    # --------------------------------------------------------------- fused
    def fuse(self, bound: BoundProgram, donate_weights: bool = False):
        """Stage the whole program into one jitted callable.

        Returns ``fn(inputs: dict, weights: dict) -> outputs: dict`` — a
        single XLA program per RCB stream (the baremetal analogue).
        """
        self._prog = bound.program
        prog = bound.program
        weight_names = sorted(n for n, t in prog.tensors.items()
                              if t.kind == "weight")
        input_names = sorted(n for n, t in prog.tensors.items()
                             if t.kind == "input")
        trace_driver = rhal_mod.make_trace_driver()

        def staged(inputs: dict, weights: dict) -> dict:
            buffers = {}
            buffers.update({k: weights[k] for k in weight_names})
            buffers.update({k: inputs[k] for k in input_names})
            idx = 0
            for block in prog.blocks:
                for op in block.ops:
                    self._dispatch(trace_driver, op, buffers, None, idx,
                                   None)
                    idx += 1
            return {name: buffers[name]
                    for name, t in prog.tensors.items()
                    if t.kind == "output" and name in buffers}

        donate = (1,) if donate_weights else ()
        return jax.jit(staged, donate_argnums=donate)

    # ------------------------------------------------------------- helpers
    def weights_from(self, bound: BoundProgram) -> dict:
        return {n: b for n, b in bound.buffers.items()
                if bound.program.tensors[n].kind == "weight"}
