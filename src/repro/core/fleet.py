"""Elastic fleet operations — RTPM as the serving control plane.

PRs 3-5 built the recovery primitives in isolation: heartbeat fault
verdicts (rtpm), stage re-queue on tile failure (partition.execute),
graceful drain with explicit hand-back (ServiceLoop/server), per-group-
count partition caching (executor) and zero-byte RIMFS re-binds
(residency). This module composes them into one self-healing controller
(DESIGN.md §10):

  * ``FleetController.tick`` runs the observe -> decide -> act loop:
    dispatcher queue depth + admission backlog, deadline-miss (shed)
    rate, and heartbeat verdicts (including the per-worker EWMA
    straggler signal) feed a hysteresis scaler that walks the mesh
    ladder (2 -> 4 -> 8 -> 2) and a healer that replaces meshes with
    dead groups.
  * All mutations of dispatcher-owned state (``server.mesh``,
    ``server._bound``, ``platform.rimfs``) happen as **control ops on
    the dispatcher thread** (``InferenceServer.run_on_dispatcher``):
    the dispatcher executes one item at a time, so a control op runs
    with no request mid-flight — the single-owner model is the drain
    point, and a flip is atomic *between* requests by construction.
    Expensive work (partitioning, tile binds, weight pinning, linking)
    runs OFF the dispatcher beforehand; the flip itself is a pointer
    swap.
  * Hot weight swap: mount + CRC-verify the new image in the
    background, bind a **shadow** program against it, probe it with a
    golden input bit-compared against the live binding's answer, pre-
    warm the current mesh's tile binds from the new image, then flip
    atomically. Probe mismatch (or a post-swap deadline-miss spike
    during the probation window) rolls back to the old binding — whose
    residency was never unpinned, so rollback re-uploads **zero
    bytes**. Events: ``swap_started / swap_probed / swap_committed /
    swap_rolled_back`` (plus ``swap_finalized`` when probation ends).
  * Mesh cache: previously-built meshes are kept (bounded) per group
    count, so a 2 -> 8 -> 2 cycle returns to the *original* drivers and
    their already-pinned weights — scaling back down moves zero weight
    bytes.

PR 10 adds the safe-rollout plane (DESIGN.md §14):

  * Canary A/B serving: ``FleetController.canary(image, fraction)``
    binds the new image as a shadow and installs a ``CanaryState`` on
    the server — the dispatcher hash-routes a deterministic fraction of
    live plain-RCB traffic through the shadow binding and bit-compares
    sampled outputs against the primary's. A sequential probability
    ratio test (SPRT) over the agree/disagree stream auto-promotes the
    image (atomic flip, old residency released) or auto-aborts it
    (shadow dropped, primary untouched) — probation driven by real
    request outputs, not shed-rate alone. A sampled request that
    DISAGREES is answered with the primary's bytes, so a bad canary
    never serves a byte it is known to have gotten wrong.
  * Partial reshapes: a dead or stage-EWMA-straggling tile group is
    replaced in place (``TileMesh.spawn_replacement`` + prewarm one
    tile + CRC re-validation + ``install_group`` splice between
    requests) instead of rebuilding the whole mesh — zero dropped
    work, zero re-uploaded weight bytes for surviving groups.
  * Swap probation is request-count based: a swap finalizes only after
    ``probation_requests`` real requests were served on the new
    binding, so an idle period can never silently pass probation.

The chaos harness (tests/chaos.py) drives all of this under live
traffic with injected faults and asserts zero failed client requests
and bit-identical outputs throughout.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
import zlib
from typing import Any, Optional

import numpy as np

from repro.core import partition as partition_mod
from repro.core import rbl as rbl_mod
from repro.core import rhal as rhal_mod
from repro.core import rimfs as rimfs_mod


class FleetError(RuntimeError):
    pass


@dataclasses.dataclass
class FleetConfig:
    """Control-loop policy knobs (hysteresis lives here, not in code)."""
    ladder: tuple = (2, 4, 8)          # mesh sizes the scaler walks
    min_groups: int = 2
    max_groups: int = 8
    scale_up_depth: int = 8            # queue depth that argues for growth
    scale_down_depth: int = 1          # ... and for shrinking
    scale_up_ticks: int = 2            # consecutive ticks before acting
    scale_down_ticks: int = 3
    miss_rate_up: float = 0.10         # shed fraction that argues for growth
    probation_ticks: int = 3           # post-swap minimum watch ticks
    probation_requests: int = 8        # served requests before finalize
    miss_spike: float = 0.25           # post-swap shed fraction -> rollback
    spike_min_window: int = 4          # min requests before judging a spike
    mesh_cache_cap: int = 4
    control_timeout: float = 60.0      # dispatcher flip wait
    probe_seed: int = 0xF1EE7          # golden-input generator seed
    finalize_unpin: bool = True        # release old image after probation
    # --- partial reshape (replace one group instead of a full heal) ---
    partial_reshape: bool = True
    straggler_ticks: int = 3           # consecutive slow verdicts -> replace
    stage_straggler_ratio: float = 2.5  # group stage-EWMA vs median -> slow
    stage_ewma_alpha: float = 0.3
    # --- canary A/B rollout (SPRT over per-request agreement) ---
    canary_fraction: float = 0.25      # traffic hash-routed to the shadow
    canary_sample_fraction: float = 1.0  # routed requests also dual-run
    canary_serve_shadow: bool = True   # serve shadow bytes when they agree
    canary_p_good: float = 0.995       # H_good: per-request agree prob
    canary_p_bad: float = 0.80         # H_bad: a broken image's agree prob
    canary_alpha: float = 0.05         # P(abort | image good)
    canary_beta: float = 0.05          # P(promote | image bad)
    canary_min_samples: int = 4
    canary_max_samples: int = 400      # forced verdict at the cap
    canary_token_threshold: float = 1.0  # int outputs: agree fraction >= thr


@dataclasses.dataclass
class _SwapState:
    """A committed swap under probation (rollback stays possible)."""
    old_rimfs: Any
    old_bound: Any
    new_rimfs: Any
    new_bound: Any
    shed_baseline: int
    served_baseline: int
    ticks: int = 0


def golden_inputs(program, seed: int = 0xF1EE7) -> dict:
    """Deterministic probe inputs for a service program: every swap
    probe, canary splice check and circuit-breaker half-open probe runs
    the same goldens, so their reference answers are comparable across
    bindings and across time."""
    rng = np.random.RandomState(seed)
    out = {}
    for name, t in program.tensors.items():
        if t.kind != "input":
            continue
        dt = np.dtype(t.dtype)
        if dt.kind in "iu":
            out[name] = rng.randint(0, 4, size=t.shape).astype(dt)
        else:
            out[name] = rng.randn(*t.shape).astype(dt)
    return out


class SPRT:
    """Wald's sequential probability ratio test over a Bernoulli
    agree/disagree stream (DESIGN.md §14).

    ``llr`` accumulates log P(obs | H_bad)/P(obs | H_good): an agreement
    drives it down (toward *promote*), a disagreement drives it sharply
    up (toward *abort*). With the default priors (p_good=0.995,
    p_bad=0.8, alpha=beta=0.05) one disagreement adds ~+3.7 while an
    agreement adds ~-0.2, so a clean canary promotes after ~14 agreed
    samples and a broken one aborts after 1-2 disagreements — without
    ever serving enough bad traffic to matter.
    """

    def __init__(self, p_good: float = 0.995, p_bad: float = 0.80,
                 alpha: float = 0.05, beta: float = 0.05,
                 min_samples: int = 4, max_samples: int = 400):
        self.min_samples = min_samples
        self.max_samples = max_samples
        self.llr = 0.0
        self.n = 0
        self.agrees = 0
        self._abort_at = math.log((1.0 - beta) / alpha)
        self._promote_at = math.log(beta / (1.0 - alpha))
        self._l_agree = math.log(p_bad / p_good)
        self._l_disagree = math.log((1.0 - p_bad) / (1.0 - p_good))

    def observe(self, agree: bool) -> None:
        self.n += 1
        if agree:
            self.agrees += 1
            self.llr += self._l_agree
        else:
            self.llr += self._l_disagree

    def verdict(self) -> Optional[str]:
        """"promote" | "abort" | None (keep sampling)."""
        if self.n < self.min_samples:
            return None
        if self.llr >= self._abort_at:
            return "abort"
        if self.llr <= self._promote_at:
            return "promote"
        if self.n >= self.max_samples:     # undecided at the cap: the
            return "abort"                 # image failed to prove itself
        return None

    def summary(self) -> dict:
        return {"n": self.n, "agrees": self.agrees,
                "disagrees": self.n - self.agrees,
                "llr": round(self.llr, 4), "verdict": self.verdict()}


class CanaryState:
    """Dispatcher-visible state of one canary rollout.

    Installed on ``server.canary`` via a control op; the dispatcher
    consults it per request (hash routing + sampling are pure functions
    of the request id, so the split is deterministic and replayable) and
    feeds agree/disagree bits back through ``record``. The controller
    polls ``sprt.verdict()`` from its tick and promotes/aborts."""

    def __init__(self, bound, fs, fraction: float, sprt: SPRT,
                 label: str = "", sample_fraction: float = 1.0,
                 serve_shadow: bool = True, token_threshold: float = 1.0):
        self.bound = bound
        self.fs = fs
        self.fraction = max(0.0, min(1.0, fraction))
        self.sprt = sprt
        self.label = label
        self.sample_fraction = max(0.0, min(1.0, sample_fraction))
        self.serve_shadow = serve_shadow
        self.token_threshold = token_threshold
        self.stats = {"routed": 0, "sampled": 0, "agree": 0,
                      "disagree": 0, "served_shadow": 0}

    @staticmethod
    def _hash(tag: bytes, rid: int) -> int:
        return zlib.crc32(tag + int(rid).to_bytes(8, "little")) % 10_000

    def routes(self, rid: int) -> bool:
        """Deterministic traffic split: same rid always lands on the
        same side, regardless of arrival order or thread."""
        return self._hash(b"route", rid) < int(self.fraction * 10_000)

    def samples(self, rid: int) -> bool:
        """Of the routed requests, which also dual-run the primary for
        an agree/disagree SPRT sample (independent hash stream)."""
        return self._hash(b"sample", rid) < int(
            self.sample_fraction * 10_000)

    def judge(self, primary: dict, shadow: dict) -> bool:
        """Bit-compare float outputs; integer (token) outputs may use an
        agreement-fraction threshold for sampled LM decode."""
        if set(primary) != set(shadow):
            return False
        for k in primary:
            a, b = np.asarray(primary[k]), np.asarray(shadow[k])
            if a.shape != b.shape or a.dtype != b.dtype:
                return False
            if a.dtype.kind in "iu" and self.token_threshold < 1.0:
                agree = float(np.mean(a == b)) if a.size else 1.0
                if agree < self.token_threshold:
                    return False
            elif not np.array_equal(a, b):
                return False
        return True

    def record(self, agree: bool) -> None:
        self.sprt.observe(agree)
        self.stats["sampled"] += 1
        self.stats["agree" if agree else "disagree"] += 1


class FleetController:
    """Observe -> decide -> drain -> reshape/swap -> resume.

    Owns NO request-path state: everything the dispatcher touches is
    flipped via control ops. The controller may run its ``tick`` from a
    background thread (``start``/``stop``) or be stepped manually for
    deterministic tests. All actions are idempotent with respect to the
    serving invariants: no accepted request is dropped, outputs stay
    bit-identical to the single-device reference, and every transition
    emits an event through the platform's unified dispatcher.
    """

    EVENTS = ("scale_started", "scale_complete", "heal_started",
              "heal_complete", "swap_started", "swap_probed",
              "swap_committed", "swap_rolled_back", "swap_finalized",
              "straggler_detected", "fleet_error",
              "canary_started", "canary_promoted", "canary_aborted",
              "reshape_started", "reshape_complete")

    def __init__(self, server, config: Optional[FleetConfig] = None):
        self.server = server
        self.cfg = config or FleetConfig()
        self.events: list = []          # (kind, payload) in emit order
        self.history: list = []         # per-tick reports
        self._mesh_cache: "collections.OrderedDict[int, Any]" = \
            collections.OrderedDict()
        if server.mesh is not None:
            self._mesh_cache[server.mesh.n_groups] = server.mesh
        self._swap: Optional[_SwapState] = None
        self._canary: Optional[CanaryState] = None
        self._up_streak = 0
        self._down_streak = 0
        self._stage_ewma: dict = {}     # gid -> EWMA stage busy seconds
        self._straggler_streak: dict = {"gid": None, "n": 0}
        self._last = {"shed": self._shed_total(),
                      "served": self._served_total()}
        self._lock = threading.RLock()  # serializes control actions
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        for kind in self.EVENTS:        # record every fleet event locally
            server.platform.events.register(
                kind, (lambda k: lambda p: self.events.append((k, p)))(kind))
        # per-group stage busy time feeds the straggler EWMA (partial
        # reshape policy); posted by partition.execute on the dispatcher
        server.platform.events.register("stage_complete", self._on_stage)

    def _on_stage(self, payload: dict) -> None:
        gid, dt = payload.get("group"), payload.get("seconds")
        if gid is None or dt is None:
            return
        a = self.cfg.stage_ewma_alpha
        prev = self._stage_ewma.get(gid)
        self._stage_ewma[gid] = dt if prev is None else \
            (1.0 - a) * prev + a * dt

    # ----------------------------------------------------------- telemetry
    def _post(self, kind: str, payload: dict) -> None:
        self.server.platform.post(kind, payload)

    def _shed_total(self) -> int:
        s = self.server.scheduler.shed_count
        eng = getattr(self.server, "engine", None)
        if eng is not None and eng.scheduler is not None:
            s += eng.scheduler.shed_count
        return s

    def _served_total(self) -> int:
        return len(self.server.platform.telemetry._lat)

    def observe(self) -> dict:
        """One control-loop observation: queue pressure, miss rate since
        the previous observation, heartbeat verdicts (the controller's
        poll beats live groups and registers dead ones silent — exactly
        the liveness sweep partition.execute performs), and mesh ground
        truth."""
        server = self.server
        depth = server._loop.depth() + server.scheduler.pending()
        shed, served = self._shed_total(), self._served_total()
        shed_d = shed - self._last["shed"]
        served_d = served - self._last["served"]
        self._last = {"shed": shed, "served": served}
        mesh = server.mesh
        mesh_dead: list = []
        if mesh is not None:
            hb = server.platform.heartbeats
            for gid in mesh.gids:
                if mesh.alive(gid):
                    # step 0 on purpose: pipeline stages beat with their
                    # stage index during execution, which differs across
                    # groups legitimately — the step-lag straggler rule
                    # is for same-step data-parallel workers, not stages
                    hb.beat(f"tile{gid}", 0)
                else:
                    hb.register_silent(f"tile{gid}")
            mesh_dead = [g for g in mesh.gids if not mesh.alive(g)]
        verdict = server.platform.heartbeats.check()
        lat = server.platform.telemetry.summary(warmup=0)
        return {"depth": depth, "shed_delta": shed_d,
                "served_delta": served_d,
                "miss_rate": shed_d / max(1, shed_d + served_d),
                "n_groups": mesh.n_groups if mesh is not None else 1,
                "mesh_dead": mesh_dead, "verdicts": verdict["verdicts"],
                "failed": verdict["failed"],
                "stragglers": verdict["stragglers"],
                "p99": lat.get("p99")}

    # -------------------------------------------------------------- policy
    def _ladder_up(self, cur: int) -> Optional[int]:
        for n in sorted(self.cfg.ladder):
            if cur < n <= self.cfg.max_groups:
                return n
        return None

    def _ladder_down(self, cur: int) -> Optional[int]:
        for n in sorted(self.cfg.ladder, reverse=True):
            if cur > n >= self.cfg.min_groups:
                return n
        return None

    def _stage_straggler(self, obs: dict) -> Optional[int]:
        """A group whose stage-busy EWMA is ``stage_straggler_ratio``x
        the median of its peers, for ``straggler_ticks`` consecutive
        observations, is a straggler — replace it in place."""
        cfg = self.cfg
        if obs["n_groups"] < 2 or len(self._stage_ewma) < obs["n_groups"]:
            return None
        ew = {g: self._stage_ewma[g] for g in range(obs["n_groups"])
              if g in self._stage_ewma}
        if len(ew) < 2:
            return None
        worst = max(ew, key=ew.get)
        peers = [v for g, v in ew.items() if g != worst]
        med = float(np.median(peers))
        if med > 0 and ew[worst] > cfg.stage_straggler_ratio * med:
            st = self._straggler_streak
            st["n"] = st["n"] + 1 if st["gid"] == worst else 1
            st["gid"] = worst
            if st["n"] >= cfg.straggler_ticks:
                return worst
        else:
            self._straggler_streak = {"gid": None, "n": 0}
        return None

    def decide(self, obs: dict) -> Optional[tuple]:
        """Pure policy: observation -> action (None = hold). Hysteresis
        via consecutive-tick streaks so one noisy sample never reshapes
        the mesh."""
        cfg = self.cfg
        if obs["mesh_dead"]:
            dead = tuple(obs["mesh_dead"])
            # one dead group in a multi-group mesh: splice in a single
            # replacement instead of rebuilding the world
            if cfg.partial_reshape and len(dead) == 1 and \
                    obs["n_groups"] > 1:
                return ("replace", dead[0], "dead")
            return ("heal", dead)
        slow = self._stage_straggler(obs)
        if slow is not None and cfg.partial_reshape:
            return ("replace", slow, "straggler")
        pressure_up = obs["depth"] >= cfg.scale_up_depth or \
            obs["miss_rate"] > cfg.miss_rate_up
        pressure_down = obs["depth"] <= cfg.scale_down_depth and \
            obs["shed_delta"] == 0
        if pressure_up:
            self._up_streak += 1
            self._down_streak = 0
        elif pressure_down:
            self._down_streak += 1
            self._up_streak = 0
        else:
            self._up_streak = self._down_streak = 0
        cur = obs["n_groups"]
        if self._up_streak >= cfg.scale_up_ticks:
            nxt = self._ladder_up(cur)
            if nxt is not None:
                return ("scale", nxt)
        if self._down_streak >= cfg.scale_down_ticks:
            nxt = self._ladder_down(cur)
            if nxt is not None:
                return ("scale", nxt)
        return None

    def tick(self) -> dict:
        """One full control-loop iteration; callable from tests for
        deterministic stepping or from the background thread."""
        with self._lock:
            obs = self.observe()
            report: dict = {"obs": obs, "action": None}
            tile_stragglers = [w for w in obs["stragglers"]
                               if w.startswith("tile")]
            if tile_stragglers:
                self._post("straggler_detected",
                           {"workers": tile_stragglers})
            if self._swap is not None:
                report["swap"] = self._probation(obs)
            if self._canary is not None:
                report["canary"] = self._canary_tick()
            action = self.decide(obs)
            if action is not None:
                report["action"] = action
                try:
                    if action[0] == "heal":
                        self.heal(dead=action[1])
                    elif action[0] == "scale":
                        self.scale_to(action[1])
                    elif action[0] == "replace":
                        try:
                            self.replace_group(action[1], reason=action[2])
                        except Exception as e:
                            # a failed splice must not strand a dead
                            # group: fall back to the full heal path
                            self._post("fleet_error",
                                       {"action": action,
                                        "error": repr(e),
                                        "fallback": "heal"})
                            self.heal()
                except Exception as e:
                    report["error"] = repr(e)
                    self._post("fleet_error",
                               {"action": action, "error": repr(e)})
            self.history.append(report)
            return report

    # ------------------------------------------------------------- scaling
    def _build_mesh(self, n: int):
        mesh = self._mesh_cache.get(n)
        if mesh is not None and all(mesh.alive(g) for g in mesh.gids):
            self._mesh_cache.move_to_end(n)
            return mesh, True
        self._mesh_cache.pop(n, None)   # never reuse a mesh with dead groups
        mesh = rhal_mod.TileMesh(n)
        return mesh, False

    def _prewarm(self, mesh, bound=None, rimfs=None) -> None:
        """Partition + bind + link + pin weights against the new mesh's
        drivers, OFF the dispatcher thread: by flip time the first
        request pays nothing. The per-tile bind caches and the
        per-group-count partition cache make this idempotent."""
        server = self.server
        bound = bound if bound is not None else server._bound
        rimfs = rimfs if rimfs is not None else server.platform.rimfs
        part = partition_mod.ensure_partition(bound, mesh.n_groups)
        partition_mod.prewarm(part, mesh, rimfs=rimfs)

    def _cache_mesh(self, mesh) -> None:
        self._mesh_cache[mesh.n_groups] = mesh
        self._mesh_cache.move_to_end(mesh.n_groups)
        while len(self._mesh_cache) > self.cfg.mesh_cache_cap:
            self._mesh_cache.popitem(last=False)

    def scale_to(self, n: int) -> dict:
        """Reshape the live mesh to ``n`` tile groups without dropping a
        request: pre-warm off-thread, flip on the dispatcher (between
        requests), resume. Returns the scale report."""
        with self._lock:
            server = self.server
            if server._bound is None:
                raise FleetError("cannot scale: server not provisioned")
            cur = server.mesh.n_groups if server.mesh is not None else 1
            if n == cur and server.mesh is not None:
                return {"from": cur, "to": n, "noop": True}
            t0 = time.perf_counter()
            self._post("scale_started", {"from": cur, "to": n})
            mesh, cached = self._build_mesh(n)
            self._prewarm(mesh)

            def flip():
                server.mesh = mesh
                return server._loop.depth()

            depth_at_flip = server.run_on_dispatcher(
                flip, timeout=self.cfg.control_timeout)
            if server.mesh is not None:
                self._cache_mesh(mesh)
            self._up_streak = self._down_streak = 0
            report = {"from": cur, "to": n, "cached_mesh": cached,
                      "depth_at_flip": depth_at_flip,
                      "seconds": time.perf_counter() - t0}
            self._post("scale_complete", report)
            return report

    def heal(self, dead: tuple = ()) -> dict:
        """Replace a mesh with dead groups by a fresh same-size mesh.
        In-flight stages already failed over to survivors (partition
        re-queue); healing restores full capacity for what follows."""
        with self._lock:
            server = self.server
            mesh = server.mesh
            if mesh is None:
                raise FleetError("no mesh to heal")
            n = mesh.n_groups
            dead = tuple(dead) or tuple(g for g in mesh.gids
                                        if not mesh.alive(g))
            t0 = time.perf_counter()
            self._post("heal_started", {"n_groups": n, "dead": list(dead)})
            self._mesh_cache.pop(n, None)      # poisoned: drop it
            if server.platform.rimfs is not None:
                # tile-group death integrity sweep: the fresh mesh must
                # only ever prewarm from a CRC-clean weight store
                server.platform.rimfs.fsck(strict=False)
                self._post("rimfs_fsck", {"phase": "heal"})
            fresh = rhal_mod.TileMesh(n)
            self._prewarm(fresh)

            def flip():
                server.mesh = fresh
                return True

            server.run_on_dispatcher(flip, timeout=self.cfg.control_timeout)
            self._cache_mesh(fresh)
            # dead tile workers answered their last poll long ago; revive
            # the names so the fresh mesh's groups aren't born "failed"
            for gid in fresh.gids:
                server.platform.heartbeats.beat(f"tile{gid}", 0)
            report = {"n_groups": n, "dead": list(dead),
                      "seconds": time.perf_counter() - t0}
            self._post("heal_complete", report)
            return report

    # ----------------------------------------------------- partial reshape
    def replace_group(self, gid: int, reason: str = "manual") -> dict:
        """Replace ONE tile group in place (partial reshape, §14).

        Off-thread: spawn a fresh driver for the slot, prewarm exactly
        that stage's tile bind against it (one stage's weight bytes move
        — survivors' arenas, bind caches and DMA counters are untouched)
        and CRC re-validate the new residency. On-thread: a one-pointer
        ``install_group`` splice between requests. Zero dropped work —
        in-flight stages on a dead group already failed over."""
        with self._lock:
            server = self.server
            mesh = server.mesh
            if mesh is None:
                raise FleetError("no mesh to reshape")
            if server._bound is None:
                raise FleetError("cannot reshape: server not provisioned")
            t0 = time.perf_counter()
            self._post("reshape_started", {"group": gid, "reason": reason})
            fs = server.platform.rimfs
            if fs is not None:
                # the replacement must only prewarm from a CRC-clean
                # store (same integrity sweep the full heal runs)
                fs.fsck(strict=False)
                self._post("rimfs_fsck", {"phase": "reshape"})
            fresh = mesh.spawn_replacement(gid)
            part = partition_mod.ensure_partition(server._bound,
                                                  mesh.n_groups)
            partition_mod.prewarm_group(part, fresh.driver, gid, rimfs=fs)
            if fs is not None:
                entry = fs._resident.get(id(fresh.driver))
                if entry is not None and not entry[1].revalidate():
                    raise FleetError(
                        f"replacement group {gid} failed CRC revalidation")

            def splice():
                mesh.install_group(fresh)
                return server._loop.depth()

            depth_at_splice = server.run_on_dispatcher(
                splice, timeout=self.cfg.control_timeout)
            # the slot's worker name is live again; reset its rhythm and
            # the straggler bookkeeping that targeted the old hardware
            server.platform.heartbeats.beat(f"tile{gid}", 0)
            self._stage_ewma.pop(gid, None)
            self._straggler_streak = {"gid": None, "n": 0}
            report = {"group": gid, "reason": reason,
                      "depth_at_splice": depth_at_splice,
                      "seconds": time.perf_counter() - t0}
            self._post("reshape_complete", report)
            return report

    # ------------------------------------------------------------ hot swap
    def _golden_inputs(self, program) -> dict:
        return golden_inputs(program, seed=self.cfg.probe_seed)

    def swap_weights(self, image: bytes, label: str = "") -> str:
        """Zero-downtime weight swap. Returns "committed" or
        "rolled_back". The old binding's residency survives until
        ``finalize`` (probation's end), so rollback is a pointer flip
        that re-uploads zero bytes."""
        with self._lock:
            server = self.server
            if server._bound is None:
                raise FleetError("cannot swap: server not provisioned")
            if self._swap is not None:
                raise FleetError("swap already in probation; finalize or "
                                 "roll back first")
            self._post("swap_started",
                       {"label": label, "bytes": len(image)})
            try:
                new_fs = rimfs_mod.mount(image)
                new_fs.verify_image()
            except Exception as e:
                self._post("swap_rolled_back",
                           {"label": label, "reason": f"mount: {e}"})
                return "rolled_back"
            program = server.platform.program
            shadow = rbl_mod.bind(program, rimfs=new_fs)
            golden = self._golden_inputs(program)
            # reference answer from the LIVE binding, on the dispatcher
            # (so it reflects exactly what clients are being served)
            ref = server.run_on_dispatcher(
                lambda: server._infer(golden),
                timeout=self.cfg.control_timeout)
            from repro.core.executor import Executor
            probe = Executor().run(shadow, inputs=golden, rimfs=new_fs)
            probe = {k: np.asarray(v) for k, v in probe.items()}
            ok = set(probe) == set(ref) and all(
                probe[k].shape == ref[k].shape
                and np.array_equal(probe[k], ref[k]) for k in ref)
            self._post("swap_probed", {"label": label, "ok": ok})
            if not ok:
                self._post("swap_rolled_back",
                           {"label": label, "reason": "probe mismatch"})
                return "rolled_back"
            if server.mesh is not None:
                # pin the new image into the live mesh's arenas BEFORE
                # the flip — alongside the old image, never displacing it
                self._prewarm(server.mesh, bound=shadow, rimfs=new_fs)

            def flip():
                old = (server.platform.rimfs, server._bound)
                server.platform.rimfs = new_fs
                server._bound = shadow
                return old

            old_rimfs, old_bound = server.run_on_dispatcher(
                flip, timeout=self.cfg.control_timeout)
            self._swap = _SwapState(
                old_rimfs=old_rimfs, old_bound=old_bound,
                new_rimfs=new_fs, new_bound=shadow,
                shed_baseline=self._shed_total(),
                served_baseline=self._served_total())
            self._post("swap_committed", {"label": label})
            return "committed"

    def _probation(self, obs: dict) -> dict:
        """Post-swap watch: a deadline-miss spike rolls the swap back
        automatically; a quiet window finalizes it.

        Finalization is REQUEST-count gated, not wall-clock gated: the
        new binding must have served ``probation_requests`` real
        requests (plus ``probation_ticks`` ticks as a floor) before the
        old image's residency is released. An idle fleet therefore never
        silently passes probation — zero traffic means rollback stays a
        zero-byte pointer flip indefinitely."""
        swap = self._swap
        swap.ticks += 1
        shed = self._shed_total() - swap.shed_baseline
        served = self._served_total() - swap.served_baseline
        window = shed + served
        rate = shed / max(1, window)
        if window >= self.cfg.spike_min_window and \
                rate > self.cfg.miss_spike:
            self.rollback(reason=f"miss_spike: {rate:.2f} over "
                          f"{window} requests")
            return {"state": "rolled_back", "miss_rate": rate,
                    "served": served}
        if swap.ticks >= self.cfg.probation_ticks and \
                served >= self.cfg.probation_requests:
            self.finalize_swap()
            return {"state": "finalized", "miss_rate": rate,
                    "served": served}
        return {"state": "probation", "tick": swap.ticks,
                "served": served, "miss_rate": rate}

    def rollback(self, reason: str = "manual") -> None:
        """Flip back to the pre-swap binding. The old residency was kept
        pinned through probation, so this moves zero weight bytes."""
        with self._lock:
            swap = self._swap
            if swap is None:
                raise FleetError("no swap to roll back")
            server = self.server

            def flip_back():
                server.platform.rimfs = swap.old_rimfs
                server._bound = swap.old_bound
                return True

            server.run_on_dispatcher(flip_back,
                                     timeout=self.cfg.control_timeout)
            self._release_residency(swap.new_rimfs)
            self._swap = None
            self._post("swap_rolled_back", {"reason": reason})

    def finalize_swap(self) -> None:
        """End probation: the new image is trusted; release the old
        image's device residency (configurable)."""
        with self._lock:
            swap = self._swap
            if swap is None:
                return
            freed = 0
            if self.cfg.finalize_unpin and \
                    swap.old_rimfs is not swap.new_rimfs:
                freed = self._release_residency(swap.old_rimfs)
            self._swap = None
            self._post("swap_finalized", {"freed_bytes": freed})

    # -------------------------------------------------------------- canary
    def canary(self, image: bytes, fraction: Optional[float] = None,
               label: str = "", sample_fraction: Optional[float] = None,
               serve_shadow: Optional[bool] = None) -> str:
        """Start a canary A/B rollout of ``image`` (DESIGN.md §14).

        Mount + CRC-verify the image, bind it as a shadow, prewarm the
        live mesh from it (alongside the primary — never displacing it),
        then install a ``CanaryState`` on the dispatcher: a hash-routed
        ``fraction`` of plain-RCB traffic executes on the shadow, and a
        ``sample_fraction`` of THAT also dual-runs the primary to feed
        the SPRT an agree/disagree bit. A sampled disagreement is always
        answered with the primary's bytes, so with the default
        ``sample_fraction=1.0`` a broken canary serves zero wrong bytes
        before the SPRT aborts it. Returns "started" or "aborted"."""
        with self._lock:
            server = self.server
            cfg = self.cfg
            if server._bound is None:
                raise FleetError("cannot canary: server not provisioned")
            if self._canary is not None:
                raise FleetError("canary already in flight; promote or "
                                 "abort it first")
            if self._swap is not None:
                raise FleetError("swap in probation; finalize or roll "
                                 "back before starting a canary")
            frac = cfg.canary_fraction if fraction is None else fraction
            self._post("canary_started",
                       {"label": label, "fraction": frac,
                        "bytes": len(image)})
            try:
                new_fs = rimfs_mod.mount(image)
                new_fs.verify_image()
            except Exception as e:
                self._post("canary_aborted",
                           {"label": label, "reason": f"mount: {e}"})
                return "aborted"
            program = server.platform.program
            shadow = rbl_mod.bind(program, rimfs=new_fs)
            if server.mesh is not None:
                self._prewarm(server.mesh, bound=shadow, rimfs=new_fs)
            state = CanaryState(
                bound=shadow, fs=new_fs, fraction=frac,
                sprt=SPRT(p_good=cfg.canary_p_good,
                          p_bad=cfg.canary_p_bad,
                          alpha=cfg.canary_alpha, beta=cfg.canary_beta,
                          min_samples=cfg.canary_min_samples,
                          max_samples=cfg.canary_max_samples),
                label=label,
                sample_fraction=cfg.canary_sample_fraction
                if sample_fraction is None else sample_fraction,
                serve_shadow=cfg.canary_serve_shadow
                if serve_shadow is None else serve_shadow,
                token_threshold=cfg.canary_token_threshold)

            def install():
                server.canary = state
                return True

            server.run_on_dispatcher(install,
                                     timeout=cfg.control_timeout)
            self._canary = state
            return "started"

    def _canary_tick(self) -> dict:
        """Poll the SPRT from the control loop and act on its verdict."""
        state = self._canary
        verdict = state.sprt.verdict()
        if verdict == "promote":
            self.promote_canary()
        elif verdict == "abort":
            self.abort_canary(reason="sprt")
        return dict(state.sprt.summary(), stats=dict(state.stats),
                    state=verdict or "sampling")

    def promote_canary(self) -> None:
        """The SPRT accepted H_good: flip the shadow to primary (atomic,
        between requests) and release the OLD image's residency. The
        shadow's weights were prewarmed at canary start, so promotion
        moves zero weight bytes."""
        with self._lock:
            state = self._canary
            if state is None:
                raise FleetError("no canary to promote")
            server = self.server

            def flip():
                server.canary = None
                old = (server.platform.rimfs, server._bound)
                server.platform.rimfs = state.fs
                server._bound = state.bound
                return old

            old_fs, _old_bound = server.run_on_dispatcher(
                flip, timeout=self.cfg.control_timeout)
            freed = 0
            if self.cfg.finalize_unpin and old_fs is not state.fs:
                freed = self._release_residency(old_fs)
            self._canary = None
            self._post("canary_promoted",
                       dict(state.sprt.summary(), label=state.label,
                            stats=dict(state.stats), freed_bytes=freed))

    def abort_canary(self, reason: str = "manual") -> None:
        """The SPRT accepted H_bad (or the operator pulled the cord):
        detach the canary and drop the shadow's residency. The primary
        binding was never touched — abort moves zero primary bytes."""
        with self._lock:
            state = self._canary
            if state is None:
                raise FleetError("no canary to abort")
            server = self.server

            def clear():
                server.canary = None
                return True

            server.run_on_dispatcher(clear,
                                     timeout=self.cfg.control_timeout)
            self._release_residency(state.fs)
            self._canary = None
            self._post("canary_aborted",
                       dict(state.sprt.summary(), label=state.label,
                            stats=dict(state.stats), reason=reason))

    @staticmethod
    def _release_residency(fs) -> int:
        """Unpin every driver's resident copy of ``fs`` (arena ranges
        freed; the RIMFS host image itself is untouched)."""
        if fs is None:
            return 0
        freed = 0
        for _key, (_ref, ri) in list(fs._resident.items()):
            freed += ri.nbytes()
            ri.unpin()
        fs._resident.clear()
        return freed

    # ----------------------------------------------------------- lifecycle
    def start(self, interval: float = 0.2) -> None:
        """Run ``tick`` on a background thread every ``interval``s."""
        if self._thread is not None:
            raise FleetError("controller already running")
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval):
                try:
                    self.tick()
                except Exception as e:   # a bad tick must not kill the loop
                    self._post("fleet_error", {"error": repr(e)})

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="fleet-controller")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=10)
        self._thread = None

    def summary(self) -> dict:
        kinds = collections.Counter(k for k, _ in self.events)
        return {"ticks": len(self.history), "events": dict(kinds),
                "mesh_cache": sorted(self._mesh_cache),
                "swap_in_probation": self._swap is not None,
                "canary": self._canary.sprt.summary()
                if self._canary is not None else None}
