"""Peephole optimizer over RCB op streams (RCTC's pre-emission pass).

Because control is *data*, optimizing a workload is list surgery on its op
stream — no retracing, no recompilation of model code.  RCTC runs this pass
before emitting a program; the executor and linker are unaware it exists.

Rules (DESIGN.md §5):

  F1  SCALE_SHIFT + RELU  ->  SCALE_SHIFT_RELU   (fused vtable slot)
  F2  ADD + RELU          ->  ADD_RELU           (fused vtable slot)
  E1  DEQUANT(s) + QUANTIZE(s) -> PASSTHROUGH    (exact round-trip elision:
      int8 -> fp32 -> int8 at the same scale reproduces the input bits,
      PROVIDED the int8 source came from an in-program QUANTIZE — those
      clip to [-127, 127]; a raw -128 would be re-clipped by the round
      trip but preserved by PASSTHROUGH, so unknown-provenance sources
      only elide with ``lossy=True``)
  E2  QUANTIZE(s) + DEQUANT(s) -> PASSTHROUGH    (LOSSY — the fp->int8->fp
      trip rounds; only applied with ``lossy=True``)
  C1  adjacent DMA / copy coalescing: a copy chain through a single-use
      scratch collapses to one transfer (H2D+D2D -> H2D, D2D+D2H -> D2H,
      D2D+D2D -> D2D, PASSTHROUGH chains, ...)
  D1  dead scratch / op elimination: side-effect-free ops whose results are
      never read are removed (to fixpoint), along with their scratch
      descriptors.

Every rule except E2 is bit-exact: fused slots execute the identical
primitive sequence, elision/coalescing only remove ops whose outputs are
reproduced exactly.  All rules fire only when the intermediate is a
single-use scratch, so observable buffers (inputs/outputs/weights) are
never touched.
"""
from __future__ import annotations

import collections
from typing import Optional

from repro.core.rcb import Op, RCB, RCBOp, RCBProgram

# compute ops + buffer-table ops with no effect beyond their dst buffer
_PURE = {
    Op.ALLOC, Op.FREE, Op.BIND_CONST, Op.GEMM, Op.CONV2D, Op.DENSE, Op.ADD,
    Op.RELU, Op.SOFTMAX, Op.MAXPOOL, Op.AVGPOOL_GLOBAL, Op.SCALE_SHIFT,
    Op.QUANTIZE, Op.DEQUANT, Op.RESHAPE, Op.GEMM_I8, Op.CONV2D_I8,
    Op.PASSTHROUGH, Op.SCALE_SHIFT_RELU, Op.ADD_RELU,
    # LM-layer ops (per-layer RCTC lowering): side-effect-free computes,
    # eligible for dead-scratch elimination like any other compute slot.
    Op.RMSNORM, Op.ROPE, Op.SILU_MUL,
    Op.ATTENTION, Op.MATMUL_INT8, Op.SSM_SCAN, Op.WKV6,
}

_FUSE_RELU = {Op.SCALE_SHIFT: Op.SCALE_SHIFT_RELU, Op.ADD: Op.ADD_RELU}

# copy-chain coalescing: (first, second) -> coalesced transfer kind
_COALESCE = {
    (Op.DMA_H2D, Op.DMA_D2D): Op.DMA_H2D,
    (Op.DMA_H2D, Op.PASSTHROUGH): Op.DMA_H2D,
    (Op.DMA_D2D, Op.DMA_D2D): Op.DMA_D2D,
    (Op.DMA_D2D, Op.DMA_D2H): Op.DMA_D2H,
    (Op.DMA_D2D, Op.PASSTHROUGH): Op.DMA_D2D,
    (Op.PASSTHROUGH, Op.PASSTHROUGH): Op.PASSTHROUGH,
    (Op.PASSTHROUGH, Op.DMA_D2D): Op.DMA_D2D,
    (Op.PASSTHROUGH, Op.DMA_D2H): Op.DMA_D2H,
}


def op_count(prog: RCBProgram) -> int:
    return sum(len(b.ops) for b in prog.blocks)


def _use_counts(blocks: list) -> tuple:
    """Global read/write counts per symbol across ALL blocks — peephole
    windows are per-block, but safety is whole-program."""
    reads: collections.Counter = collections.Counter()
    writes: collections.Counter = collections.Counter()
    for ops in blocks:
        for op in ops:
            reads.update(op.srcs)
            writes.update(op.dsts)
    return reads, writes


def _single_use_scratch(sym: str, tensors: dict, reads, writes) -> bool:
    t = tensors.get(sym)
    return (t is not None and t.kind == "scratch"
            and reads[sym] == 1 and writes[sym] == 1)


def _pair_pass(blocks: list, tensors: dict, lossy: bool) -> bool:
    """One sweep of the two-op window rules (F1/F2/E1/E2/C1)."""
    reads, writes = _use_counts(blocks)
    # int8 symbols with known clipped range [-127, 127] (E1 exactness)
    quantized = {op.dsts[0] for ops in blocks for op in ops
                 if op.op is Op.QUANTIZE and op.dsts}
    changed = False
    for bi, ops in enumerate(blocks):
        out: list = []
        i = 0
        while i < len(ops):
            a = ops[i]
            b = ops[i + 1] if i + 1 < len(ops) else None
            fused: Optional[RCBOp] = None
            if (b is not None and a.dsts and b.srcs == (a.dsts[0],)
                    and _single_use_scratch(a.dsts[0], tensors, reads,
                                            writes)):
                mid = a.dsts[0]
                if b.op is Op.RELU and a.op in _FUSE_RELU:
                    fused = RCBOp(_FUSE_RELU[a.op], b.dsts, a.srcs, a.attrs)
                elif (a.op is Op.DEQUANT and b.op is Op.QUANTIZE
                      and a.attrs.get("scale") == b.attrs.get("scale")
                      and (lossy or a.srcs[0] in quantized)):
                    fused = RCBOp(Op.PASSTHROUGH, b.dsts, a.srcs)
                elif (lossy and a.op is Op.QUANTIZE and b.op is Op.DEQUANT
                      and a.attrs.get("scale") == b.attrs.get("scale")):
                    fused = RCBOp(Op.PASSTHROUGH, b.dsts, a.srcs)
                elif (a.op, b.op) in _COALESCE:
                    fused = RCBOp(_COALESCE[(a.op, b.op)], b.dsts, a.srcs)
                if fused is not None:
                    # keep counters consistent for later windows this sweep
                    reads[mid] -= 1
                    writes[mid] -= 1
                    reads.update(fused.srcs)
                    for s in a.srcs:
                        reads[s] -= 1
            if fused is not None:
                out.append(fused)
                i += 2
                changed = True
            else:
                out.append(a)
                i += 1
        blocks[bi] = out
    return changed


def _dead_pass(blocks: list, tensors: dict) -> bool:
    """Remove side-effect-free ops whose dsts are never-read scratch."""
    reads, _writes = _use_counts(blocks)
    changed = False
    for bi, ops in enumerate(blocks):
        out = []
        for op in ops:
            if (op.op in _PURE and op.dsts
                    and all(tensors.get(d) is not None
                            and tensors[d].kind == "scratch"
                            and reads[d] == 0 for d in op.dsts)):
                for s in op.srcs:
                    reads[s] -= 1          # may cascade on the next sweep
                changed = True
                continue
            out.append(op)
        blocks[bi] = out
    return changed


def optimize(prog: RCBProgram, lossy: bool = False) -> RCBProgram:
    """Run all peephole rules to fixpoint; returns a new RCBProgram.

    Block boundaries, ids and deps are preserved (an emptied block stays as
    an empty RCB so dependency edges keep resolving).
    """
    blocks = [list(b.ops) for b in prog.blocks]
    for _ in range(64):                        # fixpoint, bounded
        changed = _pair_pass(blocks, prog.tensors, lossy)
        changed |= _dead_pass(blocks, prog.tensors)
        if not changed:
            break
    # drop scratch descriptors no longer referenced by any op
    referenced: set = set()
    for ops in blocks:
        for op in ops:
            referenced.update(op.dsts)
            referenced.update(op.srcs)
    tensors = {n: t for n, t in prog.tensors.items()
               if t.kind != "scratch" or n in referenced}
    new_blocks = [RCB(b.block_id, b.block_type, b.deps, tuple(ops))
                  for b, ops in zip(prog.blocks, blocks)]
    out = RCBProgram(prog.name, tensors, new_blocks, prog.artifacts)
    out.validate()
    return out
