"""Pure-JAX semantics for every RCB compute op.

One function per opcode; both RHAL drivers (eager CPU-interpret and fused
XLA) dispatch through this table, so the two execution modes are equivalent
by construction — the paper's portability claim, testable.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.rcb import Op


def gemm(a, b, attrs):
    ta, tb = attrs.get("ta", False), attrs.get("tb", False)
    a = a.T if ta else a
    b = b.T if tb else b
    return jnp.matmul(a, b)


def gemm_i8(a, b, attrs):
    return jax.lax.dot(a, b, preferred_element_type=jnp.int32)


def conv2d(x, w, attrs):
    """x: (N,H,W,C), w: (KH,KW,C,O)."""
    stride = tuple(attrs.get("stride", (1, 1)))
    padding = attrs.get("padding", "SAME")
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv2d_i8(x, w, attrs):
    stride = tuple(attrs.get("stride", (1, 1)))
    padding = attrs.get("padding", "SAME")
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)


def dense(x, w, b=None, attrs=None):
    y = jnp.matmul(x, w)
    if b is not None:
        y = y + b
    return y


def add(a, b, attrs):
    return a + b


def relu(x, attrs):
    return jnp.maximum(x, 0)


def softmax(x, attrs):
    return jax.nn.softmax(x.astype(jnp.float32),
                          axis=attrs.get("axis", -1)).astype(x.dtype)


def maxpool(x, attrs):
    win = tuple(attrs.get("window", (2, 2)))
    stride = tuple(attrs.get("stride", win))
    pad = attrs.get("padding", "VALID")
    if pad == "SAME":
        pads = jax.lax.padtype_to_pads(
            x.shape, (1, *win, 1), (1, *stride, 1), "SAME")
    else:
        pads = [(0, 0)] * x.ndim
    init = (-jnp.inf if jnp.issubdtype(x.dtype, jnp.floating)
            else jnp.iinfo(x.dtype).min)
    return jax.lax.reduce_window(
        x, init, jax.lax.max, (1, *win, 1), (1, *stride, 1), pads)


def avgpool_global(x, attrs):
    return jnp.mean(x, axis=(1, 2))


def scale_shift(x, scale, shift, attrs=None):
    return x * scale + shift


def scale_shift_relu(x, scale, shift, attrs=None):
    """Fused SCALE_SHIFT+RELU vtable slot (core/opt.py peephole rule F1)."""
    return jnp.maximum(x * scale + shift, 0)


def add_relu(a, b, attrs=None):
    """Fused ADD+RELU vtable slot (core/opt.py peephole rule F2)."""
    return jnp.maximum(a + b, 0)


def quantize(x, attrs):
    scale = attrs["scale"]
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def dequantize(x, attrs):
    return x.astype(jnp.float32) * attrs["scale"]


def reshape(x, attrs):
    return jnp.reshape(x, tuple(attrs["shape"]))


def passthrough(x, attrs):
    return x


def rmsnorm(x, w, attrs):
    from repro.models.common import rms_norm
    return rms_norm(x, w, eps=float((attrs or {}).get("eps", 1e-5)))


def rope(x, positions, attrs):
    from repro.models.common import apply_rope
    return apply_rope(x, positions,
                      theta=float((attrs or {}).get("theta", 10000.0)))


def silu_mul(gate, x, attrs=None):
    return jax.nn.silu(gate) * x


# Kernel opcodes dispatch through the registry (kernels/registry.py) so the
# interpreted path, GRAPH_EXEC artifacts and linked handlers share one
# implementation per kernel (fallback ladder included).
OP_KERNELS: dict[Op, str] = {
    Op.ATTENTION: "attention",
    Op.MATMUL_INT8: "matmul_int8",
    Op.SSM_SCAN: "ssm_scan",
    Op.WKV6: "wkv6",
}


def _kernel_fn(name: str) -> Callable:
    def fn(srcs, attrs):
        from repro.kernels import registry
        return registry.call_op(name, srcs, attrs)
    return fn


_TABLE: dict[Op, Callable] = {
    Op.GEMM: lambda srcs, attrs: gemm(srcs[0], srcs[1], attrs),
    Op.GEMM_I8: lambda srcs, attrs: gemm_i8(srcs[0], srcs[1], attrs),
    Op.CONV2D: lambda srcs, attrs: conv2d(srcs[0], srcs[1], attrs),
    Op.CONV2D_I8: lambda srcs, attrs: conv2d_i8(srcs[0], srcs[1], attrs),
    Op.DENSE: lambda srcs, attrs: dense(*srcs, attrs=attrs),
    Op.ADD: lambda srcs, attrs: add(srcs[0], srcs[1], attrs),
    Op.RELU: lambda srcs, attrs: relu(srcs[0], attrs),
    Op.SOFTMAX: lambda srcs, attrs: softmax(srcs[0], attrs),
    Op.MAXPOOL: lambda srcs, attrs: maxpool(srcs[0], attrs),
    Op.AVGPOOL_GLOBAL: lambda srcs, attrs: avgpool_global(srcs[0], attrs),
    Op.SCALE_SHIFT: lambda srcs, attrs: scale_shift(*srcs, attrs=attrs),
    Op.SCALE_SHIFT_RELU: lambda srcs, attrs: scale_shift_relu(*srcs,
                                                              attrs=attrs),
    Op.ADD_RELU: lambda srcs, attrs: add_relu(srcs[0], srcs[1], attrs),
    Op.QUANTIZE: lambda srcs, attrs: quantize(srcs[0], attrs),
    Op.DEQUANT: lambda srcs, attrs: dequantize(srcs[0], attrs),
    Op.RESHAPE: lambda srcs, attrs: reshape(srcs[0], attrs),
    Op.PASSTHROUGH: lambda srcs, attrs: passthrough(srcs[0], attrs),
    Op.RMSNORM: lambda srcs, attrs: rmsnorm(srcs[0], srcs[1], attrs),
    Op.ROPE: lambda srcs, attrs: rope(srcs[0], srcs[1], attrs),
    Op.SILU_MUL: lambda srcs, attrs: silu_mul(srcs[0], srcs[1], attrs),
    Op.ATTENTION: _kernel_fn("attention"),
    Op.MATMUL_INT8: _kernel_fn("matmul_int8"),
    Op.SSM_SCAN: _kernel_fn("ssm_scan"),
    Op.WKV6: _kernel_fn("wkv6"),
}


def compute(op: Op, srcs, attrs):
    """Execute one compute opcode on already-bound operands."""
    fn = _TABLE.get(op)
    if fn is None:
        raise NotImplementedError(f"no semantics for {op!r}")
    return fn(srcs, attrs)


def lookup(op: Op) -> Callable:
    """Resolve one opcode to its handler ``fn(srcs, attrs)`` ahead of time.

    The program linker (core/linker.py) calls this once per op at link time
    so the hot dispatch loop never touches the table again.
    """
    fn = _TABLE.get(op)
    if fn is None:
        raise NotImplementedError(f"no semantics for {op!r}")
    return fn
