"""Runtime In-Memory File System — flat, read-only, zero-copy weight store.

Image layout (all little-endian):

  [0:4]   magic  b"RIMF"
  [4:6]   version
  [6:8]   flags
  [8:12]  n_files
  [12:16] index_bytes
  [16:..] index: per file a json-encoded entry
          {name, offset, nbytes, dtype, shape, crc32}
  [..]    128-byte aligned data region (one aligned blob per file)
  [-4:]   CRC-32 of everything before it

``mount()`` wraps a bytes-like object and serves **zero-copy numpy views**
via ``np.frombuffer`` — no deserialization, no copies; exactly the paper's
"returns physical addresses directly to the DMA engine" property (the view's
buffer pointer IS what ``jax.device_put`` consumes). The image doubles as
the checkpoint format (checkpoint/ckpt.py) and the network provisioning
payload (serving/protocol.py).
"""
from __future__ import annotations

import io
import json
import pathlib
import struct
import zlib
from typing import Mapping, Optional, Union

import numpy as np

MAGIC = b"RIMF"
ALIGN = 128          # GMIO-alignment analogue: TPU-friendly 128B lanes


class RIMFSError(ValueError):
    pass


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def pack(files: Mapping[str, np.ndarray], *, version: int = 1) -> bytes:
    """Flatten named arrays into one RIMFS image."""
    index = []
    blobs = []
    # header size depends on index size; compute index first with
    # placeholder offsets, then fix up (entries are fixed-length jsons once
    # offsets are known, so do two passes with stable formatting).
    metas = []
    for name, arr in files.items():
        arr = np.ascontiguousarray(arr)
        metas.append((name, arr))

    def build_index(data_start: int):
        out, off = [], data_start
        for name, arr in metas:
            off = _align(off)
            out.append({
                "name": name, "offset": off, "nbytes": int(arr.nbytes),
                "dtype": arr.dtype.str, "shape": list(arr.shape),
                "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
            })
            off += arr.nbytes
        return out, off

    # iterate to fixed point: index length changes offset digits rarely; two
    # passes suffice in practice, loop defensively.
    data_start = 16
    for _ in range(5):
        index, total = build_index(data_start)
        blob = json.dumps(index, separators=(",", ":")).encode()
        new_start = 16 + len(blob)
        if new_start == data_start:
            break
        data_start = new_start
    index, total = build_index(data_start)
    blob = json.dumps(index, separators=(",", ":")).encode()

    buf = bytearray(_align(total) + 4)
    struct.pack_into("<4sHHII", buf, 0, MAGIC, version, 0, len(metas),
                     len(blob))
    buf[16:16 + len(blob)] = blob
    for entry, (name, arr) in zip(index, metas):
        o = entry["offset"]
        buf[o:o + arr.nbytes] = arr.tobytes()
    crc = zlib.crc32(bytes(buf[:-4])) & 0xFFFFFFFF
    struct.pack_into("<I", buf, len(buf) - 4, crc)
    return bytes(buf)


class RIMFS:
    """A mounted image. All reads are zero-copy views into the backing
    buffer; ``verify()`` checks per-file CRCs without copying."""

    def __init__(self, data: Union[bytes, bytearray, memoryview, np.memmap]):
        self._data = data
        buf = memoryview(data) if not isinstance(data, np.memmap) else data
        magic, ver, _flags, n, ilen = struct.unpack_from("<4sHHII", buf, 0)
        if bytes(magic) != MAGIC:
            raise RIMFSError(f"bad RIMFS magic: {bytes(magic)!r}")
        self.version = ver
        index = json.loads(bytes(buf[16:16 + ilen]).decode())
        if len(index) != n:
            raise RIMFSError("index length mismatch")
        self._index = {e["name"]: e for e in index}

    # ------------------------------------------------------------------ api
    def files(self) -> list:
        return list(self._index)

    def stat(self, name: str) -> dict:
        return dict(self._index[name])

    def read(self, name: str) -> np.ndarray:
        """Zero-copy ndarray view of one file."""
        e = self._index.get(name)
        if e is None:
            raise RIMFSError(f"no such file: {name!r}")
        return np.frombuffer(
            self._data, dtype=np.dtype(e["dtype"]),
            count=int(np.prod(e["shape"])) if e["shape"] else 1,
            offset=e["offset"]).reshape(e["shape"])

    def address_of(self, name: str) -> tuple:
        """(offset, nbytes) — the paper's 'physical address' for DMA."""
        e = self._index[name]
        return e["offset"], e["nbytes"]

    def verify(self, name: Optional[str] = None) -> bool:
        names = [name] if name else self.files()
        for n in names:
            e = self._index[n]
            view = self.read(n)
            if (zlib.crc32(view.tobytes()) & 0xFFFFFFFF) != e["crc32"]:
                raise RIMFSError(f"CRC mismatch in {n!r}")
        return True

    def verify_image(self) -> bool:
        raw = bytes(self._data) if not isinstance(self._data, (bytes,)) \
            else self._data
        (crc,) = struct.unpack_from("<I", raw, len(raw) - 4)
        if crc != (zlib.crc32(raw[:-4]) & 0xFFFFFFFF):
            raise RIMFSError("image CRC mismatch")
        return True

    def total_bytes(self) -> int:
        return len(self._data)

    def overhead_bytes(self) -> int:
        """Non-payload bytes (header + index + padding) — the 'runtime
        memory overhead' the paper compares against OS file systems."""
        payload = sum(e["nbytes"] for e in self._index.values())
        return self.total_bytes() - payload


def mount(data: Union[bytes, bytearray, memoryview]) -> RIMFS:
    return RIMFS(data)


def mount_file(path: Union[str, pathlib.Path]) -> RIMFS:
    """mmap-backed mount: zero-copy straight from the page cache."""
    mm = np.memmap(str(path), dtype=np.uint8, mode="r")
    return RIMFS(mm)


def save_file(path: Union[str, pathlib.Path],
              files: Mapping[str, np.ndarray]) -> int:
    img = pack(files)
    pathlib.Path(path).write_bytes(img)
    return len(img)
