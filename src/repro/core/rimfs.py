"""Runtime In-Memory File System — flat, read-only, zero-copy weight store.

Image layout (all little-endian):

  [0:4]   magic  b"RIMF"
  [4:6]   version
  [6:8]   flags
  [8:12]  n_files
  [12:16] index_bytes
  [16:..] index: per file a json-encoded entry
          {name, offset, nbytes, dtype, shape, crc32}
  [..]    128-byte aligned data region (one aligned blob per file)
  [-4:]   CRC-32 of everything before it

``mount()`` wraps a bytes-like object and serves **zero-copy numpy views**
via ``np.frombuffer`` — no deserialization, no copies; exactly the paper's
"returns physical addresses directly to the DMA engine" property (the view's
buffer pointer IS what ``jax.device_put`` consumes). The image doubles as
the checkpoint format (checkpoint/ckpt.py) and the network provisioning
payload (serving/protocol.py).
"""
from __future__ import annotations

import io
import json
import pathlib
import struct
import weakref
import zlib
from typing import Mapping, Optional, Union

import numpy as np

MAGIC = b"RIMF"
ALIGN = 128          # GMIO-alignment analogue: TPU-friendly 128B lanes


class RIMFSError(ValueError):
    pass


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def pack(files: Mapping[str, np.ndarray], *, version: int = 1) -> bytes:
    """Flatten named arrays into one RIMFS image."""
    index = []
    blobs = []
    # header size depends on index size; compute index first with
    # placeholder offsets, then fix up (entries are fixed-length jsons once
    # offsets are known, so do two passes with stable formatting).
    metas = []
    for name, arr in files.items():
        arr = np.ascontiguousarray(arr)
        metas.append((name, arr))

    def build_index(data_start: int):
        out, off = [], data_start
        for name, arr in metas:
            off = _align(off)
            out.append({
                "name": name, "offset": off, "nbytes": int(arr.nbytes),
                "dtype": arr.dtype.str, "shape": list(arr.shape),
                "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
            })
            off += arr.nbytes
        return out, off

    # iterate to fixed point: index length changes offset digits rarely; two
    # passes suffice in practice, loop defensively.
    data_start = 16
    for _ in range(5):
        index, total = build_index(data_start)
        blob = json.dumps(index, separators=(",", ":")).encode()
        new_start = 16 + len(blob)
        if new_start == data_start:
            break
        data_start = new_start
    index, total = build_index(data_start)
    blob = json.dumps(index, separators=(",", ":")).encode()

    buf = bytearray(_align(total) + 4)
    struct.pack_into("<4sHHII", buf, 0, MAGIC, version, 0, len(metas),
                     len(blob))
    buf[16:16 + len(blob)] = blob
    for entry, (name, arr) in zip(index, metas):
        o = entry["offset"]
        buf[o:o + arr.nbytes] = arr.tobytes()
    crc = zlib.crc32(bytes(buf[:-4])) & 0xFFFFFFFF
    struct.pack_into("<I", buf, len(buf) - 4, crc)
    return bytes(buf)


class RIMFS:
    """A mounted image. All reads are zero-copy views into the backing
    buffer; ``verify()`` checks per-file CRCs without copying."""

    def __init__(self, data: Union[bytes, bytearray, memoryview, np.memmap]):
        self._data = data
        buf = memoryview(data) if not isinstance(data, np.memmap) else data
        magic, ver, _flags, n, ilen = struct.unpack_from("<4sHHII", buf, 0)
        if bytes(magic) != MAGIC:
            raise RIMFSError(f"bad RIMFS magic: {bytes(magic)!r}")
        self.version = ver
        index = json.loads(bytes(buf[16:16 + ilen]).decode())
        if len(index) != n:
            raise RIMFSError("index length mismatch")
        self._index = {e["name"]: e for e in index}
        # per-driver residency cache: id -> (weakref(driver), ResidentImage)
        self._resident: dict[int, tuple] = {}

    # ------------------------------------------------------------------ api
    def files(self) -> list:
        return list(self._index)

    def stat(self, name: str) -> dict:
        return dict(self._index[name])

    def read(self, name: str) -> np.ndarray:
        """Zero-copy ndarray view of one file."""
        e = self._index.get(name)
        if e is None:
            raise RIMFSError(f"no such file: {name!r}")
        return np.frombuffer(
            self._data, dtype=np.dtype(e["dtype"]),
            count=int(np.prod(e["shape"])) if e["shape"] else 1,
            offset=e["offset"]).reshape(e["shape"])

    def address_of(self, name: str) -> tuple:
        """(offset, nbytes) — the paper's 'physical address' for DMA."""
        e = self._index[name]
        return e["offset"], e["nbytes"]

    def verify(self, name: Optional[str] = None) -> bool:
        names = [name] if name else self.files()
        for n in names:
            e = self._index[n]
            view = self.read(n)
            if (zlib.crc32(view.tobytes()) & 0xFFFFFFFF) != e["crc32"]:
                raise RIMFSError(f"CRC mismatch in {n!r}")
        return True

    def verify_image(self) -> bool:
        raw = bytes(self._data) if not isinstance(self._data, (bytes,)) \
            else self._data
        (crc,) = struct.unpack_from("<I", raw, len(raw) - 4)
        if crc != (zlib.crc32(raw[:-4]) & 0xFFFFFFFF):
            raise RIMFSError("image CRC mismatch")
        return True

    def resident(self, driver, names: Optional[list] = None
                 ) -> "ResidentImage":
        """Device residency (zero re-upload): pin files into the driver's
        arena ONCE and serve the device buffers from then on.

        The upload consumes the same zero-copy host views ``read`` serves
        (``address_of`` gives each file's stable host "physical address"),
        so nothing is copied host-side; subsequent ``resident`` calls for
        the same driver — e.g. every RBL re-bind, every new
        ``ServingEngine`` over this image — return the cached
        ``ResidentImage`` and move zero bytes (asserted against the
        driver's DMA counters in tests/benchmarks). ``names`` restricts
        pinning to the files a program actually uses; later calls extend
        the pinned set incrementally (already-pinned files never move
        again). Cache entries for garbage-collected drivers are pruned —
        a dead driver's weight copy is not kept alive by this cache.
        """
        for key, (ref, _) in list(self._resident.items()):
            if ref() is None:                     # driver was collected
                del self._resident[key]
        entry = self._resident.get(id(driver))
        if entry is not None and entry[0]() is driver:
            ri = entry[1]
            ri.extend(names if names is not None else self.files())
            return ri
        ri = ResidentImage(self, driver, names)
        self._resident[id(driver)] = (weakref.ref(driver), ri)
        return ri

    def total_bytes(self) -> int:
        return len(self._data)

    def overhead_bytes(self) -> int:
        """Non-payload bytes (header + index + padding) — the 'runtime
        memory overhead' the paper compares against OS file systems."""
        payload = sum(e["nbytes"] for e in self._index.values())
        return self.total_bytes() - payload


class ResidentImage:
    """Weight files pinned device-side, offset-registered in the driver's
    arena. Built once per (image, driver) pair by ``RIMFS.resident`` and
    extended incrementally as later binds request more files.

    The upload is split-phase when the driver has async DMA slots: every
    file's transfer is ISSUED before any is WAITED on (one batched
    descriptor when the driver supports it), so uploads overlap each
    other instead of paying one host round-trip per file. The driver is
    held by weakref: the cache never outlives the backend it pinned into.
    """

    def __init__(self, fs: RIMFS, driver, names: Optional[list] = None):
        self.fs = fs
        self._driver_ref = weakref.ref(driver)
        self._host_views: dict[str, np.ndarray] = {}
        self._offsets: dict[str, int] = {}
        self._bufs: dict[str, object] = {}
        self.extend(names if names is not None else fs.files())

    @property
    def driver(self):
        return self._driver_ref()

    def extend(self, names) -> None:
        """Pin any not-yet-resident files (already-pinned ones never
        re-upload; the DMA counters do not move for them)."""
        order = [n for n in names if n not in self._bufs]
        if not order:
            return
        driver = self.driver
        if driver is None:
            raise RIMFSError("resident image's driver was collected")
        for name in order:
            view = self.fs.read(name)          # zero-copy view of the image
            self._host_views[name] = view
            if getattr(driver, "arena", None) is not None:
                self._offsets[name] = driver.arena.alloc(view.nbytes)
        if getattr(driver, "dma_async_batch", None) is not None:
            # the whole file set under one batched issue
            tickets = driver.dma_async_batch(
                [self._host_views[n] for n in order], "h2d")
            for name, t in zip(order, tickets):
                self._bufs[name] = driver.dma_wait(t)
        elif getattr(driver, "dma_async", None) is not None:
            tickets = {n: driver.dma_async(self._host_views[n], "h2d")
                       for n in order}
            for name, t in tickets.items():    # redeem after ALL issues
                self._bufs[name] = driver.dma_wait(t)
        else:
            for name in order:
                self._bufs[name] = driver.initiate_dma(
                    self._host_views[name], "h2d")

    # ---------------------------------------------------------------- api
    def files(self) -> list:
        return list(self._bufs)

    def buffer(self, name: str):
        """The pinned device buffer for one file."""
        return self._bufs[name]

    __getitem__ = buffer

    def __contains__(self, name: str) -> bool:
        return name in self._bufs

    def buffers(self) -> dict:
        return dict(self._bufs)

    def host_view(self, name: str) -> np.ndarray:
        """The zero-copy host view the upload consumed (aliases the
        mounted image — tested, not assumed)."""
        return self._host_views[name]

    def offset_of(self, name: str) -> Optional[int]:
        """Arena offset of the pinned range (None without an arena)."""
        return self._offsets.get(name)

    def pinned_ranges(self) -> list:
        """Sorted [(arena_offset, nbytes), ...] of every pinned file —
        the hot-swap machinery asserts a shadow image's ranges are
        disjoint from (and do not displace) the live image's."""
        return sorted((off, self._host_views[name].nbytes)
                      for name, off in self._offsets.items())

    def nbytes(self) -> int:
        return sum(v.nbytes for v in self._host_views.values())

    def unpin(self) -> None:
        """Release the arena ranges and drop the buffer table."""
        driver = self.driver
        arena = getattr(driver, "arena", None) if driver is not None \
            else None
        if arena is not None:
            for off in self._offsets.values():
                arena.free(off)
        self._offsets.clear()
        self._bufs.clear()
        if driver is not None:
            self.fs._resident.pop(id(driver), None)


def mount(data: Union[bytes, bytearray, memoryview]) -> RIMFS:
    return RIMFS(data)


def mount_file(path: Union[str, pathlib.Path]) -> RIMFS:
    """mmap-backed mount: zero-copy straight from the page cache."""
    mm = np.memmap(str(path), dtype=np.uint8, mode="r")
    return RIMFS(mm)


def save_file(path: Union[str, pathlib.Path],
              files: Mapping[str, np.ndarray]) -> int:
    img = pack(files)
    pathlib.Path(path).write_bytes(img)
    return len(img)
