"""Runtime In-Memory File System — flat, read-only, zero-copy weight store.

Image layout (all little-endian):

  [0:4]   magic  b"RIMF"
  [4:6]   version
  [6:8]   flags
  [8:12]  n_files
  [12:16] index_bytes
  [16:..] index: per file a json-encoded entry
          {name, offset, nbytes, dtype, shape, crc32}
  [..]    128-byte aligned data region (one aligned blob per file)
  [-4:]   CRC-32 of everything before it

``mount()`` wraps a bytes-like object and serves **zero-copy numpy views**
via ``np.frombuffer`` — no deserialization, no copies; exactly the paper's
"returns physical addresses directly to the DMA engine" property (the view's
buffer pointer IS what ``jax.device_put`` consumes). The image doubles as
the checkpoint format (checkpoint/ckpt.py) and the network provisioning
payload (serving/protocol.py).
"""
from __future__ import annotations

import io
import itertools
import json
import os
import pathlib
import struct
import weakref
import zlib
from typing import Mapping, Optional, Union

import numpy as np

from repro.core.integrity import IntegrityError, payload_crc

MAGIC = b"RIMF"
ALIGN = 128          # GMIO-alignment analogue: TPU-friendly 128B lanes


class RIMFSError(IntegrityError, ValueError):
    """RIMFS-level integrity/format fault. Subclasses ``IntegrityError``
    so the unified taxonomy (DESIGN.md §11) narrows to one recoverable
    class at the recovery layer, and ``ValueError`` for the seed-era
    callers that catch it as a format error."""

    def __init__(self, message: str, kind: str = "rimfs"):
        super().__init__(message, kind=kind)


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def _dtype_tag(dt: np.dtype) -> str:
    """Wire tag for one file's dtype. Numpy's ``.str`` collapses extension
    dtypes (ml_dtypes bfloat16 et al) to opaque void types (``|V2``), which
    cannot round-trip — tag those by NAME instead (LM weight images ship
    bfloat16)."""
    return dt.name if dt.kind == "V" else dt.str


def _dtype_of(tag: str) -> np.dtype:
    try:
        return np.dtype(tag)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, tag))


def pack(files: Mapping[str, np.ndarray], *, version: int = 1) -> bytes:
    """Flatten named arrays into one RIMFS image."""
    index = []
    blobs = []
    # header size depends on index size; compute index first with
    # placeholder offsets, then fix up (entries are fixed-length jsons once
    # offsets are known, so do two passes with stable formatting).
    metas = []
    for name, arr in files.items():
        arr = np.ascontiguousarray(arr)
        metas.append((name, arr))

    def build_index(data_start: int):
        out, off = [], data_start
        for name, arr in metas:
            off = _align(off)
            out.append({
                "name": name, "offset": off, "nbytes": int(arr.nbytes),
                "dtype": _dtype_tag(arr.dtype), "shape": list(arr.shape),
                "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
            })
            off += arr.nbytes
        return out, off

    # iterate to fixed point: index length changes offset digits rarely; two
    # passes suffice in practice, loop defensively.
    data_start = 16
    for _ in range(5):
        index, total = build_index(data_start)
        blob = json.dumps(index, separators=(",", ":")).encode()
        new_start = 16 + len(blob)
        if new_start == data_start:
            break
        data_start = new_start
    index, total = build_index(data_start)
    blob = json.dumps(index, separators=(",", ":")).encode()

    buf = bytearray(_align(total) + 4)
    struct.pack_into("<4sHHII", buf, 0, MAGIC, version, 0, len(metas),
                     len(blob))
    buf[16:16 + len(blob)] = blob
    for entry, (name, arr) in zip(index, metas):
        o = entry["offset"]
        buf[o:o + arr.nbytes] = arr.tobytes()
    crc = zlib.crc32(bytes(buf[:-4])) & 0xFFFFFFFF
    struct.pack_into("<I", buf, len(buf) - 4, crc)
    return bytes(buf)


class RIMFS:
    """A mounted image. All reads are zero-copy views into the backing
    buffer; ``verify()`` checks per-file CRCs without copying.

    Integrity plane (DESIGN.md §11): with ``verify_reads`` on (default)
    every file's CRC is checked the FIRST time it is opened — ``read``,
    ``resident`` pinning, bind-time weight resolution all flow through
    here — so a poisoned weight image is rejected before it ever binds,
    not only when a caller remembers to ``verify()``. The check is
    memoized per file; ``fsck()`` re-verifies everything and resets the
    memo (bring-up / post-fault re-validation)."""

    def __init__(self, data: Union[bytes, bytearray, memoryview, np.memmap],
                 verify_reads: bool = True):
        self._data = data
        buf = memoryview(data) if not isinstance(data, np.memmap) else data
        magic, ver, _flags, n, ilen = struct.unpack_from("<4sHHII", buf, 0)
        if bytes(magic) != MAGIC:
            raise RIMFSError(f"bad RIMFS magic: {bytes(magic)!r}")
        self.version = ver
        index = json.loads(bytes(buf[16:16 + ilen]).decode())
        if len(index) != n:
            raise RIMFSError("index length mismatch")
        self._index = {e["name"]: e for e in index}
        # per-driver residency cache: id -> (weakref(driver), ResidentImage)
        self._resident: dict[int, tuple] = {}
        self.verify_reads = verify_reads
        self._verified: set = set()        # files whose CRC already checked

    # ------------------------------------------------------------------ api
    def files(self) -> list:
        return list(self._index)

    def stat(self, name: str) -> dict:
        return dict(self._index[name])

    def read(self, name: str, verify: Optional[bool] = None) -> np.ndarray:
        """Zero-copy ndarray view of one file (CRC-checked on first
        open unless ``verify=False`` / ``verify_reads`` off)."""
        e = self._index.get(name)
        if e is None:
            raise RIMFSError(f"no such file: {name!r}")
        view = np.frombuffer(
            self._data, dtype=_dtype_of(e["dtype"]),
            count=int(np.prod(e["shape"])) if e["shape"] else 1,
            offset=e["offset"]).reshape(e["shape"])
        check = self.verify_reads if verify is None else verify
        if check and name not in self._verified:
            if (zlib.crc32(view.tobytes()) & 0xFFFFFFFF) != e["crc32"]:
                raise RIMFSError(f"CRC mismatch in {name!r} (read)",
                                 kind="file_crc")
            self._verified.add(name)
        return view

    def address_of(self, name: str) -> tuple:
        """(offset, nbytes) — the paper's 'physical address' for DMA."""
        e = self._index[name]
        return e["offset"], e["nbytes"]

    def verify(self, name: Optional[str] = None) -> bool:
        names = [name] if name else self.files()
        for n in names:
            e = self._index[n]
            view = self.read(n, verify=False)
            if (zlib.crc32(view.tobytes()) & 0xFFFFFFFF) != e["crc32"]:
                raise RIMFSError(f"CRC mismatch in {n!r}", kind="file_crc")
            self._verified.add(n)
        return True

    def verify_image(self) -> bool:
        raw = bytes(self._data) if not isinstance(self._data, (bytes,)) \
            else self._data
        (crc,) = struct.unpack_from("<I", raw, len(raw) - 4)
        if crc != (zlib.crc32(raw[:-4]) & 0xFFFFFFFF):
            raise RIMFSError("image CRC mismatch", kind="image_crc")
        return True

    def fsck(self, strict: bool = True) -> dict:
        """Full consistency check: image trailer CRC + every per-file
        CRC, re-verified from scratch (the read memo is reset first, so
        corruption that landed AFTER a file's first read is caught).
        Invoked on platform bring-up and after any tile-group death.
        Returns a report dict; with ``strict`` (default) corruption
        raises ``RIMFSError`` instead. An image mounted from an
        ``ImageStore`` replays/rolls back the store's journal through
        ``ImageStore.fsck`` first — this method checks the mounted
        bytes."""
        self._verified.clear()
        report: dict = {"files": len(self._index), "bad_files": [],
                        "image_crc_ok": True}
        try:
            self.verify_image()
        except RIMFSError:
            report["image_crc_ok"] = False
            if strict:
                raise
        for n, e in self._index.items():
            view = self.read(n, verify=False)
            if (zlib.crc32(view.tobytes()) & 0xFFFFFFFF) != e["crc32"]:
                report["bad_files"].append(n)
                if strict:
                    raise RIMFSError(f"fsck: CRC mismatch in {n!r}",
                                     kind="file_crc")
            else:
                self._verified.add(n)
        report["ok"] = report["image_crc_ok"] and not report["bad_files"]
        return report

    def resident(self, driver, names: Optional[list] = None
                 ) -> "ResidentImage":
        """Device residency (zero re-upload): pin files into the driver's
        arena ONCE and serve the device buffers from then on.

        The upload consumes the same zero-copy host views ``read`` serves
        (``address_of`` gives each file's stable host "physical address"),
        so nothing is copied host-side; subsequent ``resident`` calls for
        the same driver — e.g. every RBL re-bind, every new
        ``ServingEngine`` over this image — return the cached
        ``ResidentImage`` and move zero bytes (asserted against the
        driver's DMA counters in tests/benchmarks). ``names`` restricts
        pinning to the files a program actually uses; later calls extend
        the pinned set incrementally (already-pinned files never move
        again). Cache entries for garbage-collected drivers are pruned —
        a dead driver's weight copy is not kept alive by this cache.
        """
        for key, (ref, _) in list(self._resident.items()):
            if ref() is None:                     # driver was collected
                del self._resident[key]
        entry = self._resident.get(id(driver))
        if entry is not None and entry[0]() is driver:
            ri = entry[1]
            ri.extend(names if names is not None else self.files())
            return ri
        ri = ResidentImage(self, driver, names)
        self._resident[id(driver)] = (weakref.ref(driver), ri)
        return ri

    def total_bytes(self) -> int:
        return len(self._data)

    def overhead_bytes(self) -> int:
        """Non-payload bytes (header + index + padding) — the 'runtime
        memory overhead' the paper compares against OS file systems."""
        payload = sum(e["nbytes"] for e in self._index.values())
        return self.total_bytes() - payload


class ResidentImage:
    """Weight files pinned device-side, offset-registered in the driver's
    arena. Built once per (image, driver) pair by ``RIMFS.resident`` and
    extended incrementally as later binds request more files.

    The upload is split-phase when the driver has async DMA slots: every
    file's transfer is ISSUED before any is WAITED on (one batched
    descriptor when the driver supports it), so uploads overlap each
    other instead of paying one host round-trip per file. The driver is
    held by weakref: the cache never outlives the backend it pinned into.
    """

    def __init__(self, fs: RIMFS, driver, names: Optional[list] = None):
        self.fs = fs
        self._driver_ref = weakref.ref(driver)
        self._host_views: dict[str, np.ndarray] = {}
        self._offsets: dict[str, int] = {}
        self._bufs: dict[str, object] = {}
        self.extend(names if names is not None else fs.files())

    @property
    def driver(self):
        return self._driver_ref()

    def extend(self, names) -> None:
        """Pin any not-yet-resident files (already-pinned ones never
        re-upload; the DMA counters do not move for them)."""
        order = [n for n in names if n not in self._bufs]
        if not order:
            return
        driver = self.driver
        if driver is None:
            raise RIMFSError("resident image's driver was collected")
        for name in order:
            view = self.fs.read(name)          # zero-copy view of the image
            self._host_views[name] = view
            if getattr(driver, "arena", None) is not None:
                self._offsets[name] = driver.arena.alloc(view.nbytes)
        if getattr(driver, "dma_async_batch", None) is not None:
            # the whole file set under one batched issue
            tickets = driver.dma_async_batch(
                [self._host_views[n] for n in order], "h2d")
            for name, t in zip(order, tickets):
                self._bufs[name] = driver.dma_wait(t)
        elif getattr(driver, "dma_async", None) is not None:
            tickets = {n: driver.dma_async(self._host_views[n], "h2d")
                       for n in order}
            for name, t in tickets.items():    # redeem after ALL issues
                self._bufs[name] = driver.dma_wait(t)
        else:
            for name in order:
                self._bufs[name] = driver.initiate_dma(
                    self._host_views[name], "h2d")

    # ---------------------------------------------------------------- api
    def files(self) -> list:
        return list(self._bufs)

    def buffer(self, name: str):
        """The pinned device buffer for one file."""
        return self._bufs[name]

    __getitem__ = buffer

    def __contains__(self, name: str) -> bool:
        return name in self._bufs

    def buffers(self) -> dict:
        return dict(self._bufs)

    def host_view(self, name: str) -> np.ndarray:
        """The zero-copy host view the upload consumed (aliases the
        mounted image — tested, not assumed)."""
        return self._host_views[name]

    def offset_of(self, name: str) -> Optional[int]:
        """Arena offset of the pinned range (None without an arena)."""
        return self._offsets.get(name)

    def pinned_ranges(self) -> list:
        """Sorted [(arena_offset, nbytes), ...] of every pinned file —
        the hot-swap machinery asserts a shadow image's ranges are
        disjoint from (and do not displace) the live image's."""
        return sorted((off, self._host_views[name].nbytes)
                      for name, off in self._offsets.items())

    def revalidate(self) -> bool:
        """CRC-compare every pinned DEVICE buffer against its file's
        index CRC. This is the quarantine-lift check: after a watchdog
        kill the group's arena is poisoned until the weight copies it
        holds are proven bit-identical to the image
        (``TileMesh.revive``)."""
        for name, buf in self._bufs.items():
            if payload_crc(buf) != self.fs._index[name]["crc32"]:
                return False
        return True

    def nbytes(self) -> int:
        return sum(v.nbytes for v in self._host_views.values())

    def unpin(self) -> None:
        """Release the arena ranges and drop the buffer table."""
        driver = self.driver
        arena = getattr(driver, "arena", None) if driver is not None \
            else None
        if arena is not None:
            for off in self._offsets.values():
                arena.free(off)
        self._offsets.clear()
        self._bufs.clear()
        if driver is not None:
            self.fs._resident.pop(id(driver), None)


class Journal:
    """Write-ahead intent log for journaled image installs.

    Append-only records (dicts); when file-backed every append is
    flushed + fsynced BEFORE the caller proceeds — the write-ahead
    property an OS journal would provide. Record kinds:

      intent   {txid, crc, nbytes}  an install is about to stage
      commit   {txid}               staged payload is complete and valid
      applied  {txid}               the visible image was flipped
      rollback {txid}               fsck discarded the staging
    """

    def __init__(self, path: Optional[Union[str, pathlib.Path]] = None):
        self.path = pathlib.Path(path) if path is not None else None
        self._records: list = []
        if self.path is not None and self.path.exists():
            for line in self.path.read_text().splitlines():
                if line.strip():
                    self._records.append(json.loads(line))
        last = max((r["seq"] for r in self._records), default=0)
        self._seq = itertools.count(last + 1)

    def append(self, kind: str, txid: int, **meta) -> dict:
        rec = {"seq": next(self._seq), "kind": kind, "txid": txid, **meta}
        self._records.append(rec)
        if self.path is not None:
            with open(self.path, "a") as f:
                f.write(json.dumps(rec, separators=(",", ":")) + "\n")
                f.flush()
                os.fsync(f.fileno())
        return rec

    def records(self) -> list:
        return list(self._records)

    def pending(self) -> dict:
        """txid -> {"intent": rec, "committed": bool} for every intent
        without an applied/rollback resolution (the fsck worklist)."""
        state: dict = {}
        for r in self._records:
            if r["kind"] == "intent":
                state[r["txid"]] = {"intent": r, "committed": False}
            elif r["kind"] == "commit" and r["txid"] in state:
                state[r["txid"]]["committed"] = True
            elif r["kind"] in ("applied", "rollback"):
                state.pop(r["txid"], None)
        return state


class ImageStore:
    """Durable home of a serving image with journaled installs.

    Every install is write-ahead journaled: intent record -> stage the
    new bytes (side buffer; a ``.stage<txid>`` file when disk-backed)
    -> commit mark -> atomic flip (``os.replace``) -> applied mark. A
    fault at ANY point leaves the visible image either wholly old or
    wholly new, never a mixture; ``fsck()`` REPLAYS committed installs
    whose flip never landed (redo) and ROLLS BACK uncommitted staging
    (undo), then runs the mounted image's own per-file-CRC ``fsck``.

    ``fail_at`` on ``install`` is the chaos-injection hook: raise at a
    named step ("after_intent" / "after_stage" / "after_commit") to
    model a crash mid-write — the recovery path is then exercised by
    calling ``fsck()`` on the survivor.
    """

    def __init__(self, image: Optional[bytes] = None,
                 path: Optional[Union[str, pathlib.Path]] = None):
        self.path = pathlib.Path(path) if path is not None else None
        self.journal = Journal(
            f"{self.path}.journal" if self.path is not None else None)
        last_tx = max((r["txid"] for r in self.journal.records()),
                      default=0)
        self._txids = itertools.count(last_tx + 1)
        self._staging: dict[int, bytes] = {}
        self._image: Optional[bytes] = None
        if self.path is not None and self.path.exists():
            self._image = self.path.read_bytes()
        if image is not None:
            self.install(image)

    # ------------------------------------------------------------------ api
    def image(self) -> Optional[bytes]:
        """The committed (fully visible) image bytes."""
        return self._image

    def mount(self) -> RIMFS:
        if self._image is None:
            raise RIMFSError("image store is empty")
        return RIMFS(self._image)

    def _stage_path(self, txid: int) -> pathlib.Path:
        return pathlib.Path(f"{self.path}.stage{txid}")

    def install(self, image_bytes: bytes,
                fail_at: Optional[str] = None) -> int:
        """Journaled install; returns the transaction id."""
        txid = next(self._txids)
        self.journal.append("intent", txid,
                            crc=zlib.crc32(image_bytes) & 0xFFFFFFFF,
                            nbytes=len(image_bytes))
        if fail_at == "after_intent":
            raise IntegrityError(
                f"injected fault: crash after intent (tx {txid})",
                kind="journal_fault")
        self._staging[txid] = bytes(image_bytes)
        if self.path is not None:
            self._stage_path(txid).write_bytes(image_bytes)
        if fail_at == "after_stage":
            raise IntegrityError(
                f"injected fault: crash after stage (tx {txid})",
                kind="journal_fault")
        self.journal.append("commit", txid)
        if fail_at == "after_commit":
            raise IntegrityError(
                f"injected fault: crash after commit (tx {txid})",
                kind="journal_fault")
        self._apply(txid, image_bytes)
        return txid

    def _apply(self, txid: int, image_bytes: bytes) -> None:
        if self.path is not None:
            tmp = pathlib.Path(f"{self.path}.tmp")
            tmp.write_bytes(image_bytes)
            os.replace(tmp, self.path)           # the atomic flip
        self._image = bytes(image_bytes)
        self.journal.append("applied", txid)
        self._staging.pop(txid, None)
        if self.path is not None:
            sp = self._stage_path(txid)
            if sp.exists():
                sp.unlink()

    def fsck(self, strict: bool = True) -> dict:
        """Replay/roll back the journal, then fsck the mounted image.

        Committed transactions whose flip never became visible are
        re-applied from staging (CRC-checked against the intent record
        first); everything else pending is rolled back. The visible
        image is therefore always a fully-written, CRC-clean state."""
        report: dict = {"replayed": [], "rolled_back": [], "image": None}
        pend = self.journal.pending()
        for txid in sorted(pend):
            st = pend[txid]
            staged = self._staging.get(txid)
            if staged is None and self.path is not None:
                sp = self._stage_path(txid)
                if sp.exists():
                    staged = sp.read_bytes()
            intact = staged is not None and \
                (zlib.crc32(staged) & 0xFFFFFFFF) == st["intent"]["crc"]
            if st["committed"] and intact:
                self._apply(txid, staged)        # redo
                report["replayed"].append(txid)
            else:                                # undo
                self._staging.pop(txid, None)
                if self.path is not None:
                    sp = self._stage_path(txid)
                    if sp.exists():
                        sp.unlink()
                self.journal.append("rollback", txid)
                report["rolled_back"].append(txid)
        if self._image is not None:
            report["image"] = self.mount().fsck(strict=strict)
        return report


def mount(data: Union[bytes, bytearray, memoryview]) -> RIMFS:
    return RIMFS(data)


def mount_file(path: Union[str, pathlib.Path]) -> RIMFS:
    """mmap-backed mount: zero-copy straight from the page cache."""
    mm = np.memmap(str(path), dtype=np.uint8, mode="r")
    return RIMFS(mm)


def save_file(path: Union[str, pathlib.Path],
              files: Mapping[str, np.ndarray]) -> int:
    img = pack(files)
    pathlib.Path(path).write_bytes(img)
    return len(img)
