"""Runtime Platform Management — the system executive.

In the paper RTPM replaces the OS for three concerns: interconnect/cache
coherency, asynchronous event handling (a unified ISR dispatcher), and host
connectivity/telemetry over a CRC-32-framed lwIP stack. At pod scale the
same role is the *cluster control plane*; this module provides:

  * ``EventDispatcher`` — the unified ISR analogue: typed events
    (completion, error, heartbeat, preemption) fan out to registered
    handlers from a single queue.
  * ``Telemetry``       — per-step latency ring buffer; mean / percentile /
    CV (the paper's headline determinism metric).
  * ``HeartbeatMonitor``— worker liveness with an injectable clock; a
    deadline policy yields failure + straggler verdicts (the 1000-node
    fault-tolerance hook; tests drive it with a fake clock).
  * ``ServiceLoop``     — the single-owner dispatcher worker: N producer
    threads enqueue work into a bounded queue, ONE heartbeat-monitored
    thread drains it, so every piece of state the handler touches is
    owned by exactly one thread (the serving path's concurrency model).
  * ``Platform``        — glue: provisioning (mount RIMFS image + decode
    RCB program from bytes — the network payloads), time-to-service
    measurement, checkpoint/restart + elastic re-binding orchestration.

Thread-safety: the network server calls into RTPM from connection-handler
threads while the dispatcher runs, so ``EventDispatcher``, ``Telemetry``
and ``HeartbeatMonitor`` take internal locks (handlers run outside the
dispatcher lock so they may re-post without deadlocking).
"""
from __future__ import annotations

import collections
import dataclasses
import queue as queue_mod
import statistics
import threading
import time
from typing import Any, Callable, Optional

from repro.core import rbl as rbl_mod
from repro.core import rimfs as rimfs_mod
from repro.core.rcb import RCBProgram


# ---------------------------------------------------------------------------
# Events (unified ISR dispatcher)
# ---------------------------------------------------------------------------

class EventDispatcher:
    def __init__(self):
        self._handlers: dict[str, list[Callable]] = collections.defaultdict(list)
        self._queue: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self.dropped = 0

    def register(self, kind: str, handler: Callable[[dict], None]) -> None:
        with self._lock:
            self._handlers[kind].append(handler)

    def post(self, kind: str, payload: Optional[dict] = None) -> None:
        self._queue.append((kind, payload or {}))

    def process(self, max_events: Optional[int] = None) -> int:
        """Drain the queue; safe to call from several threads at once.
        Events pop under the lock but handlers run OUTSIDE it, so a
        handler may ``post`` (or even ``process``) without deadlocking."""
        n = 0
        while max_events is None or n < max_events:
            with self._lock:
                if not self._queue:
                    return n
                kind, payload = self._queue.popleft()
                handlers = list(self._handlers.get(kind) or ())
                if not handlers:
                    self.dropped += 1
            for h in handlers:
                h(payload)
            n += 1
        return n


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

class Telemetry:
    def __init__(self, capacity: int = 65536):
        self._lat: collections.deque = collections.deque(maxlen=capacity)
        self._metrics: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.bytes_moved = 0
        self.bytes_overlapped = 0
        self._counters: dict = collections.defaultdict(int)

    def incr(self, name: str, n: int = 1) -> None:
        """Monotonic fault/recovery counters (the integrity plane's
        telemetry surface: DESIGN.md §11 maps each fault class here)."""
        with self._lock:
            self._counters[name] += int(n)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    def record_latency(self, seconds: float) -> None:
        self._lat.append(seconds)

    def count(self) -> int:
        """Samples recorded so far (ring-capped). With ``summary(warmup=
        prev_count)`` this gives windowed stats over only the samples
        that landed since a controller's previous observation — the
        brown-out ladder's queue-wait p99 signal."""
        return len(self._lat)

    def record_dma(self, bytes_moved: int, bytes_overlapped: int = 0) -> None:
        """Data-movement accounting from the residency plan: total DMA
        payload vs the split-phase share that overlapped compute (the
        paper's 3-7x data-movement story, DESIGN.md §6)."""
        with self._lock:
            self.bytes_moved += int(bytes_moved)
            self.bytes_overlapped += int(bytes_overlapped)

    def dma_summary(self) -> dict:
        moved, over = self.bytes_moved, self.bytes_overlapped
        return {"bytes_moved": moved, "bytes_overlapped": over,
                "overlap_fraction": over / moved if moved else 0.0}

    def record(self, **metrics) -> None:
        self._metrics.append(dict(metrics, t=time.time()))

    def summary(self, warmup: int = 0) -> dict:
        xs = list(self._lat)[warmup:]
        if len(xs) < 2:
            return {"n": len(xs)}
        xs_sorted = sorted(xs)
        mu = statistics.fmean(xs)
        sd = statistics.stdev(xs)
        q = lambda p: xs_sorted[min(len(xs) - 1, int(p * len(xs)))]
        return {
            "n": len(xs), "mean": mu, "std": sd,
            "cv_percent": 100.0 * sd / mu if mu else float("inf"),
            "p50": q(0.50), "p95": q(0.95), "p99": q(0.99),
            "min": xs_sorted[0], "max": xs_sorted[-1],
        }


# ---------------------------------------------------------------------------
# Heartbeats / failure & straggler detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerState:
    last_beat: float
    step: int = 0
    alive: bool = True
    gap_ewma: Optional[float] = None   # EWMA of inter-beat gaps (seconds)


class HeartbeatMonitor:
    """Deadline-policy liveness. ``clock`` injectable for determinism.

    Two verdict tiers: a worker silent past ``deadline`` is **failed**
    (dead until it beats again); a live worker whose silence exceeds its
    own measured rhythm — EWMA of inter-beat gaps × ``straggler_factor``
    — is a **straggler**. The per-worker EWMA is what lets a fleet
    controller distinguish a slow-but-alive group from a dead one long
    before the wall-clock deadline expires: a worker that beat every
    50 ms and has been silent for half a second is in trouble *now*,
    not in ``deadline`` seconds. ``straggler_floor`` keeps sub-floor
    silences from flagging fast beaters between polls.
    """

    def __init__(self, deadline: float = 10.0, straggler_factor: float = 3.0,
                 clock: Callable[[], float] = time.monotonic,
                 gap_alpha: float = 0.3, straggler_floor: float = 0.05):
        self.deadline = deadline
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.gap_alpha = gap_alpha
        self.straggler_floor = straggler_floor
        self.workers: dict[str, WorkerState] = {}
        self._lock = threading.Lock()

    def beat(self, worker: str, step: int = 0) -> None:
        now = self.clock()
        with self._lock:
            w = self.workers.get(worker)
            if w is None:
                self.workers[worker] = WorkerState(now, step)
            else:
                if w.alive and w.last_beat > float("-inf"):
                    gap = max(0.0, now - w.last_beat)
                    w.gap_ewma = gap if w.gap_ewma is None else \
                        (1 - self.gap_alpha) * w.gap_ewma \
                        + self.gap_alpha * gap
                else:
                    w.gap_ewma = None      # revival: old rhythm is stale
                w.last_beat, w.step, w.alive = now, step, True

    def register_silent(self, worker: str, step: int = 0) -> None:
        """Register a worker that did NOT answer the registration poll:
        it fails the next deadline check instead of looking freshly
        alive (a beat would stamp 'now' and mask the silence)."""
        with self._lock:
            if worker not in self.workers:
                self.workers[worker] = WorkerState(float("-inf"), step)

    def check(self) -> dict:
        """Returns {"failed": [...], "stragglers": [...], "median_step": n,
        "verdicts": {worker: "failed"|"straggler"|"ok"}}.

        Straggler evidence, any of: silence past ``deadline/factor``
        (wall-clock policy), step count lagging the live median, or —
        the per-worker rhythm signal — silence past
        ``max(floor, gap_ewma * factor)`` for workers with a measured
        inter-beat EWMA."""
        now = self.clock()
        failed, stragglers = [], []
        verdicts: dict[str, str] = {}
        with self._lock:
            steps = [w.step for w in self.workers.values() if w.alive]
            median_step = sorted(steps)[len(steps) // 2] if steps else 0
            for name, w in self.workers.items():
                if not w.alive:
                    verdicts[name] = "failed"
                    continue
                age = now - w.last_beat
                rhythm_lag = w.gap_ewma is not None and \
                    age > max(self.straggler_floor,
                              w.gap_ewma * self.straggler_factor)
                if age > self.deadline:
                    w.alive = False
                    failed.append(name)
                    verdicts[name] = "failed"
                elif age > self.deadline / self.straggler_factor or \
                        w.step + 2 < median_step or rhythm_lag:
                    stragglers.append(name)
                    verdicts[name] = "straggler"
                else:
                    verdicts[name] = "ok"
        return {"failed": failed, "stragglers": stragglers,
                "median_step": median_step, "verdicts": verdicts}


# ---------------------------------------------------------------------------
# ServiceLoop — the single-owner dispatcher worker
# ---------------------------------------------------------------------------

_DRAIN = object()          # sentinel: drain what's queued, then exit


class Watchdog:
    """Per-dispatch deadline enforcement for the ServiceLoop.

    ``arm(item)`` before the handler runs, ``disarm()`` after; a monitor
    thread polls and, once the armed dispatch outlives its budget,
    fires ``on_hang(item)`` exactly ONCE for that dispatch (outside the
    lock, so the hook may kill tile groups and post events freely — the
    hung handler thread then unwedges through the normal ``TileFailure``
    path, because the guarded driver slots start raising).

    Budgets come from ``budget_fn(item)`` at arm time — the scheduler
    EWMA × slack policy lives in the caller's closure, not here. A
    ``None`` / non-finite budget leaves the dispatch unwatched (boot
    grace: no EWMA observation yet means no defensible deadline).
    """

    def __init__(self, budget_fn: Callable[[Any], Optional[float]],
                 on_hang: Callable[[Any], None], poll: float = 0.02):
        self.budget_fn = budget_fn
        self.on_hang = on_hang
        self.poll = poll
        self.stats = {"armed": 0, "preemptions": 0}
        self._lock = threading.Lock()
        self._gen = 0
        self._fired_gen = -1
        self._armed: Optional[tuple] = None     # (gen, item, deadline)
        self._closed = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="rtpm-watchdog")
        self._thread.start()

    def arm(self, item: Any) -> None:
        try:
            budget = self.budget_fn(item)
        except Exception:
            budget = None
        with self._lock:
            self._gen += 1
            if budget is None or not (0 <= budget < float("inf")):
                self._armed = None
                return
            self.stats["armed"] += 1
            self._armed = (self._gen, item, time.monotonic() + budget)

    def disarm(self) -> None:
        with self._lock:
            self._armed = None

    def _run(self) -> None:
        while not self._closed.wait(self.poll):
            fire = None
            with self._lock:
                if self._armed is not None:
                    gen, item, deadline = self._armed
                    if time.monotonic() >= deadline and \
                            gen != self._fired_gen:
                        self._fired_gen = gen   # once per dispatch
                        self.stats["preemptions"] += 1
                        fire = item
            if fire is not None:
                try:
                    self.on_hang(fire)
                except Exception:
                    pass                        # the hook must never kill us

    def close(self) -> None:
        self._closed.set()
        self._thread.join(timeout=2.0)


class ServiceLoop:
    """Bounded work queue drained by ONE heartbeat-monitored thread.

    The serving path's concurrency model in one object: any number of
    producer threads call ``submit`` (non-blocking — a full queue or a
    draining loop returns ``False``, the caller's backpressure signal),
    and a single worker thread owns everything the ``handler`` touches.
    No shared device state, no lock sprinkling — races are eliminated at
    the root by ownership.

    The worker registers with the platform's ``HeartbeatMonitor`` under
    ``name`` and beats every iteration (including idle polls), so a hung
    handler is caught by the same deadline policy that watches tile
    workers. ``on_idle`` (optional) runs whenever the queue is empty —
    and, when it reports progress by returning True, between queue pops —
    which is how the serving engine's continuous-batching decode steps
    interleave with request intake. Queue-wait and handler latency land
    in two ``Telemetry`` rings for the TELEMETRY wire message.
    """

    def __init__(self, platform: "Platform", handler: Callable[[Any], None],
                 name: str = "dispatcher", max_queue: int = 256,
                 poll: float = 0.02,
                 on_idle: Optional[Callable[[], bool]] = None,
                 on_drop: Optional[Callable[[Any], None]] = None,
                 watchdog_budget: Optional[Callable[[Any],
                                                    Optional[float]]] = None,
                 on_hang: Optional[Callable[[Any], None]] = None,
                 watchdog_poll: float = 0.02):
        self.platform = platform
        self.handler = handler
        self.name = name
        self.poll = poll
        self.on_idle = on_idle
        self.on_drop = on_drop
        self.queue_wait = Telemetry()
        self.dispatch_latency = Telemetry()
        self.stats = {"processed": 0, "rejected": 0, "errors": 0}
        self._stats_lock = threading.Lock()   # "rejected" is multi-producer
        self._submit_lock = threading.Lock()  # orders submits vs close()
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=max_queue)
        self._draining = threading.Event()
        self._drain_on_exit = True
        self._step = 0
        self._current: Any = None             # in-flight item (worker-owned)
        self.watchdog: Optional[Watchdog] = None
        if watchdog_budget is not None and on_hang is not None:
            self.watchdog = Watchdog(watchdog_budget, on_hang,
                                     poll=watchdog_poll)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"rtpm-{name}")
        platform.heartbeats.beat(name, 0)
        self._thread.start()

    # ------------------------------------------------------------- producers
    def submit(self, item: Any) -> bool:
        """Enqueue from any thread. False == rejected (backpressure).

        The drain-check + put happen under ``_submit_lock`` — ``close``
        sets the draining flag under the same lock, so an accepted item
        is ALWAYS ahead of the drain sentinel in the queue (a submit that
        returned True cannot be silently dropped by a racing shutdown)."""
        with self._submit_lock:
            if not self._draining.is_set():
                try:
                    self._q.put_nowait((time.monotonic(), item))
                    return True
                except queue_mod.Full:
                    pass
        with self._stats_lock:
            self.stats["rejected"] += 1
        return False

    def reject(self) -> None:
        """Count an item the caller refused BEFORE enqueue (e.g. an
        admission-cap refusal) so the rejected stat covers all paths."""
        with self._stats_lock:
            self.stats["rejected"] += 1

    def depth(self) -> int:
        return self._q.qsize()

    # --------------------------------------------------------------- worker
    def _idle(self) -> bool:
        """on_idle, guarded: an exception must degrade to 'no progress',
        never kill the dispatcher thread (the whole server would go dark
        while still accepting connections)."""
        if self.on_idle is None:
            return False
        try:
            return bool(self.on_idle())
        except Exception as e:
            self.stats["errors"] += 1
            self.platform.post("dispatch_error",
                               {"worker": self.name, "error": repr(e)})
            return False

    def _run(self) -> None:
        hb = self.platform.heartbeats
        while True:
            busy = self._idle()
            try:
                got = self._q.get_nowait() if busy \
                    else self._q.get(timeout=self.poll)
            except queue_mod.Empty:
                hb.beat(self.name, self._step)
                continue
            if got is _DRAIN:
                # graceful drain: finish whatever on_idle is still working
                # through (e.g. in-flight continuous-batching decodes).
                # A forced close (drain=False) skips this — the caller
                # refuses the leftovers explicitly instead.
                while self._drain_on_exit and self._idle():
                    hb.beat(self.name, self._step)
                hb.beat(self.name, self._step)
                return
            t_enq, item = got
            self._step += 1
            hb.beat(self.name, self._step)
            self.queue_wait.record_latency(time.monotonic() - t_enq)
            self._current = item
            if self.watchdog is not None:
                self.watchdog.arm(item)
            t0 = time.perf_counter()
            try:
                self.handler(item)
            except Exception as e:      # handler owns replies; never die
                self.stats["errors"] += 1
                self.platform.post("dispatch_error",
                                   {"worker": self.name, "error": repr(e)})
            finally:
                if self.watchdog is not None:
                    self.watchdog.disarm()
                self._current = None
            self.stats["processed"] += 1
            self.dispatch_latency.record_latency(time.perf_counter() - t0)

    # ------------------------------------------------------------ lifecycle
    def close(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the worker. ``drain=True`` processes everything already
        queued first (graceful SHUTDOWN); ``drain=False`` hands each
        dropped item to ``on_drop`` so its submitter can be refused
        explicitly rather than left waiting forever.

        ``timeout`` bounds the WHOLE call. If the worker is wedged inside
        a handler and the drain promise cannot be kept, every still-queued
        item is handed to ``on_drop`` on the way out (refused, not lost)
        and the sentinel is left queued so a worker that eventually
        unwedges still exits; the heartbeat monitor is what reports the
        wedged dispatcher dead."""
        deadline = time.monotonic() + timeout
        with self._submit_lock:     # no submit can land after the sentinel
            self._draining.set()
        self._drain_on_exit = drain
        if not drain:
            self._hand_back()
        try:
            self._q.put(_DRAIN, timeout=max(0.0, deadline - time.monotonic()))
        except queue_mod.Full:      # worker stuck with a full queue: the
            pass                    # heartbeat deadline is the real alarm
        self._thread.join(max(0.0, deadline - time.monotonic()))
        if self._thread.is_alive():
            # wedged: the drain promise is broken — refuse the leftovers
            # explicitly (including the in-flight dispatch, whose
            # submitter would otherwise wait forever; reply-once guards
            # downstream make a late handler completion harmless), then
            # re-arm the sentinel for a late unwedge. The watchdog stays
            # up: its preemption is what unwedges the worker.
            self._drain_on_exit = False
            self._hand_back()
            cur = self._current
            if cur is not None and self.on_drop is not None:
                try:
                    self.on_drop(cur)
                except Exception:
                    pass
            try:
                self._q.put_nowait(_DRAIN)
            except queue_mod.Full:
                pass
        elif self.watchdog is not None:
            self.watchdog.close()

    def _hand_back(self) -> None:
        """Drain queued (never-started) items to ``on_drop``."""
        try:
            while True:
                got = self._q.get_nowait()
                if got is not _DRAIN and self.on_drop is not None:
                    self.on_drop(got[1])
        except queue_mod.Empty:
            pass

    def alive(self) -> bool:
        return self._thread.is_alive()

    def summary(self) -> dict:
        out = {**self.stats, "depth": self.depth(),
               "queue_wait": self.queue_wait.summary(),
               "dispatch": self.dispatch_latency.summary()}
        if self.watchdog is not None:
            out["watchdog"] = dict(self.watchdog.stats)
        return out


# ---------------------------------------------------------------------------
# Platform
# ---------------------------------------------------------------------------

class Platform:
    """The executive: provisioning, service readiness, elasticity."""

    def __init__(self, deadline: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self._boot_t0 = time.perf_counter()
        self.events = EventDispatcher()
        self.telemetry = Telemetry()
        self.heartbeats = HeartbeatMonitor(deadline=deadline, clock=clock)
        self.rimfs: Optional[rimfs_mod.RIMFS] = None
        self.program: Optional[RCBProgram] = None
        self._ready_at: Optional[float] = None
        self.events.register("rcb_complete",
                             lambda p: self.telemetry.record(**p))
        self.events.register(
            "dma_complete",
            lambda p: self.telemetry.record_dma(
                p.get("bytes_moved", 0), p.get("bytes_overlapped", 0)))
        # fault-taxonomy counters (DESIGN.md §11): every integrity-plane
        # event increments a monotonic telemetry counter so recovery is
        # observable over the TELEMETRY wire message.
        for kind, counter in (("integrity_error", "integrity_errors"),
                              ("watchdog_preempt", "watchdog_preemptions"),
                              ("dma_retry", "dma_retries"),
                              ("rimfs_fsck", "rimfs_fscks"),
                              ("tile_failure", "tile_failures"),
                              # safe-rollout / overload control plane
                              # (DESIGN.md §14)
                              ("canary_sample", "canary_samples"),
                              ("canary_promoted", "canary_promotions"),
                              ("canary_aborted", "canary_aborts"),
                              ("reshape_complete", "partial_reshapes"),
                              ("brownout_rung", "brownout_transitions"),
                              ("brownout_shed", "brownout_sheds"),
                              ("circuit_open", "circuit_opens"),
                              ("circuit_closed", "circuit_closes")):
            self.events.register(
                kind, lambda p, c=counter: self.telemetry.incr(
                    c, p.get("n", 1)))

    # ------------------------------------------------------------ provision
    def provision(self, image: Optional[bytes] = None,
                  program_bytes: Optional[bytes] = None,
                  program: Optional[RCBProgram] = None,
                  verify: bool = True) -> None:
        """Paper phase 1: load model binary (RCBs + weights) into RIMFS."""
        if image is not None:
            self.rimfs = rimfs_mod.mount(image)
            if verify:
                # bring-up fsck: image trailer + per-file CRCs (strict —
                # a poisoned weight image must never bind)
                self.rimfs.fsck(strict=True)
                self.events.post("rimfs_fsck", {"phase": "provision"})
            # autotune-cache reload (DESIGN.md §13): an image carrying the
            # kernel registry's winner table installs it now, so kernel
            # handlers linked against this provision hit tuned block sizes
            # with zero sweep trials.
            from repro.kernels import registry as kreg
            if kreg.AUTOTUNE_FILE in self.rimfs.files():
                n = kreg.load_image(self.rimfs)
                self.events.post("autotune_loaded", {"entries": n})
        if program_bytes is not None:
            program = RCBProgram.decode(program_bytes)
        if program is not None:
            self.program = program
        self._ready_at = time.perf_counter()
        self.events.post("provisioned",
                         {"files": self.rimfs.files() if self.rimfs else []})

    def bind(self, inputs: Optional[dict] = None, driver=None,
             artifacts: Optional[dict] = None) -> rbl_mod.BoundProgram:
        """Paper phase 2: symbolic -> physical resolution."""
        assert self.program is not None, "provision() first"
        if artifacts:
            self.program.artifacts.update(artifacts)
        return rbl_mod.bind(self.program, rimfs=self.rimfs, inputs=inputs,
                            driver=driver)

    # ------------------------------------------------------------ readiness
    def time_to_service(self) -> float:
        """Boot -> network-ready (paper Table 2's 350-745x metric)."""
        assert self._ready_at is not None
        return self._ready_at - self._boot_t0

    def post(self, kind: str, payload: Optional[dict] = None) -> None:
        self.events.post(kind, payload)
        self.events.process()

    # ---------------------------------------------------------- tile groups
    def run_partitioned(self, bound: rbl_mod.BoundProgram,
                        inputs: Optional[dict] = None, mesh=None,
                        n_groups: int = 2, rimfs=None) -> dict:
        """Orchestrate partitioned multi-tile execution (paper's RTPM role
        over the tile array): every tile group is registered as a
        heartbeat-monitored worker ("tile<g>"), stages pipeline over the
        mesh with split-phase cut-edge streams, and a failed stage
        re-queues on a surviving group after the liveness sweep — the
        "worker_failed" / "stage_requeued" / "stage_complete" events fan
        out through the unified dispatcher.
        """
        from repro.core import partition as partition_mod
        from repro.core.executor import Executor
        from repro.core.rhal import TileMesh
        if mesh is None:
            mesh = TileMesh(n_groups)
        rimfs = rimfs if rimfs is not None else self.rimfs
        if isinstance(bound, partition_mod.PartitionedProgram):
            return partition_mod.execute(bound, mesh, inputs=inputs,
                                         rimfs=rimfs, platform=self)
        # delegate to the executor's cached path: repeated orchestration
        # of the same BoundProgram re-cuts and re-links nothing (the
        # executor's own driver is unused — per-group drivers dispatch)
        return Executor().run_partitioned(
            bound, inputs=inputs, rimfs=rimfs, mesh=mesh, platform=self)

    # ------------------------------------------------------------ elasticity
    def handle_failures(self, bound: rbl_mod.BoundProgram,
                        on_shrink: Optional[Callable] = None) -> dict:
        """Failure/straggler sweep; re-binds the program when workers die.

        Control-as-data makes elasticity a pure re-binding: the RCB stream is
        untouched, only physical resources change (paper §5.2 implication).
        """
        verdict = self.heartbeats.check()
        if verdict["failed"]:
            self.events.post("worker_failed", {"workers": verdict["failed"]})
            self.events.process()
            if on_shrink is not None:
                on_shrink(verdict["failed"])
            rbl_mod.rebind(bound)
        return verdict
