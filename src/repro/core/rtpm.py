"""Runtime Platform Management — the system executive.

In the paper RTPM replaces the OS for three concerns: interconnect/cache
coherency, asynchronous event handling (a unified ISR dispatcher), and host
connectivity/telemetry over a CRC-32-framed lwIP stack. At pod scale the
same role is the *cluster control plane*; this module provides:

  * ``EventDispatcher`` — the unified ISR analogue: typed events
    (completion, error, heartbeat, preemption) fan out to registered
    handlers from a single queue.
  * ``Telemetry``       — per-step latency ring buffer; mean / percentile /
    CV (the paper's headline determinism metric).
  * ``HeartbeatMonitor``— worker liveness with an injectable clock; a
    deadline policy yields failure + straggler verdicts (the 1000-node
    fault-tolerance hook; tests drive it with a fake clock).
  * ``Platform``        — glue: provisioning (mount RIMFS image + decode
    RCB program from bytes — the network payloads), time-to-service
    measurement, checkpoint/restart + elastic re-binding orchestration.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

from repro.core import rbl as rbl_mod
from repro.core import rimfs as rimfs_mod
from repro.core.rcb import RCBProgram


# ---------------------------------------------------------------------------
# Events (unified ISR dispatcher)
# ---------------------------------------------------------------------------

class EventDispatcher:
    def __init__(self):
        self._handlers: dict[str, list[Callable]] = collections.defaultdict(list)
        self._queue: collections.deque = collections.deque()
        self.dropped = 0

    def register(self, kind: str, handler: Callable[[dict], None]) -> None:
        self._handlers[kind].append(handler)

    def post(self, kind: str, payload: Optional[dict] = None) -> None:
        self._queue.append((kind, payload or {}))

    def process(self, max_events: Optional[int] = None) -> int:
        n = 0
        while self._queue and (max_events is None or n < max_events):
            kind, payload = self._queue.popleft()
            handlers = self._handlers.get(kind)
            if not handlers:
                self.dropped += 1
            else:
                for h in handlers:
                    h(payload)
            n += 1
        return n


# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------

class Telemetry:
    def __init__(self, capacity: int = 65536):
        self._lat: collections.deque = collections.deque(maxlen=capacity)
        self._metrics: collections.deque = collections.deque(maxlen=capacity)
        self.bytes_moved = 0
        self.bytes_overlapped = 0

    def record_latency(self, seconds: float) -> None:
        self._lat.append(seconds)

    def record_dma(self, bytes_moved: int, bytes_overlapped: int = 0) -> None:
        """Data-movement accounting from the residency plan: total DMA
        payload vs the split-phase share that overlapped compute (the
        paper's 3-7x data-movement story, DESIGN.md §6)."""
        self.bytes_moved += int(bytes_moved)
        self.bytes_overlapped += int(bytes_overlapped)

    def dma_summary(self) -> dict:
        moved, over = self.bytes_moved, self.bytes_overlapped
        return {"bytes_moved": moved, "bytes_overlapped": over,
                "overlap_fraction": over / moved if moved else 0.0}

    def record(self, **metrics) -> None:
        self._metrics.append(dict(metrics, t=time.time()))

    def summary(self, warmup: int = 0) -> dict:
        xs = list(self._lat)[warmup:]
        if len(xs) < 2:
            return {"n": len(xs)}
        xs_sorted = sorted(xs)
        mu = statistics.fmean(xs)
        sd = statistics.stdev(xs)
        q = lambda p: xs_sorted[min(len(xs) - 1, int(p * len(xs)))]
        return {
            "n": len(xs), "mean": mu, "std": sd,
            "cv_percent": 100.0 * sd / mu if mu else float("inf"),
            "p50": q(0.50), "p95": q(0.95), "p99": q(0.99),
            "min": xs_sorted[0], "max": xs_sorted[-1],
        }


# ---------------------------------------------------------------------------
# Heartbeats / failure & straggler detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerState:
    last_beat: float
    step: int = 0
    alive: bool = True


class HeartbeatMonitor:
    """Deadline-policy liveness. ``clock`` injectable for determinism."""

    def __init__(self, deadline: float = 10.0, straggler_factor: float = 3.0,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline = deadline
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.workers: dict[str, WorkerState] = {}

    def beat(self, worker: str, step: int = 0) -> None:
        now = self.clock()
        w = self.workers.get(worker)
        if w is None:
            self.workers[worker] = WorkerState(now, step)
        else:
            w.last_beat, w.step, w.alive = now, step, True

    def register_silent(self, worker: str, step: int = 0) -> None:
        """Register a worker that did NOT answer the registration poll:
        it fails the next deadline check instead of looking freshly
        alive (a beat would stamp 'now' and mask the silence)."""
        if worker not in self.workers:
            self.workers[worker] = WorkerState(float("-inf"), step)

    def check(self) -> dict:
        """Returns {"failed": [...], "stragglers": [...]}."""
        now = self.clock()
        failed, stragglers = [], []
        steps = [w.step for w in self.workers.values() if w.alive]
        median_step = sorted(steps)[len(steps) // 2] if steps else 0
        for name, w in self.workers.items():
            if not w.alive:
                continue
            age = now - w.last_beat
            if age > self.deadline:
                w.alive = False
                failed.append(name)
            elif age > self.deadline / self.straggler_factor or \
                    w.step + 2 < median_step:
                stragglers.append(name)
        return {"failed": failed, "stragglers": stragglers,
                "median_step": median_step}


# ---------------------------------------------------------------------------
# Platform
# ---------------------------------------------------------------------------

class Platform:
    """The executive: provisioning, service readiness, elasticity."""

    def __init__(self, deadline: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self._boot_t0 = time.perf_counter()
        self.events = EventDispatcher()
        self.telemetry = Telemetry()
        self.heartbeats = HeartbeatMonitor(deadline=deadline, clock=clock)
        self.rimfs: Optional[rimfs_mod.RIMFS] = None
        self.program: Optional[RCBProgram] = None
        self._ready_at: Optional[float] = None
        self.events.register("rcb_complete",
                             lambda p: self.telemetry.record(**p))
        self.events.register(
            "dma_complete",
            lambda p: self.telemetry.record_dma(
                p.get("bytes_moved", 0), p.get("bytes_overlapped", 0)))

    # ------------------------------------------------------------ provision
    def provision(self, image: Optional[bytes] = None,
                  program_bytes: Optional[bytes] = None,
                  program: Optional[RCBProgram] = None,
                  verify: bool = True) -> None:
        """Paper phase 1: load model binary (RCBs + weights) into RIMFS."""
        if image is not None:
            self.rimfs = rimfs_mod.mount(image)
            if verify:
                self.rimfs.verify_image()
        if program_bytes is not None:
            program = RCBProgram.decode(program_bytes)
        if program is not None:
            self.program = program
        self._ready_at = time.perf_counter()
        self.events.post("provisioned",
                         {"files": self.rimfs.files() if self.rimfs else []})

    def bind(self, inputs: Optional[dict] = None, driver=None,
             artifacts: Optional[dict] = None) -> rbl_mod.BoundProgram:
        """Paper phase 2: symbolic -> physical resolution."""
        assert self.program is not None, "provision() first"
        if artifacts:
            self.program.artifacts.update(artifacts)
        return rbl_mod.bind(self.program, rimfs=self.rimfs, inputs=inputs,
                            driver=driver)

    # ------------------------------------------------------------ readiness
    def time_to_service(self) -> float:
        """Boot -> network-ready (paper Table 2's 350-745x metric)."""
        assert self._ready_at is not None
        return self._ready_at - self._boot_t0

    def post(self, kind: str, payload: Optional[dict] = None) -> None:
        self.events.post(kind, payload)
        self.events.process()

    # ---------------------------------------------------------- tile groups
    def run_partitioned(self, bound: rbl_mod.BoundProgram,
                        inputs: Optional[dict] = None, mesh=None,
                        n_groups: int = 2, rimfs=None) -> dict:
        """Orchestrate partitioned multi-tile execution (paper's RTPM role
        over the tile array): every tile group is registered as a
        heartbeat-monitored worker ("tile<g>"), stages pipeline over the
        mesh with split-phase cut-edge streams, and a failed stage
        re-queues on a surviving group after the liveness sweep — the
        "worker_failed" / "stage_requeued" / "stage_complete" events fan
        out through the unified dispatcher.
        """
        from repro.core import partition as partition_mod
        from repro.core.executor import Executor
        from repro.core.rhal import TileMesh
        if mesh is None:
            mesh = TileMesh(n_groups)
        rimfs = rimfs if rimfs is not None else self.rimfs
        if isinstance(bound, partition_mod.PartitionedProgram):
            return partition_mod.execute(bound, mesh, inputs=inputs,
                                         rimfs=rimfs, platform=self)
        # delegate to the executor's cached path: repeated orchestration
        # of the same BoundProgram re-cuts and re-links nothing (the
        # executor's own driver is unused — per-group drivers dispatch)
        return Executor().run_partitioned(
            bound, inputs=inputs, rimfs=rimfs, mesh=mesh, platform=self)

    # ------------------------------------------------------------ elasticity
    def handle_failures(self, bound: rbl_mod.BoundProgram,
                        on_shrink: Optional[Callable] = None) -> dict:
        """Failure/straggler sweep; re-binds the program when workers die.

        Control-as-data makes elasticity a pure re-binding: the RCB stream is
        untouched, only physical resources change (paper §5.2 implication).
        """
        verdict = self.heartbeats.check()
        if verdict["failed"]:
            self.events.post("worker_failed", {"workers": verdict["failed"]})
            self.events.process()
            if on_shrink is not None:
                on_shrink(verdict["failed"])
            rbl_mod.rebind(bound)
        return verdict
