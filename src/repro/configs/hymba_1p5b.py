"""Hymba-1.5B — hybrid: parallel attention + Mamba heads per layer.

[arXiv:2411.13676] 32L d_model=1600 25H (GQA kv=5, head_dim=64)
d_ff=5504 vocab=32001 ssm_state=16. Attention is sliding-window (Hymba uses
SWA in all but 3 layers); the Mamba branch gives O(1) decode state, so the
arch is sub-quadratic and runs long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention="sliding",
    sliding_window=1024,
    ssm_state=16,
    subquadratic=True,
))
