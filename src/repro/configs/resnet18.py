"""ResNet-18 — the paper's case study (ImageNet-1k classification, INT8).

Standard He et al. (2016) ResNet-18: conv7x7/64 -> 4 stages of 2 basic
blocks (64/128/256/512) -> GAP -> fc(1000). The paper deploys this through
the RCB path with 12.63 MB of (quantized) parameters on a 4x7 AIE grid; here
it is the reference workload for the RCTC -> RCB -> executor pipeline and
the INT8 quantization flow.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet18"
    stage_sizes: tuple = (2, 2, 2, 2)
    stage_widths: tuple = (64, 128, 256, 512)
    num_classes: int = 1000
    image_size: int = 224
    stem_width: int = 64

    def smoke(self) -> "ResNetConfig":
        return dataclasses.replace(
            self, name="resnet18-smoke",
            stage_sizes=(1, 1), stage_widths=(8, 16),
            num_classes=10, image_size=32, stem_width=8)


CONFIG = ResNetConfig()
