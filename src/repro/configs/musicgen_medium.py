"""MusicGen-medium — decoder-only LM over EnCodec audio tokens.

[arXiv:2306.05284] 48L d_model=1536 24H (kv=24, MHA, head_dim=64)
d_ff=6144 vocab=2048. Backbone only; the EnCodec frontend is a stub —
``input_specs()`` provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    input_kind="embeddings",     # EnCodec frame-embedding stub
))
