"""Snowflake Arctic 480B — 128-expert top-2 MoE with dense residual MLP.

[hf:Snowflake/snowflake-arctic-base] 35L d_model=7168 56H (GQA kv=8,
head_dim=128) per-expert d_ff=4864 vocab=32000; MoE 128e top-2 in parallel
with a dense residual MLP (Arctic's dense+MoE hybrid).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,                   # per-expert
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    d_ff_dense=7168,             # dense residual branch width
))
