"""Architecture configs (one module per assigned arch + paper case study)."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
    get_config,
    list_configs,
    register,
)

_ARCH_MODULES = [
    "rwkv6_1p6b",
    "pixtral_12b",
    "moonshot_v1_16b_a3b",
    "arctic_480b",
    "qwen3_14b",
    "qwen2_1p5b",
    "mistral_nemo_12b",
    "phi3_medium_14b",
    "hymba_1p5b",
    "musicgen_medium",
    "resnet18",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


ARCHES = [m.replace("_", "-").replace("-1p6b", "-1.6b").replace("-1p5b", "-1.5b")
          for m in _ARCH_MODULES if m != "resnet18"]
