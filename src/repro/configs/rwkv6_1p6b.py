"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.

[arXiv:2404.05892] 24L d_model=2048 d_ff=7168 vocab=65536; head size 64.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=7168,
    vocab_size=65536,
    attention="none",
    use_rope=False,
    rwkv_head_dim=64,            # 32 heads of size 64
    subquadratic=True,           # O(1) decode state -> long_500k runs
))
