"""Config system for AEG-JAX.

Every assigned architecture is expressed as a frozen ``ModelConfig``; input
shapes are ``ShapeConfig``s. A (ModelConfig x ShapeConfig) pair defines one
dry-run / roofline cell. Reduced ("smoke") variants are derived mechanically
so the smoke tests always exercise the same code path as the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (family-polymorphic superset)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int                        # dense FFN (or per-expert FFN for MoE)
    vocab_size: int

    # --- attention flavour -------------------------------------------------
    attention: str = "full"          # full | sliding | none
    sliding_window: int = 0          # used when attention == "sliding"
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2
    rope_theta: float = 10_000.0
    use_rope: bool = True

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: dense MLP in parallel w/ MoE
    d_ff_dense: int = 0               # width of the arctic dense residual MLP

    # --- SSM / recurrent ---------------------------------------------------
    ssm_state: int = 0               # mamba state size (hymba)
    rwkv_head_dim: int = 64          # rwkv6 head size

    # --- modality ----------------------------------------------------------
    input_kind: str = "tokens"       # tokens | embeddings (vlm/audio stubs)

    # --- misc --------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    subquadratic: bool = False       # may run long_500k

    # ------------------------------------------------------------------ api
    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab_size
        n = v * d if self.input_kind == "tokens" else 0   # token embedding
        if not self.tie_embeddings:
            n += v * d                                    # lm head
        n += d                                        # final norm
        per_layer = 2 * d                             # two RMSNorm scales
        if self.family == "ssm":                      # rwkv6 time-mix + channel-mix
            heads = d // self.rwkv_head_dim
            per_layer += 4 * d * d                    # r,k,v,g projections
            per_layer += d * d                        # output proj
            per_layer += 2 * d * 32 + 6 * d * 32      # lora decks (w / mix)
            per_layer += 2 * d + heads * self.rwkv_head_dim  # w0, u, ln params
            per_layer += d * self.d_ff + self.d_ff * d + d * d  # channel mix
        else:
            ad, kd = self.attn_dim, self.kv_dim
            per_layer += d * ad + 2 * d * kd + ad * d  # q,k,v,o
            if self.qkv_bias:
                per_layer += ad + 2 * kd
            if self.qk_norm:
                per_layer += 2 * self.head_dim
            if self.family == "hybrid":
                di, s = self.d_model, self.ssm_state
                per_layer += d * 2 * di + di * d       # in/out proj
                per_layer += di * (2 * s + 1) + di * s + di  # B,C,dt proj; A; D
            if self.num_experts > 0:
                per_layer += d * self.num_experts      # router
                per_layer += self.num_experts * 3 * d * self.d_ff
                if self.moe_dense_residual:
                    per_layer += 3 * d * self.d_ff_dense
            else:
                per_layer += 3 * d * self.d_ff         # SwiGLU
        return n + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        inactive = (self.num_experts - self.experts_per_token) * \
            3 * self.d_model * self.d_ff * self.num_layers
        return full - inactive

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=64,
            num_heads=max(1, min(4, self.num_heads)) if self.num_heads else 0,
            num_kv_heads=_smoke_kv(self),
            head_dim=16 if self.num_heads else self.head_dim,
            d_ff=128,
            d_ff_dense=64 if self.moe_dense_residual else 0,
            vocab_size=256,
            num_experts=min(4, self.num_experts),
            experts_per_token=min(2, self.experts_per_token),
            # dropless in smoke tests: capacity covers worst-case routing so
            # decode is exactly consistent with full forward (capacity
            # dropping is seq-length-dependent by construction)
            moe_capacity_factor=float(min(4, self.num_experts) or 1),
            sliding_window=min(16, self.sliding_window) if self.sliding_window else 0,
            ssm_state=min(4, self.ssm_state) if self.ssm_state else 0,
            rwkv_head_dim=16,
            dtype="float32",
        )


def _smoke_kv(cfg: ModelConfig) -> int:
    if cfg.num_heads == 0:
        return 0
    q = max(1, min(4, cfg.num_heads))
    if cfg.num_kv_heads == cfg.num_heads:       # MHA stays MHA
        return q
    return max(1, min(2, cfg.num_kv_heads))     # GQA stays grouped


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    def smoke(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, name=self.name + "-smoke",
            seq_len=min(32, self.seq_len), global_batch=min(4, self.global_batch))


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells for an architecture (long_500k only for sub-quadratic)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate config {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs as _pkg  # ensure arch modules imported
    _pkg.load_all()
    if name.endswith("-smoke"):
        return _REGISTRY[name[: -len("-smoke")]].smoke()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _pkg
    _pkg.load_all()
    return sorted(_REGISTRY)
