"""Pixtral-12B — pixtral ViT frontend (stubbed) + Mistral-Nemo decoder.

[hf:mistralai/Pixtral-12B-2409] 40L d_model=5120 32H (GQA kv=8, head_dim=128)
d_ff=14336 vocab=131072. Backbone only; ``input_specs()`` provides precomputed
patch embeddings (frontend stub per assignment).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000_000.0,
    input_kind="embeddings",     # patch-embedding stub
))
