"""Jitted wrapper for the INT8 GEMM kernel.

The shape/dtype contract is enforced eagerly; ``interpret`` is resolved
outside the jitted body (kernels/common.resolve_interpret).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import check_rank, resolve_interpret
from repro.kernels.int8_matmul.kernel import int8_matmul_mkn


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def _int8_matmul_jit(x, w, scale, *, block_m: int, block_n: int,
                     block_k: int, out_dtype, interpret: bool) -> jax.Array:
    return int8_matmul_mkn(x, w, scale, block_m=block_m, block_n=block_n,
                           block_k=block_k, out_dtype=out_dtype,
                           interpret=interpret)


def check_contract(x, w, scale, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128) -> None:
    """Shape/dtype contract shared with the kernel registry."""
    check_rank("int8_matmul", "x", x, 2)
    check_rank("int8_matmul", "w", w, 2)
    check_rank("int8_matmul", "scale", scale, 1)
    for name, a in (("x", x), ("w", w)):
        if jnp.dtype(a.dtype) != jnp.int8:
            raise ValueError(
                f"int8_matmul: operand {name!r} must be int8, got {a.dtype}")
    if not jnp.issubdtype(scale.dtype, jnp.floating):
        raise ValueError(
            f"int8_matmul: scale must be floating, got {scale.dtype}")
    m, k = x.shape
    kw, n = w.shape
    if m == 0 or k == 0 or n == 0:
        raise ValueError(
            f"int8_matmul: zero-size operand (m={m}, k={k}, n={n})")
    if kw != k:
        raise ValueError(
            f"int8_matmul: contraction mismatch x {tuple(x.shape)} vs "
            f"w {tuple(w.shape)}")
    if scale.shape[0] != n:
        raise ValueError(
            f"int8_matmul: scale must be per-out-channel (n={n},), got "
            f"{tuple(scale.shape)}")
    for dim, blk, name in ((m, block_m, "block_m"), (n, block_n, "block_n"),
                           (k, block_k, "block_k")):
        if dim % min(int(blk), dim) != 0:
            raise ValueError(
                f"int8_matmul: {name}={blk} does not tile dim {dim} "
                f"(dims must be multiples of the clamped block size)")


def int8_matmul(x, w, scale, *, block_m: int = 128, block_n: int = 128,
                block_k: int = 128, out_dtype=jnp.float32,
                interpret: bool | None = None) -> jax.Array:
    """x: (M,K) int8; w: (K,N) int8; scale: (N,) f32. Returns (M,N)."""
    check_contract(x, w, scale, block_m=block_m, block_n=block_n,
                   block_k=block_k)
    return _int8_matmul_jit(x, w, scale, block_m=int(block_m),
                            block_n=int(block_n), block_k=int(block_k),
                            out_dtype=jnp.dtype(out_dtype),
                            interpret=resolve_interpret(interpret))
