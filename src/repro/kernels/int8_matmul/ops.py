"""Jitted wrapper for the INT8 GEMM kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.kernel import int8_matmul_mkn


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def int8_matmul(x, w, scale, *, block_m: int = 128, block_n: int = 128,
                block_k: int = 128, out_dtype=jnp.float32,
                interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return int8_matmul_mkn(x, w, scale, block_m=block_m, block_n=block_n,
                           block_k=block_k, out_dtype=out_dtype,
                           interpret=interpret)
