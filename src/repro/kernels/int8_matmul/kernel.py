"""INT8 x INT8 -> INT32 blocked GEMM with fused per-channel dequant.

The paper's deployment quantizes to INT8 (§3.4); on TPU the MXU executes
int8 pairs at 2x bf16 throughput, so the quantized RCB path maps to this
kernel. (bm x bk)/(bk x bn) tiles stage through VMEM, the int32 accumulator
persists in scratch across the sequential k dimension, and the requant
scale (x_scale * w_scale[channel]) fuses into the epilogue — one HBM write
of the final tile, no int32 round-trip.

Grid: (n_m, n_n, n_k)   [k dim sequential]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_scr, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32)

    @pl.when(ki == n_k - 1)
    def _emit():
        scale = s_ref[...].astype(jnp.float32)          # (1, bn)
        o_ref[...] = (acc_scr[...].astype(jnp.float32) *
                      scale).astype(o_ref.dtype)


def int8_matmul_mkn(x, w, scale, *, block_m: int = 128, block_n: int = 128,
                    block_k: int = 128, out_dtype=jnp.float32,
                    interpret: bool = False) -> jax.Array:
    """x: (M,K) int8; w: (K,N) int8; scale: (N,) f32 (per-out-channel,
    already multiplied by the activation scale). Returns (M,N) out_dtype."""
    m, k = x.shape
    _, n = w.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    kernel = functools.partial(_kernel, n_k=k // block_k)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, block_n), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x, w, scale[None, :])
