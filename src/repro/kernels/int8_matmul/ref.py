"""Pure-jnp oracle for the INT8 GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(x, w, scale, out_dtype=jnp.float32):
    acc = jax.lax.dot(x, w, preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * scale[None, :]).astype(out_dtype)
