"""Naive per-token selective-scan oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(da, bx, c):
    """da/bx: (B,T,di,N) (da = log decay); c: (B,T,N) -> y (B,T,di)."""
    def body(h, inp):
        da_, bx_, c_ = inp
        h = jnp.exp(da_) * h + bx_
        return h, jnp.einsum("bdn,bn->bd", h, c_)

    b, t, di, n = da.shape
    h0 = jnp.zeros((b, di, n), jnp.float32)
    inputs = (da.astype(jnp.float32).swapaxes(0, 1),
              bx.astype(jnp.float32).swapaxes(0, 1),
              c.astype(jnp.float32).swapaxes(0, 1))
    _, ys = jax.lax.scan(body, h0, inputs)
    return ys.swapaxes(0, 1).astype(da.dtype)
