"""Selective-SSM scan (Mamba-style) — Pallas TPU kernel.

The (d_block, N) state tile lives in VMEM scratch and persists across the
sequential chunk dimension; within a chunk the recurrence runs as an
unrolled time loop over VREG-resident tiles (N=16 states x 8-lane sublanes —
the recurrence is elementwise on the VPU, with the C_t contraction feeding
the MXU only at readout). Channels are tiled on the grid so arbitrarily
wide d_inner streams through a fixed VMEM budget.

Grid: (B, n_d_blocks, n_chunks)   [chunk dim sequential]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(da_ref, bx_ref, c_ref, o_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    da = da_ref[0].astype(jnp.float32)      # (C, db, N) log-decay <= 0
    bx = bx_ref[0].astype(jnp.float32)      # (C, db, N) input term
    cc = c_ref[0].astype(jnp.float32)       # (C, N)

    h = h_scr[...]                          # (db, N)
    ys = []
    for t in range(chunk):                  # unrolled VPU recurrence
        h = jnp.exp(da[t]) * h + bx[t]
        ys.append(jnp.sum(h * cc[t][None, :], axis=1))   # (db,)
    h_scr[...] = h
    o_ref[0] = jnp.stack(ys, axis=0).astype(o_ref.dtype)   # (C, db)


def ssm_scan_btdn(da, bx, c, *, chunk: int = 16, d_block: int = 256,
                  interpret: bool = False) -> jax.Array:
    """da/bx: (B,T,di,N); c: (B,T,N). Returns y (B,T,di)."""
    b, t, di, n = da.shape
    chunk = min(chunk, t)
    d_block = min(d_block, di)
    assert t % chunk == 0 and di % d_block == 0, (t, chunk, di, d_block)
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, di // d_block, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block, n),
                         lambda b_, d_, c_: (b_, c_, d_, 0)),
            pl.BlockSpec((1, chunk, d_block, n),
                         lambda b_, d_, c_: (b_, c_, d_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, d_, c_: (b_, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block),
                               lambda b_, d_, c_: (b_, c_, d_)),
        out_shape=jax.ShapeDtypeStruct((b, t, di), da.dtype),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(da, bx, c)
