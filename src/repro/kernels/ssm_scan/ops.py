"""Jitted wrapper for the selective-scan kernel (+ CPU interpret fallback)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan_btdn


@functools.partial(jax.jit, static_argnames=("chunk", "d_block", "interpret"))
def ssm_scan(da, bx, c, *, chunk: int = 16, d_block: int = 256,
             interpret: bool | None = None) -> jax.Array:
    """da/bx: (B,T,di,N) with da = per-step log-decay (<=0); c: (B,T,N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssm_scan_btdn(da, bx, c, chunk=chunk, d_block=d_block,
                         interpret=interpret)
