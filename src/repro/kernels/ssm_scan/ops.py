"""Jitted wrapper for the selective-scan kernel (+ CPU interpret fallback).

The shape/dtype contract is enforced eagerly; ``interpret`` is resolved
outside the jitted body (kernels/common.resolve_interpret).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import (check_float_dtype, check_rank,
                                  resolve_interpret)
from repro.kernels.ssm_scan.kernel import ssm_scan_btdn


@functools.partial(jax.jit, static_argnames=("chunk", "d_block", "interpret"))
def _ssm_scan_jit(da, bx, c, *, chunk: int, d_block: int,
                  interpret: bool) -> jax.Array:
    return ssm_scan_btdn(da, bx, c, chunk=chunk, d_block=d_block,
                         interpret=interpret)


def check_contract(da, bx, c, *, chunk: int = 16,
                   d_block: int = 256) -> None:
    """Shape/dtype contract shared with the kernel registry."""
    check_rank("ssm_scan", "da", da, 4)
    check_rank("ssm_scan", "bx", bx, 4)
    check_rank("ssm_scan", "c", c, 3)
    for name, a in (("da", da), ("bx", bx), ("c", c)):
        check_float_dtype("ssm_scan", name, a)
    b, t, di, n = da.shape
    if tuple(bx.shape) != tuple(da.shape):
        raise ValueError(
            f"ssm_scan: da/bx shapes differ: {tuple(da.shape)} vs "
            f"{tuple(bx.shape)}")
    if tuple(c.shape) != (b, t, n):
        raise ValueError(
            f"ssm_scan: c must be (B,T,N)=({b},{t},{n}), got "
            f"{tuple(c.shape)}")
    if t == 0:
        raise ValueError("ssm_scan: zero-length sequence (t=0)")
    if di == 0 or n == 0:
        raise ValueError(f"ssm_scan: zero-size state (di={di}, n={n})")
    if t % min(int(chunk), t) != 0:
        raise ValueError(
            f"ssm_scan: chunk={chunk} does not tile seq_len {t} "
            f"(pad the sequence or pick a divisor)")
    if di % min(int(d_block), di) != 0:
        raise ValueError(
            f"ssm_scan: d_block={d_block} does not tile d_inner {di}")


def ssm_scan(da, bx, c, *, chunk: int = 16, d_block: int = 256,
             interpret: bool | None = None) -> jax.Array:
    """da/bx: (B,T,di,N) with da = per-step log-decay (<=0); c: (B,T,N)."""
    check_contract(da, bx, c, chunk=chunk, d_block=d_block)
    return _ssm_scan_jit(da, bx, c, chunk=int(chunk), d_block=int(d_block),
                         interpret=resolve_interpret(interpret))
