"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  group: int = 1, causal: bool = True,
                  scale: float | None = None) -> jax.Array:
    """q: (BHG, S, D); k/v: (BH, S, D)."""
    bhg, s, d = q.shape
    bh, sk, _ = k.shape
    scale = scale if scale is not None else 1.0 / d ** 0.5
    qg = q.reshape(bh, group, s, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bgqd,bkd->bgqk", qg, kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgqk,bkd->bgqd", p, vf)
    return o.reshape(bhg, s, d).astype(q.dtype)
