"""Jitted public wrapper for the flash-attention kernel.

Handles layout (B,S,H,D) <-> kernel layout, GQA head grouping, head_dim
padding to the 128-lane MXU width, and interpret-mode fallback on CPU.
The shape/dtype contract is enforced eagerly (clear ``ValueError`` before
any tracing); ``interpret`` is resolved OUTSIDE the jitted body
(kernels/common.resolve_interpret) so it enters the trace as an
already-concrete static flag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import (check_float_dtype, check_rank,
                                  resolve_interpret)
from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _flash_attention_jit(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool, block_q: int, block_k: int,
                         interpret: bool) -> jax.Array:
    b, s, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    dp = max(d, 128) if not interpret else d      # MXU lane alignment
    if dp != d:
        pad = [(0, 0)] * 3 + [(0, dp - d)]
        q, k, v = (jnp.pad(a, pad) for a in (q, k, v))
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, dp)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dp)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dp)
    o = flash_attention_bhsd(qk, kk, vk, group=g, causal=causal,
                             scale=1.0 / d ** 0.5, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    o = o.reshape(b, h, s, dp).transpose(0, 2, 1, 3)
    return o[..., :d]


def check_contract(q, k, v) -> None:
    """Shape/dtype contract shared with the kernel registry."""
    for name, a in (("q", q), ("k", k), ("v", v)):
        check_rank("flash_attention", name, a, 4)
        check_float_dtype("flash_attention", name, a)
    b, s, h, d = q.shape
    bk, sk, hkv, dk = k.shape
    if tuple(k.shape) != tuple(v.shape):
        raise ValueError(
            f"flash_attention: k/v shapes differ: {tuple(k.shape)} vs "
            f"{tuple(v.shape)}")
    if bk != b or dk != d:
        raise ValueError(
            f"flash_attention: q {tuple(q.shape)} and k {tuple(k.shape)} "
            f"disagree on batch/head_dim")
    if hkv == 0 or h % hkv != 0:
        raise ValueError(
            f"flash_attention: GQA grouping requires num_heads % "
            f"num_kv_heads == 0, got h={h}, hkv={hkv}")
    if s == 0 or sk == 0:
        raise ValueError(
            f"flash_attention: zero-length sequence (s={s}, s_kv={sk})")


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,Hkv,D); returns (B,S,H,D)."""
    check_contract(q, k, v)
    return _flash_attention_jit(q, k, v, causal=bool(causal),
                                block_q=int(block_q), block_k=int(block_k),
                                interpret=resolve_interpret(interpret))
