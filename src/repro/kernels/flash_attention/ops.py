"""Jitted public wrapper for the flash-attention kernel.

Handles layout (B,S,H,D) <-> kernel layout, GQA head grouping, head_dim
padding to the 128-lane MXU width, and interpret-mode fallback on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,Hkv,D); returns (B,S,H,D)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, d = q.shape
    _, sk, hkv, _ = k.shape
    g = h // hkv
    dp = max(d, 128) if not interpret else d      # MXU lane alignment
    if dp != d:
        pad = [(0, 0)] * 3 + [(0, dp - d)]
        q, k, v = (jnp.pad(a, pad) for a in (q, k, v))
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, dp)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dp)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dp)
    o = flash_attention_bhsd(qk, kk, vk, group=g, causal=causal,
                             scale=1.0 / d ** 0.5, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    o = o.reshape(b, h, s, dp).transpose(0, 2, 1, 3)
    return o[..., :d]
