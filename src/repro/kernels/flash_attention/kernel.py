"""Blocked causal (GQA) flash attention — Pallas TPU kernel.

TPU adaptation of the paper's "software choreographs data movement into
local memory" principle: BlockSpecs stage (block_q x d) query tiles and
(block_k x d) KV tiles HBM->VMEM; the online-softmax running state
(m, l, acc) lives in VMEM scratch and persists across the sequential
trailing grid dimension (the KV walk), so scores never round-trip to HBM.
MXU alignment: block sizes are multiples of 128 on the matmul dims (the
wrapper pads smaller head_dims).

Grid: (batch*kv_heads*group, n_q_blocks, n_kv_blocks)   [last dim sequential]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       s.shape, 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + \
        jax.lax.dot(p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _emit():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         group: int = 1, causal: bool = True,
                         scale: float | None = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q: (BHG, S, D); k/v: (BH, S, D) with BHG == BH*group."""
    bhg, s, d = q.shape
    bh, sk, _ = k.shape
    assert bhg == bh * group, (q.shape, k.shape, group)
    scale = scale if scale is not None else 1.0 / d ** 0.5
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    n_q = pl.cdiv(s, block_q)
    n_k = pl.cdiv(sk, block_k)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bhg, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, g=group: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, g=group: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhg, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
