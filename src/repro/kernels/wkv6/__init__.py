from repro.kernels.wkv6.ops import wkv6  # noqa: F401
