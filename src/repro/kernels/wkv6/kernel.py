"""RWKV-6 WKV recurrence (data-dependent decay) — Pallas TPU kernel.

Chunked formulation (see models/rwkv6.py): all exponentials are of
non-positive arguments, so the kernel is overflow-free for arbitrarily
strong decay. Per (batch*head) the (K,K) state lives in VMEM scratch and
persists across the sequential chunk dimension; each chunk stages (C,K)
tiles of r/k/v/logw and computes the (C,C,K) pairwise-decay contraction
entirely in VMEM — the HBM traffic is exactly 4 reads + 1 write of the
(T,K) stream per head, vs O(T*K*K) for a naive recurrence.

Grid: (B*H, n_chunks)   [chunk dim sequential, state carried in scratch]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
            chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)            # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)          # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)            # (1, K) bonus

    C = chunk
    p = jnp.cumsum(lw, axis=0)                  # inclusive
    pprev = p - lw                              # exclusive (p_{t-1})

    # intra-chunk: att[t,j] = sum_i r[t,i] k[j,i] exp(pprev[t,i]-p[j,i]), j<t
    diff = pprev[:, None, :] - p[None, :, :]    # (C,C,K), <=0 for j<=t-1
    tmask = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jmask = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    mask = (jmask < tmask)[:, :, None]
    e = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    att = jnp.einsum("ti,ji,tji->tj", r, k, e,
                     preferred_element_type=jnp.float32)
    y = jax.lax.dot(att, v, preferred_element_type=jnp.float32)

    # diagonal bonus: y[t] += (r[t] . (u*k[t])) v[t]
    coef = jnp.sum(r * u * k, axis=1, keepdims=True)
    y = y + coef * v

    # inter-chunk: state entering the chunk
    s = s_scr[...]                               # (K, K)
    y = y + jax.lax.dot(r * jnp.exp(pprev), s,
                        preferred_element_type=jnp.float32)

    # state update: S' = exp(p[-1]) * S + sum_t (k[t]*exp(p[-1]-p[t])) v[t]^T
    kd = k * jnp.exp(p[-1:] - p)
    s_scr[...] = jnp.exp(p[-1])[:, None] * s + jax.lax.dot(
        kd.T, v, preferred_element_type=jnp.float32)

    o_ref[0] = y.astype(o_ref.dtype)


def wkv6_bhtk(r, k, v, lw, u, *, chunk: int = 64,
              interpret: bool = False) -> jax.Array:
    """r/k/v/lw: (BH, T, K); u: (BH, K). Returns y (BH, T, K)."""
    bh, t, kk = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n_c = t // chunk
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, kk), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, kk), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, kk), r.dtype),
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
