"""Naive per-token WKV6 recurrence — the true oracle (O(T*K*K) state walk).

  y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
  S_t = diag(w_t) S_{t-1} + k_t v_t^T        with w_t = exp(lw_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, lw, u):
    """r/k/v/lw: (BH, T, K) fp32; u: (BH, K). Returns (BH, T, K)."""
    bh, t, kk = r.shape
    w = jnp.exp(lw.astype(jnp.float32))

    def body(s, inp):
        r_, k_, v_, w_ = inp                       # (BH, K)
        kv = k_[:, :, None] * v_[:, None, :]       # (BH, K, K)
        y = jnp.einsum("bi,bio->bo", r_,
                       s + u[:, :, None] * kv)
        s = w_[:, :, None] * s + kv
        return s, y

    s0 = jnp.zeros((bh, kk, kk), jnp.float32)
    inputs = tuple(a.astype(jnp.float32).swapaxes(0, 1)
                   for a in (r, k, v, w))
    _, ys = jax.lax.scan(body, s0, inputs)
    return ys.swapaxes(0, 1).astype(r.dtype)
