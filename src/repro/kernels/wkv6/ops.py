"""Jitted public wrapper for the WKV6 kernel: (B,T,H,K) layout + fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_bhtk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, lw, u, *, chunk: int = 64,
         interpret: bool | None = None) -> jax.Array:
    """r/k/v/lw: (B,T,H,K); u: (H,K). Returns y (B,T,H,K)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, h, kk = r.shape

    def fold(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, t, kk)

    u_full = jnp.broadcast_to(u[None], (b, h, kk)).reshape(b * h, kk)
    y = wkv6_bhtk(fold(r), fold(k), fold(v), fold(lw), u_full,
                  chunk=chunk, interpret=interpret)
    return y.reshape(b, h, t, kk).transpose(0, 2, 1, 3)
