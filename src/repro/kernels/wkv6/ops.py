"""Jitted public wrapper for the WKV6 kernel: (B,T,H,K) layout + fallback.

The shape/dtype contract is enforced eagerly; ``interpret`` is resolved
outside the jitted body (kernels/common.resolve_interpret).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import (check_float_dtype, check_rank,
                                  resolve_interpret)
from repro.kernels.wkv6.kernel import wkv6_bhtk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv6_jit(r, k, v, lw, u, *, chunk: int, interpret: bool) -> jax.Array:
    b, t, h, kk = r.shape

    def fold(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, t, kk)

    u_full = jnp.broadcast_to(u[None], (b, h, kk)).reshape(b * h, kk)
    y = wkv6_bhtk(fold(r), fold(k), fold(v), fold(lw), u_full,
                  chunk=chunk, interpret=interpret)
    return y.reshape(b, h, t, kk).transpose(0, 2, 1, 3)


def check_contract(r, k, v, lw, u, *, chunk: int = 64) -> None:
    """Shape/dtype contract shared with the kernel registry."""
    for name, a in (("r", r), ("k", k), ("v", v), ("lw", lw)):
        check_rank("wkv6", name, a, 4)
        check_float_dtype("wkv6", name, a)
        if tuple(a.shape) != tuple(r.shape):
            raise ValueError(
                f"wkv6: operand {name!r} shape {tuple(a.shape)} differs "
                f"from r {tuple(r.shape)}")
    check_rank("wkv6", "u", u, 2)
    check_float_dtype("wkv6", "u", u)
    b, t, h, kk = r.shape
    if tuple(u.shape) != (h, kk):
        raise ValueError(
            f"wkv6: u must be (H,K)=({h},{kk}), got {tuple(u.shape)}")
    if t == 0:
        raise ValueError("wkv6: zero-length sequence (t=0)")
    if h == 0 or kk == 0:
        raise ValueError(f"wkv6: zero-size head layout (h={h}, k={kk})")
    if t % min(int(chunk), t) != 0:
        raise ValueError(
            f"wkv6: chunk={chunk} does not tile seq_len {t} "
            f"(pad the sequence or pick a divisor)")


def wkv6(r, k, v, lw, u, *, chunk: int = 64,
         interpret: bool | None = None) -> jax.Array:
    """r/k/v/lw: (B,T,H,K); u: (H,K). Returns y (B,T,H,K)."""
    check_contract(r, k, v, lw, u, chunk=chunk)
    return _wkv6_jit(r, k, v, lw, u, chunk=int(chunk),
                     interpret=resolve_interpret(interpret))
