"""Shared helpers for the kernel wrappers: backend resolution + contracts.

``resolve_interpret`` is the ONE place the pallas ``interpret`` flag is
decided, and it must be called OUTSIDE any jitted body: the flag is a
static argument of every kernel wrapper, so resolving it inside a trace
would bake whatever backend happened to be active at first trace into the
cached executable (flipping backends later would silently replay the
stale choice). The four ``ops.py`` wrappers resolve it eagerly and pass
the concrete bool down to their jitted inner functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# dtypes the pallas kernels accept for floating operands; everything else
# is rejected with a ValueError by the shape contracts below.
FLOAT_DTYPES = (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16),
                jnp.dtype(jnp.float16))


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve the pallas ``interpret`` flag for the live backend.

    ``None`` means "compiled on TPU, interpret-mode everywhere else".
    Must be called from eager (non-traced) code — the result becomes a
    static jit argument of the kernel wrappers.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def check_float_dtype(kernel: str, name: str, arr) -> None:
    """Reject unsupported floating dtypes with a clear error."""
    if jnp.dtype(arr.dtype) not in FLOAT_DTYPES:
        raise ValueError(
            f"{kernel}: operand {name!r} has unsupported dtype "
            f"{arr.dtype}; supported: "
            f"{', '.join(str(d) for d in FLOAT_DTYPES)}")


def check_rank(kernel: str, name: str, arr, rank: int) -> None:
    if arr.ndim != rank:
        raise ValueError(
            f"{kernel}: operand {name!r} must be rank-{rank}, got shape "
            f"{tuple(arr.shape)}")
