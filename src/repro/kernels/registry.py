"""Kernel registry — the Pallas kernels as first-class, autotuned handlers.

One ``KernelSpec`` per kernel holds the pallas implementation (the jitted
``ops.py`` wrapper), the pure-jnp reference oracle, the shape/dtype
contract, and an autotune space of block-size candidates. All three call
sites — eager model code, GRAPH_EXEC artifacts, and linked RCB kernel ops
(``Op.ATTENTION`` / ``MATMUL_INT8`` / ``SSM_SCAN`` / ``WKV6``) — pull
their implementation from here, so each hot loop has exactly one
implementation.

Fallback ladder (DESIGN.md §13):
  1. explicit ``impl`` override (``"pallas"`` | ``"ref"``) from op attrs
     or a keyword — tests, debugging, A/B rows;
  2. pallas with ``interpret`` resolved per call site OUTSIDE any trace
     (kernels/common.resolve_interpret: compiled on TPU, interpret-mode
     elsewhere);
  3. the ``ref.py`` oracle when the pallas toolchain is unavailable
     (import failure is caught at module load and remembered).

Autotune: ``autotune()`` sweeps the spec's candidate block sizes on the
live backend and records the winner per (kernel, shape-sig, dtype,
backend). Winners persist as a RIMFS image — one JSON file at
``kernels/autotune.json`` — via ``pack_image``/``load_image``, so a
re-provisioned process performs ZERO sweep trials for shapes it has
already seen (``sweep_trials`` counts timed candidate runs and is the
testable witness).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rimfs as rimfs_mod
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.wkv6.ref import wkv6_ref

AUTOTUNE_FILE = "kernels/autotune.json"
KERNEL_NAMES = ("attention", "matmul_int8", "ssm_scan", "wkv6")

# The pallas wrappers are gated: a missing/broken pallas toolchain demotes
# every kernel to its ref oracle instead of failing at import.
try:
    from repro.kernels.flash_attention import ops as _fa_ops
    from repro.kernels.int8_matmul import ops as _im_ops
    from repro.kernels.ssm_scan import ops as _ss_ops
    from repro.kernels.wkv6 import ops as _wk_ops
    PALLAS_IMPORT_ERROR: Optional[BaseException] = None
except Exception as e:  # pragma: no cover — pallas toolchain absent
    _fa_ops = _im_ops = _ss_ops = _wk_ops = None
    PALLAS_IMPORT_ERROR = e


def _divisor_leq(dim: int, cap: int) -> int:
    """Largest divisor of ``dim`` that is <= cap (>= 1)."""
    cap = max(1, min(int(cap), int(dim)))
    while dim % cap:
        cap -= 1
    return cap


def _dedup(cands: list[dict]) -> list[dict]:
    seen, out = set(), []
    for c in cands:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


# ---------------------------------------------------------------------------
# Per-kernel adapters: public (model-layout) signature -> pallas/ref impls
# ---------------------------------------------------------------------------

def _attention_ref_bshd(q, k, v, *, causal: bool = True):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * hkv, k.shape[1], d)
    vk = v.transpose(0, 2, 1, 3).reshape(b * hkv, v.shape[1], d)
    o = attention_ref(qk, kk, vk, group=g, causal=causal)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def _attention_pallas(q, k, v, *, params: dict, causal: bool = True):
    s, sk = q.shape[1], k.shape[1]
    bq = min(int(params["block_q"]), s)
    bk = min(int(params["block_k"]), sk)
    if not causal and (s % bq or sk % bk):
        # non-causal + ragged tiles would fold padded keys into the
        # softmax; causal masking already excludes the tail (kpos > qpos)
        return _attention_ref_bshd(q, k, v, causal=causal)
    return _fa_ops.flash_attention(q, k, v, causal=causal,
                                   block_q=bq, block_k=bk)


def _attention_space(q, k, v):
    s, sk = q.shape[1], k.shape[1]
    return _dedup([{"block_q": min(bq, s), "block_k": min(bk, sk)}
                   for bq in (64, 128) for bk in (64, 128)])


def _attention_normalize(params: dict, args) -> dict:
    q, k = args[0], args[1]
    return {"block_q": min(int(params["block_q"]), q.shape[1]),
            "block_k": min(int(params["block_k"]), k.shape[1])}


def _matmul_int8_pallas(x, w, scale, *, params: dict,
                        out_dtype=jnp.float32):
    return _im_ops.int8_matmul(x, w, scale, block_m=params["block_m"],
                               block_n=params["block_n"],
                               block_k=params["block_k"],
                               out_dtype=out_dtype)


def _matmul_int8_ref(x, w, scale, *, out_dtype=jnp.float32):
    return int8_matmul_ref(x, w, scale, out_dtype=out_dtype)


def _matmul_int8_space(x, w, scale):
    m, kdim = x.shape
    n = w.shape[1]
    return _dedup([{"block_m": _divisor_leq(m, blk),
                    "block_n": _divisor_leq(n, blk),
                    "block_k": _divisor_leq(kdim, blk)}
                   for blk in (64, 128, 256)])


def _matmul_int8_normalize(params: dict, args) -> dict:
    x, w = args[0], args[1]
    return {"block_m": _divisor_leq(x.shape[0], params["block_m"]),
            "block_n": _divisor_leq(w.shape[1], params["block_n"]),
            "block_k": _divisor_leq(x.shape[1], params["block_k"])}


def _ssm_scan_pallas(da, bx, c, *, params: dict):
    b, t, di, n = da.shape
    chunk = min(int(params["chunk"]), t)
    tp = -(-t // chunk) * chunk
    if tp != t:
        # identity padding (da=0 keeps h, bx=0 adds nothing); padded y
        # rows are sliced off below — ragged T rides the tiled kernel
        pad4 = [(0, 0), (0, tp - t), (0, 0), (0, 0)]
        da = jnp.pad(da, pad4)
        bx = jnp.pad(bx, pad4)
        c = jnp.pad(c, [(0, 0), (0, tp - t), (0, 0)])
    y = _ss_ops.ssm_scan(da, bx, c, chunk=chunk,
                         d_block=_divisor_leq(di, params["d_block"]))
    return y[:, :t]


def _ssm_scan_space(da, bx, c):
    t, di = da.shape[1], da.shape[2]
    return _dedup([{"chunk": min(ch, t), "d_block": _divisor_leq(di, db)}
                   for ch in (8, 16, 32) for db in (128, 256)])


def _ssm_scan_normalize(params: dict, args) -> dict:
    da = args[0]
    return {"chunk": min(int(params["chunk"]), da.shape[1]),
            "d_block": _divisor_leq(da.shape[2], params["d_block"])}


def _wkv6_pallas(r, k, v, lw, u, *, params: dict):
    b, t, h, kk = r.shape
    chunk = min(int(params["chunk"]), t)
    tp = -(-t // chunk) * chunk
    if tp != t:
        # identity padding: k=v=0 adds nothing to the state, lw=0 leaves
        # it undecayed, r=0 makes the padded y rows zeros (sliced off)
        pad4 = [(0, 0), (0, tp - t), (0, 0), (0, 0)]
        r, k, v, lw = (jnp.pad(a, pad4) for a in (r, k, v, lw))
    y = _wk_ops.wkv6(r, k, v, lw, u, chunk=chunk)
    return y[:, :t]


def _wkv6_ref_bthk(r, k, v, lw, u):
    b, t, h, kk = r.shape

    def fold(a):
        return a.transpose(0, 2, 1, 3).reshape(b * h, t, kk)

    uf = jnp.broadcast_to(u[None], (b, h, kk)).reshape(b * h, kk)
    y = wkv6_ref(fold(r), fold(k), fold(v), fold(lw), uf)
    return y.reshape(b, h, t, kk).transpose(0, 2, 1, 3)


def _wkv6_space(r, k, v, lw, u):
    t = r.shape[1]
    return _dedup([{"chunk": min(ch, t)} for ch in (16, 32, 64)])


def _wkv6_normalize(params: dict, args) -> dict:
    return {"chunk": min(int(params["chunk"]), args[0].shape[1])}


# Registry-level contracts re-use the ops.py checkers but relax the block
# tiling constraints (block_*=1 always tiles): the registry pads ragged
# sequences and normalizes block sizes itself, so only the semantic
# shape/dtype rules apply here.

def _contract_attention(q, k, v):
    if _fa_ops is not None:
        _fa_ops.check_contract(q, k, v)
        return
    if q.ndim != 4 or k.shape != v.shape or q.shape[1] == 0:
        raise ValueError("flash_attention: bad operand shapes")
    if k.shape[2] == 0 or q.shape[2] % k.shape[2] != 0:
        raise ValueError("flash_attention: GQA grouping requires "
                         "num_heads % num_kv_heads == 0")


def _contract_matmul_int8(x, w, scale):
    if _im_ops is not None:
        _im_ops.check_contract(x, w, scale, block_m=1, block_n=1, block_k=1)


def _contract_ssm_scan(da, bx, c):
    if _ss_ops is not None:
        _ss_ops.check_contract(da, bx, c, chunk=1, d_block=1)


def _contract_wkv6(r, k, v, lw, u):
    if _wk_ops is not None:
        _wk_ops.check_contract(r, k, v, lw, u, chunk=1)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One kernel: pallas impl + ref oracle + contract + autotune space."""
    name: str
    pallas: Optional[Callable]          # (*args, params=dict, **kw) -> out
    ref: Callable                       # (*args, **kw) -> out
    contract: Callable                  # (*args) -> None or ValueError
    space: Callable                     # (*args) -> list[dict] candidates
    normalize: Callable                 # (params, args) -> valid params
    defaults: tuple                     # ((param, value), ...)


class KernelRegistry:
    """Kernel specs + per-(shape, dtype, backend) autotuned block sizes."""

    def __init__(self):
        self.specs: dict[str, KernelSpec] = {}
        # signature -> {"params": dict, "us": float|None, "source": str}
        self.winners: dict[str, dict] = {}
        self.sweep_trials = 0           # timed candidate runs, ever
        self.stats: dict[str, int] = {}

    # ------------------------------------------------------------- plumbing
    def register(self, spec: KernelSpec) -> None:
        self.specs[spec.name] = spec

    def get(self, name: str) -> KernelSpec:
        spec = self.specs.get(name)
        if spec is None:
            raise KeyError(f"unknown kernel {name!r}; registered: "
                           f"{sorted(self.specs)}")
        return spec

    def available(self, name: str) -> bool:
        """True iff the pallas implementation imported successfully."""
        return self.get(name).pallas is not None

    def _count(self, key: str, n: int = 1) -> None:
        self.stats[key] = self.stats.get(key, 0) + n

    def signature(self, name: str, args, kwargs: Optional[dict] = None) -> str:
        shapes = ";".join(
            f"{tuple(a.shape)}:{jnp.dtype(a.dtype)}" for a in args)
        extra = json.dumps({k: str(v) for k, v in (kwargs or {}).items()},
                           sort_keys=True)
        return f"{name}|{jax.default_backend()}|{shapes}|{extra}"

    # ------------------------------------------------------------- dispatch
    def params_for(self, name: str, args,
                   kwargs: Optional[dict] = None) -> dict:
        """Autotuned winner for this site, else normalized defaults."""
        spec = self.get(name)
        hit = self.winners.get(self.signature(name, args, kwargs))
        if hit is not None:
            self._count("params_hit")
            return dict(hit["params"])
        self._count("params_default")
        return spec.normalize(dict(spec.defaults), args)

    def call(self, name: str, *args, impl: Optional[str] = None,
             params: Optional[dict] = None, **kwargs):
        """Dispatch one kernel through the fallback ladder."""
        spec = self.get(name)
        spec.contract(*args)
        if impl == "ref" or spec.pallas is None:
            if impl == "pallas" and spec.pallas is None:
                raise RuntimeError(
                    f"kernel {name!r}: pallas requested but unavailable "
                    f"({PALLAS_IMPORT_ERROR!r})")
            self._count(f"{name}_ref")
            return spec.ref(*args, **kwargs)
        if impl not in (None, "pallas"):
            raise ValueError(f"kernel {name!r}: unknown impl {impl!r} "
                             f"(expected 'pallas' or 'ref')")
        if params is None:
            params = self.params_for(name, args, kwargs)
        else:
            params = spec.normalize(dict(params), args)
        self._count(f"{name}_pallas")
        return spec.pallas(*args, params=params, **kwargs)

    # ------------------------------------------------------------- autotune
    def autotune(self, name: str, *args, **kwargs):
        """Sweep the candidate space for this call site; returns
        ``(winning params, timed trials run)``. A cached winner (including
        one loaded from a RIMFS image) costs zero trials."""
        spec = self.get(name)
        spec.contract(*args)
        key = self.signature(name, args, kwargs)
        hit = self.winners.get(key)
        if hit is not None:
            self._count("autotune_hit")
            return dict(hit["params"]), 0
        if spec.pallas is None:
            params = spec.normalize(dict(spec.defaults), args)
            self.winners[key] = {"params": params, "us": None,
                                 "source": "default"}
            return params, 0
        best, best_t = None, None
        trials = 0
        for cand in spec.space(*args):
            out = spec.pallas(*args, params=cand, **kwargs)
            jax.block_until_ready(out)             # compile + warm
            t0 = time.perf_counter()
            jax.block_until_ready(spec.pallas(*args, params=cand, **kwargs))
            dt = time.perf_counter() - t0
            trials += 1
            if best_t is None or dt < best_t:
                best, best_t = dict(cand), dt
        self.sweep_trials += trials
        self._count("autotune_sweep")
        self.winners[key] = {"params": best, "us": best_t * 1e6,
                             "source": "sweep"}
        return dict(best), trials

    # ----------------------------------------------------------- persistence
    def pack_image(self) -> bytes:
        """Serialize the winner table as a RIMFS image (one JSON file)."""
        payload = json.dumps({"version": 1, "winners": self.winners},
                             sort_keys=True).encode()
        return rimfs_mod.pack(
            {AUTOTUNE_FILE: np.frombuffer(payload, np.uint8)})

    def load_image(self, image) -> int:
        """Merge winners from a RIMFS image (bytes or mounted RIMFS).
        Returns the number of entries installed. Loaded entries satisfy
        ``autotune`` with zero sweep trials — the provision-time reload."""
        fs = rimfs_mod.mount(image) \
            if isinstance(image, (bytes, bytearray, memoryview)) else image
        data = json.loads(bytes(np.asarray(fs.read(AUTOTUNE_FILE))).decode())
        if data.get("version") != 1:
            raise ValueError(
                f"autotune image version {data.get('version')!r} != 1")
        n = 0
        for key, entry in data["winners"].items():
            if key not in self.winners:
                self.winners[key] = {"params": dict(entry["params"]),
                                     "us": entry.get("us"),
                                     "source": "loaded"}
                n += 1
        return n

    def reset(self) -> None:
        """Drop all winners and counters (a fresh provision)."""
        self.winners.clear()
        self.sweep_trials = 0
        self.stats.clear()


def _build_default_registry() -> KernelRegistry:
    reg = KernelRegistry()
    reg.register(KernelSpec(
        "attention",
        _attention_pallas if _fa_ops is not None else None,
        _attention_ref_bshd,
        _contract_attention,
        _attention_space, _attention_normalize,
        (("block_q", 128), ("block_k", 128))))
    reg.register(KernelSpec(
        "matmul_int8",
        _matmul_int8_pallas if _im_ops is not None else None,
        _matmul_int8_ref,
        _contract_matmul_int8,
        _matmul_int8_space, _matmul_int8_normalize,
        (("block_m", 128), ("block_n", 128), ("block_k", 128))))
    reg.register(KernelSpec(
        "ssm_scan",
        _ssm_scan_pallas if _ss_ops is not None else None,
        ssm_scan_ref,
        _contract_ssm_scan,
        _ssm_scan_space, _ssm_scan_normalize,
        (("chunk", 16), ("d_block", 256))))
    reg.register(KernelSpec(
        "wkv6",
        _wkv6_pallas if _wk_ops is not None else None,
        _wkv6_ref_bthk,
        _contract_wkv6,
        _wkv6_space, _wkv6_normalize,
        (("chunk", 64),)))
    return reg


REGISTRY = _build_default_registry()


# ---------------------------------------------------------------------------
# Module-level API (the singleton most call sites use)
# ---------------------------------------------------------------------------

def get(name: str) -> KernelSpec:
    return REGISTRY.get(name)


def available(name: str) -> bool:
    return REGISTRY.available(name)


def call(name: str, *args, **kwargs):
    return REGISTRY.call(name, *args, **kwargs)


def autotune(name: str, *args, **kwargs):
    return REGISTRY.autotune(name, *args, **kwargs)


def params_for(name: str, args, kwargs: Optional[dict] = None) -> dict:
    return REGISTRY.params_for(name, args, kwargs)


def pack_image() -> bytes:
    return REGISTRY.pack_image()


def load_image(image) -> int:
    return REGISTRY.load_image(image)


def reset() -> None:
    REGISTRY.reset()


def call_op(name: str, srcs, attrs) -> Any:
    """Kernel-op entry used by core/oplib: unpack RCB attrs into the
    semantic keyword signature. Attrs must stay JSON-wire-safe."""
    attrs = attrs or {}
    impl = attrs.get("impl")
    params = attrs.get("params")
    if name == "attention":
        return call("attention", *srcs, impl=impl, params=params,
                    causal=bool(attrs.get("causal", True)))
    if name == "matmul_int8":
        return call("matmul_int8", *srcs, impl=impl, params=params,
                    out_dtype=jnp.dtype(attrs.get("out_dtype", "float32")))
    return call(name, *srcs, impl=impl, params=params)


def linked_handler(name: str, attrs) -> Callable:
    """Build the specialized positional handler ``fn(*srcs)`` the RHAL
    ``link_compute`` vtables hand to core/linker.py for kernel opcodes.
    Block-size lookup happens per call (shapes are only known then); the
    heavy math runs through the kernels' shared jitted wrappers, so eager
    linked dispatch and traced fusion hit the same executables."""
    def handler(*srcs):
        return call_op(name, srcs, attrs)
    return handler
