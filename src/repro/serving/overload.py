"""Brown-out overload control plane (DESIGN.md §14).

Under sustained overload a serving fleet has exactly two honest moves:
do less work per request, or refuse some requests with a typed verdict.
``BrownoutController`` encodes that as a ladder of degradation rungs it
walks DOWN under pressure and back UP with hysteresis once the pressure
clears — every transition a dispatcher control op (atomic between
requests), every shed machine-readable, every move an RTPM event:

  rung 0  normal            full service
  rung 1  narrow_batch      coalescing window -> 1 (tail latency over
                            throughput: no request waits for company)
  rung 2  clamp_decode      LM admissions get their max_new clamped
  rung 3  shed_low_prio     priority classes >= ``shed_priority`` are
                            shed at admission with verdict kind
                            "brownout" (retryable — capacity WILL return)
  rung 4  circuit_break     the worst *failing* tile group is circuit-
                            broken: killed (partition failover routes
                            around it), probed with golden inputs after a
                            cooldown (half-open), revived + CRC-checked
                            only when the probe answers bit-identically

The controller watches the dispatcher's queue-wait p99 and the
admission miss rate over WINDOWED telemetry (only samples since its
previous tick), requires ``escalate_ticks`` consecutive hot ticks to
descend one rung and ``recover_ticks`` consecutive cool ticks (with a
margin) to climb one back — one noisy sample never changes service
levels, and recovery cannot oscillate against the very load it sheds.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional

import numpy as np

from repro.core import fleet as fleet_mod


@dataclasses.dataclass
class OverloadConfig:
    """Brown-out policy knobs (hysteresis lives here, not in code)."""
    p99_high: float = 0.5          # queue-wait p99 (s) that reads as hot
    miss_rate_high: float = 0.20   # shed fraction that reads as hot
    min_window: int = 4            # min new samples before judging a tick
    escalate_ticks: int = 2        # consecutive hot ticks -> down a rung
    recover_ticks: int = 3         # consecutive cool ticks -> up a rung
    recover_margin: float = 0.5    # cool = p99 < margin * p99_high
    max_new_clamp: int = 8         # rung 2: LM decode budget per request
    shed_priority: int = 2         # rung 3: shed priority classes >= this
    breaker_cooldown_ticks: int = 3   # circuit open -> half-open probe
    breaker_min_failures: int = 1  # tile failures before a group is
                                   # a circuit-break candidate
    control_timeout: float = 60.0
    probe_seed: int = 0xF1EE7      # golden-input seed (same as fleet's)


#: (rung, name, what degrades) — the ladder, worst rung last.
RUNGS = (
    (0, "normal", "full service"),
    (1, "narrow_batch", "batch coalescing window -> 1"),
    (2, "clamp_decode", "LM max_new clamped"),
    (3, "shed_low_priority", "low-priority admissions shed (brownout)"),
    (4, "circuit_break", "failing tile group circuit-broken"),
)
MAX_RUNG = RUNGS[-1][0]


class CircuitBreaker:
    """Open / half-open / closed over ONE tile group.

    ``trip`` kills the group through the existing quarantine path (the
    partition failover already routes around dead groups, so no request
    is dropped). After ``breaker_cooldown_ticks`` the breaker goes
    half-open: the group is revived (CRC re-validation against RIMFS
    included) and probed by running golden inputs through the full
    serving path twice — once while the group is still excluded (the
    known-good survivors' answer) and once with it back in rotation. A
    bit-identical answer closes the circuit; anything else re-kills the
    group and restarts the cooldown."""

    def __init__(self, server, cfg: OverloadConfig):
        self.server = server
        self.cfg = cfg
        self.state = "closed"
        self.gid: Optional[int] = None
        self._cooldown = 0
        self.stats = {"trips": 0, "probes": 0, "closes": 0}

    def trip(self, gid: int) -> bool:
        server = self.server
        mesh = server.mesh
        if self.state != "closed" or mesh is None or not mesh.alive(gid):
            return False

        def isolate():
            mesh.kill(gid)
            return True

        server.run_on_dispatcher(isolate, timeout=self.cfg.control_timeout)
        self.state = "open"
        self.gid = gid
        self._cooldown = self.cfg.breaker_cooldown_ticks
        self.stats["trips"] += 1
        server.platform.post("circuit_open", {"group": gid})
        return True

    def tick(self) -> None:
        if self.state != "open":
            return
        self._cooldown -= 1
        if self._cooldown <= 0:
            self.probe()

    def probe(self) -> bool:
        """Half-open: revive + golden-probe the quarantined group."""
        server, gid = self.server, self.gid
        mesh = server.mesh
        if mesh is None or gid is None:
            self.state = "closed"
            return True
        self.state = "half_open"
        self.stats["probes"] += 1
        golden = fleet_mod.golden_inputs(server.platform.program,
                                         seed=self.cfg.probe_seed)
        timeout = self.cfg.control_timeout
        try:
            # reference answer from the SURVIVORS (gid still excluded)
            ref = server.run_on_dispatcher(lambda: server._infer(golden),
                                           timeout=timeout)

            def revive():
                mesh.revive(gid, server.platform.rimfs)
                return True

            server.run_on_dispatcher(revive, timeout=timeout)
            probe = server.run_on_dispatcher(lambda: server._infer(golden),
                                             timeout=timeout)
            ok = set(probe) == set(ref) and all(
                np.array_equal(probe[k], ref[k]) for k in ref)
        except Exception:
            ok = False
        if ok:
            self.state = "closed"
            self.gid = None
            self.stats["closes"] += 1
            server.platform.post("circuit_closed", {"group": gid})
            # the revived group answered correctly; its name is live again
            server.platform.heartbeats.beat(f"tile{gid}", 0)
            return True
        # probe failed: back to quarantine, fresh cooldown
        if mesh.alive(gid):
            def isolate():
                mesh.kill(gid)
                return True
            try:
                server.run_on_dispatcher(isolate, timeout=timeout)
            except Exception:
                pass
        self.state = "open"
        self._cooldown = self.cfg.breaker_cooldown_ticks
        server.platform.post("circuit_open",
                             {"group": gid, "reason": "probe failed"})
        return False


class BrownoutController:
    """Observe -> decide -> degrade/recover, one rung per decision.

    Owns NO request-path state: every service-level change rides
    ``run_on_dispatcher`` so it lands atomically between requests. Can
    be stepped manually (``tick``) for deterministic tests or run on a
    background thread (``start``/``stop``)."""

    EVENTS = ("brownout_rung", "brownout_shed", "circuit_open",
              "circuit_closed")

    def __init__(self, server, config: Optional[OverloadConfig] = None):
        self.server = server
        self.cfg = config or OverloadConfig()
        self.rung = 0
        self.events: list = []
        self.history: list = []
        self.breaker = CircuitBreaker(server, self.cfg)
        self._hot_streak = 0
        self._cool_streak = 0
        self._saved_window = server.batch_window
        self._wait_seen = server._loop.queue_wait.count()
        self._last = {"shed": self._shed_total(),
                      "served": self._served_total()}
        self._shed_mark = self._shed_total()   # brownout_shed accounting
        self._fail_counts: dict = {}           # gid -> tile failures seen
        self._lock = threading.RLock()
        self._thread: Optional[threading.Thread] = None
        self._stopev = threading.Event()
        for kind in self.EVENTS:
            server.platform.events.register(
                kind, (lambda k: lambda p: self.events.append((k, p)))(kind))
        server.platform.events.register("tile_failure", self._on_failure)
        server.platform.events.register("watchdog_preempt", self._on_failure)

    def _on_failure(self, payload: dict) -> None:
        gid = payload.get("group")
        if gid is not None:
            self._fail_counts[gid] = self._fail_counts.get(gid, 0) + 1

    # ----------------------------------------------------------- telemetry
    def _shed_total(self) -> int:
        s = self.server.scheduler.shed_count
        eng = getattr(self.server, "engine", None)
        if eng is not None and eng.scheduler is not None:
            s += eng.scheduler.shed_count
        return s

    def _served_total(self) -> int:
        return self.server.platform.telemetry.count()

    def observe(self) -> dict:
        """Windowed pressure signals: queue-wait p99 over ONLY the
        dispatches since the previous tick, miss rate over the same
        interval, and current backlog depth."""
        loop = self.server._loop
        qw = loop.queue_wait
        n = qw.count()
        win = qw.summary(warmup=self._wait_seen)
        self._wait_seen = n
        shed, served = self._shed_total(), self._served_total()
        shed_d = shed - self._last["shed"]
        served_d = served - self._last["served"]
        self._last = {"shed": shed, "served": served}
        depth = loop.depth() + self.server.scheduler.pending()
        return {"p99": win.get("p99"), "window": win.get("n", 0),
                "shed_delta": shed_d, "served_delta": served_d,
                "miss_rate": shed_d / max(1, shed_d + served_d),
                "depth": depth}

    # -------------------------------------------------------------- policy
    def decide(self, obs: dict) -> int:
        """-1 (recover a rung), 0 (hold), +1 (degrade a rung)."""
        cfg = self.cfg
        p99 = obs["p99"]
        hot = (p99 is not None and obs["window"] >= cfg.min_window
               and p99 > cfg.p99_high) or \
            (obs["shed_delta"] + obs["served_delta"] >= cfg.min_window
             and obs["miss_rate"] > cfg.miss_rate_high)
        cool = (p99 is None or p99 < cfg.recover_margin * cfg.p99_high) \
            and obs["miss_rate"] <= cfg.miss_rate_high / 2 \
            and obs["depth"] <= 1
        if hot:
            self._hot_streak += 1
            self._cool_streak = 0
        elif cool:
            self._cool_streak += 1
            self._hot_streak = 0
        else:
            self._hot_streak = self._cool_streak = 0
        if self._hot_streak >= cfg.escalate_ticks and self.rung < MAX_RUNG:
            self._hot_streak = 0
            return 1
        if self._cool_streak >= cfg.recover_ticks and self.rung > 0:
            self._cool_streak = 0
            return -1
        return 0

    def tick(self) -> dict:
        with self._lock:
            obs = self.observe()
            self.breaker.tick()
            delta = self.decide(obs)
            report = {"obs": obs, "rung": self.rung, "delta": delta,
                      "breaker": self.breaker.state}
            if delta:
                self.set_rung(self.rung + delta,
                              reason="pressure" if delta > 0 else "recovery")
                report["rung"] = self.rung
            # honest accounting: admissions shed while the ladder is
            # engaged surface as brownout_shed telemetry
            if self.rung >= 3:
                shed_now = self._shed_total()
                d = shed_now - self._shed_mark
                if d > 0:
                    self.server.platform.post("brownout_shed", {"n": d})
            self._shed_mark = self._shed_total()
            self.history.append(report)
            return report

    # ------------------------------------------------------------- actions
    def _worst_failing_group(self) -> Optional[int]:
        mesh = self.server.mesh
        if mesh is None:
            return None
        cands = {g: n for g, n in self._fail_counts.items()
                 if n >= self.cfg.breaker_min_failures
                 and 0 <= g < mesh.n_groups and mesh.alive(g)}
        return max(cands, key=cands.get) if cands else None

    def set_rung(self, target: int, reason: str = "manual") -> dict:
        """Apply every service-level change for ``target`` as ONE
        dispatcher control op — the ladder state a request observes is
        always a consistent rung, never a half-applied mix."""
        with self._lock:
            cfg = self.cfg
            target = max(0, min(MAX_RUNG, int(target)))
            prev = self.rung
            server = self.server

            def apply():
                server.batch_window = 1 if target >= 1 \
                    else self._saved_window
                server.max_new_clamp = cfg.max_new_clamp \
                    if target >= 2 else None
                ceiling = cfg.shed_priority if target >= 3 else None
                server.scheduler.priority_ceiling = ceiling
                eng = getattr(server, "engine", None)
                if eng is not None and eng.scheduler is not None:
                    eng.scheduler.priority_ceiling = ceiling
                return True

            server.run_on_dispatcher(apply,
                                     timeout=cfg.control_timeout)
            tripped = None
            if target >= 4 and self.breaker.state == "closed":
                gid = self._worst_failing_group()
                if gid is not None and self.breaker.trip(gid):
                    tripped = gid
                    self._fail_counts.pop(gid, None)
            self.rung = target
            report = {"from": prev, "to": target, "reason": reason,
                      "name": RUNGS[target][1], "tripped": tripped}
            if target != prev:
                server.platform.post("brownout_rung", report)
            return report

    # ----------------------------------------------------------- lifecycle
    def start(self, interval: float = 0.1) -> None:
        if self._thread is not None:
            raise RuntimeError("brown-out controller already running")
        self._stopev.clear()

        def loop():
            while not self._stopev.wait(interval):
                try:
                    self.tick()
                except Exception:
                    pass          # a bad tick must not kill the loop

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="brownout-controller")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stopev.set()
        self._thread.join(timeout=10)
        self._thread = None

    def summary(self) -> dict:
        import collections
        kinds = collections.Counter(k for k, _ in self.events)
        return {"rung": self.rung, "name": RUNGS[self.rung][1],
                "ticks": len(self.history), "events": dict(kinds),
                "breaker": {"state": self.breaker.state,
                            "gid": self.breaker.gid,
                            **self.breaker.stats}}
