"""CRC-32-framed wire protocol (the paper's lwIP + CRC-32 message layer).

Frame layout (little-endian):

  [0:4]  magic  b"AEGW"
  [4:5]  type   (Msg enum)
  [5:9]  payload length
  [9:..] payload
  [-4:]  CRC-32 (IEEE 0x04C11DB7 == zlib.crc32) over magic..payload

The paper's design note applies verbatim: CRC detects accidental corruption;
confidentiality/authentication are explicitly out of scope (terminate TLS at
a gateway for untrusted networks — §5.5).
"""
from __future__ import annotations

import enum
import io
import json
import socket
import struct
import zlib
from typing import Any, Optional

import numpy as np

MAGIC = b"AEGW"
HEADER = struct.Struct("<4sBI")


class Msg(enum.IntEnum):
    PROVISION = 1          # payload: RIMFS image (+ program blob)
    INFER_REQUEST = 2      # payload: npz tensors
    INFER_RESPONSE = 3
    TELEMETRY = 4          # payload: json
    HEARTBEAT = 5
    ERROR = 6
    SHUTDOWN = 7


class ProtocolError(ValueError):
    pass


def encode_frame(kind: Msg, payload: bytes) -> bytes:
    head = HEADER.pack(MAGIC, int(kind), len(payload))
    crc = zlib.crc32(head + payload) & 0xFFFFFFFF
    return head + payload + struct.pack("<I", crc)


def decode_frame(data: bytes) -> tuple:
    magic, kind, n = HEADER.unpack_from(data, 0)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r}")
    end = HEADER.size + n
    payload = data[HEADER.size:end]
    (crc,) = struct.unpack_from("<I", data, end)
    if crc != (zlib.crc32(data[:end]) & 0xFFFFFFFF):
        raise ProtocolError("frame CRC mismatch")
    return Msg(kind), payload


# --------------------------------------------------------------- tensor io
def pack_tensors(tensors: dict) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in tensors.items()})
    return buf.getvalue()


def unpack_tensors(payload: bytes) -> dict:
    with np.load(io.BytesIO(payload)) as z:
        return {k: z[k] for k in z.files}


def pack_json(obj: Any) -> bytes:
    return json.dumps(obj).encode()


def unpack_json(payload: bytes) -> Any:
    return json.loads(payload.decode())


# --------------------------------------------------------------- socket io
def send_frame(sock: socket.socket, kind: Msg, payload: bytes) -> None:
    sock.sendall(encode_frame(kind, payload))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n > 0:
        c = sock.recv(min(n, 1 << 20))
        if not c:
            raise ConnectionError("peer closed")
        chunks.append(c)
        n -= len(c)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> tuple:
    head = _recv_exact(sock, HEADER.size)
    magic, kind, n = HEADER.unpack(head)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r}")
    rest = _recv_exact(sock, n + 4)
    payload = rest[:n]
    (crc,) = struct.unpack_from("<I", rest, n)
    if crc != (zlib.crc32(head + payload) & 0xFFFFFFFF):
        raise ProtocolError("frame CRC mismatch")
    return Msg(kind), payload
