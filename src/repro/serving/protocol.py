"""CRC-32-framed wire protocol (the paper's lwIP + CRC-32 message layer).

v1 frame layout (little-endian):

  [0:4]  magic  b"AEGW"
  [4:5]  type   (Msg enum)
  [5:9]  payload length
  [9:..] payload
  [-4:]  CRC-32 (IEEE 0x04C11DB7 == zlib.crc32) over magic..payload

v2 keeps the same magic/type/length prefix but sets bit 7 of the type
byte and inserts an 8-byte extension word after the length:

  [9:13]  request_id  (u32) — correlates pipelined requests with their
                      out-of-order responses on one connection
  [13:17] flags       (u32) — F_SHED / F_BUSY / F_DRAINING on replies
  [17:..] payload
  [-4:]   CRC-32 over everything before it

A decoder that understands v2 accepts both versions (``decode_frame_ex``
/ ``recv_frame_ex``); v1-only peers never see the version bit unless
they send it. The length field is *payload* length in both versions and
is attacker-/corruption-controlled, so every receive path enforces
``MAX_FRAME`` BEFORE allocating the payload buffer.

The paper's design note applies verbatim: CRC detects accidental corruption;
confidentiality/authentication are explicitly out of scope (terminate TLS at
a gateway for untrusted networks — §5.5).
"""
from __future__ import annotations

import enum
import io
import json
import socket
import struct
import zlib
from typing import Any, NamedTuple, Optional

import numpy as np

MAGIC = b"AEGW"
HEADER = struct.Struct("<4sBI")
EXT = struct.Struct("<II")            # v2 extension: request_id, flags
V2_BIT = 0x80                         # set on the type byte for v2 frames

#: Hard ceiling on the payload length field. A corrupted / hostile length
#: would otherwise make the receiver try to allocate up to 4 GiB before
#: the CRC ever gets a chance to reject the frame.
MAX_FRAME = 64 << 20

# Reply flags (v2 flags word).
F_SHED = 1 << 0        # request shed by the admission policy (verdict in payload)
F_BUSY = 1 << 1        # bounded dispatch queue full — backpressure, retry later
F_DRAINING = 1 << 2    # server draining after SHUTDOWN; no new work accepted
F_CANARY = 1 << 3      # response bytes produced by a canary shadow binding


class Msg(enum.IntEnum):
    PROVISION = 1          # payload: RIMFS image (+ program blob)
    INFER_REQUEST = 2      # payload: npz tensors
    INFER_RESPONSE = 3
    TELEMETRY = 4          # payload: json
    HEARTBEAT = 5
    ERROR = 6
    SHUTDOWN = 7


class ProtocolError(ValueError):
    pass


class Frame(NamedTuple):
    kind: "Msg"
    payload: bytes
    request_id: int = 0
    flags: int = 0
    version: int = 1


def _kind(raw: int) -> Msg:
    try:
        return Msg(raw & ~V2_BIT)
    except ValueError:
        raise ProtocolError(f"unknown message type {raw & ~V2_BIT}")


def _check_len(n: int, max_frame: Optional[int]) -> None:
    cap = MAX_FRAME if max_frame is None else max_frame
    if n > cap:
        raise ProtocolError(f"frame payload {n}B exceeds MAX_FRAME {cap}B")


def encode_frame(kind: Msg, payload: bytes, request_id: Optional[int] = None,
                 flags: int = 0) -> bytes:
    """v1 frame by default; passing a ``request_id`` (or flags) emits v2."""
    if request_id is None and not flags:
        head = HEADER.pack(MAGIC, int(kind), len(payload))
    else:
        head = HEADER.pack(MAGIC, int(kind) | V2_BIT, len(payload)) + \
            EXT.pack(request_id or 0, flags)
    crc = zlib.crc32(head + payload) & 0xFFFFFFFF
    return head + payload + struct.pack("<I", crc)


def decode_frame_ex(data: bytes, max_frame: Optional[int] = None) -> Frame:
    """Decode one frame (either version) from a complete byte string."""
    if len(data) < HEADER.size:
        raise ProtocolError(f"truncated frame ({len(data)}B)")
    magic, raw_kind, n = HEADER.unpack_from(data, 0)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r}")
    _check_len(n, max_frame)
    kind = _kind(raw_kind)
    rid, flags, version, off = 0, 0, 1, HEADER.size
    if raw_kind & V2_BIT:
        version = 2
        if len(data) < off + EXT.size:
            raise ProtocolError("truncated v2 extension")
        rid, flags = EXT.unpack_from(data, off)
        off += EXT.size
    end = off + n
    if len(data) < end + 4:
        raise ProtocolError(f"truncated frame body ({len(data)}B < {end + 4}B)")
    payload = data[off:end]
    (crc,) = struct.unpack_from("<I", data, end)
    if crc != (zlib.crc32(data[:end]) & 0xFFFFFFFF):
        raise ProtocolError("frame CRC mismatch")
    return Frame(kind, payload, rid, flags, version)


def decode_frame(data: bytes, max_frame: Optional[int] = None) -> tuple:
    f = decode_frame_ex(data, max_frame=max_frame)
    return f.kind, f.payload


# --------------------------------------------------------------- tensor io
def pack_tensors(tensors: dict) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in tensors.items()})
    return buf.getvalue()


def unpack_tensors(payload: bytes) -> dict:
    with np.load(io.BytesIO(payload)) as z:
        return {k: z[k] for k in z.files}


def pack_json(obj: Any) -> bytes:
    return json.dumps(obj).encode()


def unpack_json(payload: bytes) -> Any:
    return json.loads(payload.decode())


# --------------------------------------------------------------- socket io
def send_frame(sock: socket.socket, kind: Msg, payload: bytes,
               request_id: Optional[int] = None, flags: int = 0) -> None:
    sock.sendall(encode_frame(kind, payload, request_id=request_id,
                              flags=flags))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n > 0:
        c = sock.recv(min(n, 1 << 20))
        if not c:
            raise ConnectionError("peer closed")
        chunks.append(c)
        n -= len(c)
    return b"".join(chunks)


def recv_frame_ex(sock: socket.socket,
                  max_frame: Optional[int] = None) -> Frame:
    """Receive one frame (either version). The length cap is enforced
    before the payload is read — a hostile length field never triggers a
    multi-GiB allocation."""
    head = _recv_exact(sock, HEADER.size)
    magic, raw_kind, n = HEADER.unpack(head)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r}")
    _check_len(n, max_frame)
    kind = _kind(raw_kind)
    rid, flags, version = 0, 0, 1
    if raw_kind & V2_BIT:
        version = 2
        ext = _recv_exact(sock, EXT.size)
        rid, flags = EXT.unpack(ext)
        head += ext
    rest = _recv_exact(sock, n + 4)
    payload = rest[:n]
    (crc,) = struct.unpack_from("<I", rest, n)
    if crc != (zlib.crc32(head + payload) & 0xFFFFFFFF):
        raise ProtocolError("frame CRC mismatch")
    return Frame(kind, payload, rid, flags, version)


def recv_frame(sock: socket.socket, max_frame: Optional[int] = None) -> tuple:
    f = recv_frame_ex(sock, max_frame=max_frame)
    return f.kind, f.payload
