"""Batched LM serving engine over the RCB runtime.

The paper's execution flow (Provision -> Bind -> Dispatch -> Sync) drives LM
serving: RCTC wraps jitted prefill/decode steps as GRAPH_EXEC artifacts
("compiled ADF graph artifacts"), RIMFS holds the weights, RBL binds, and
this engine batches user requests through the fused dispatch path with a
continuous-batching slot table.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import rctc
from repro.core import rimfs as rimfs_mod
from repro.core.rhal import TileMesh
from repro.core.rtpm import Telemetry
from repro.launch.steps import make_decode_step, make_prefill_step, \
    sample_tokens
from repro.models import transformer as tf
from repro.models.common import init_params, is_spec
from repro.serving.scheduler import ScheduledRequest, split_verdict


def pack_params_image(params) -> bytes:
    """Flatten a params pytree into a RIMFS image (one file per leaf,
    checkpoint-compatible key naming)."""
    from repro.checkpoint.ckpt import _flatten
    return rimfs_mod.pack(_flatten(params))


def params_from_rimfs(cfg: ModelConfig, fs: rimfs_mod.RIMFS, driver=None):
    """Rebuild the params pytree from a mounted RIMFS image.

    With a ``driver``, leaves resolve through the image's per-driver
    residency cache (``RIMFS.resident``): the first call uploads every
    weight ONCE into the driver's arena; later calls — e.g. constructing a
    second ``ServingEngine`` over the same image — reuse the pinned device
    buffers and perform zero re-uploads (the driver's DMA counters do not
    move). Without a driver, leaves are zero-copy host views. A
    ``TileMesh`` is accepted in place of a driver: residency anchors on
    the mesh's primary (first live) tile group.
    """
    if isinstance(driver, TileMesh):
        driver = driver.primary
    specs = tf.model_specs(cfg)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec)
    resident = fs.resident(driver) if driver is not None else None
    out = []
    for path, spec in leaves:
        key = jax.tree_util.keystr(path)
        buf = resident[key] if resident is not None else fs.read(key)
        out.append(jnp.asarray(buf))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    priority: int = 1             # admission priority (lower = more urgent)
    deadline: Optional[float] = None   # absolute monotonic seconds
    shed: bool = False            # shed by the admission policy
    verdict: str = ""             # admission outcome ("admitted"/"shed: ...")
    verdict_kind: str = ""        # machine-readable shed kind
                                  # (scheduler.VERDICT_KINDS)


class EngineBase:
    """Shared continuous-batching scaffolding: submission queue /
    scheduler admission (with an optional per-request feasibility veto),
    token sampling, and the drain loop. Subclasses own the cache layout
    (dense slots vs paged block tables) and the prefill/decode steps."""

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 256, greedy: bool = True, scheduler=None,
                 mesh: Optional[TileMesh] = None, temperature: float = 1.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.greedy = greedy
        self.temperature = temperature
        self.scheduler = scheduler      # optional DeadlineScheduler
        self.mesh = mesh                # optional TileMesh (multi-tile)
        self.telemetry = Telemetry()
        self._key = jax.random.PRNGKey(seed)
        self._slots: list[Optional[Request]] = [None] * max_batch
        self._pos = np.zeros((max_batch,), np.int32)
        self._queue: list[Request] = []

    @classmethod
    def from_rimfs(cls, cfg: ModelConfig, fs: rimfs_mod.RIMFS, driver=None,
                   **kwargs):
        """Provision an engine straight from a RIMFS weight image.

        Weights resolve through ``RIMFS.resident(driver)``: repeated
        engine construction over the same image re-binds the pinned device
        buffers instead of re-uploading (zero additional DMA). ``driver``
        may be a ``TileMesh``: weights pin into the primary tile group's
        arena, and the mesh is exposed as ``engine.mesh`` so the
        orchestration layer can drive partitioned RCB dispatch / failover
        against the same groups the weights live on."""
        if isinstance(driver, TileMesh):
            kwargs.setdefault("mesh", driver)
        return cls(cfg, params_from_rimfs(cfg, fs, driver), **kwargs)

    # ----------------------------------------------------------------- api
    def submit(self, req: Request) -> None:
        """Enqueue a request. With a scheduler attached the request routes
        through ``DeadlineScheduler.submit`` so admission (and shedding)
        happens at ``_admit`` time; without one, plain FIFO."""
        if self.scheduler is not None:
            self.scheduler.submit(ScheduledRequest(
                rid=req.rid, tokens_needed=req.max_new,
                priority=req.priority, deadline=req.deadline, payload=req))
        else:
            self._queue.append(req)

    def _pop_admitted(self, free_slots: int, feasible=None) -> list:
        """Next requests to place into free slots: scheduler admission
        (priority + EDF + shedding) when attached, FIFO otherwise.

        ``feasible``: optional resource veto (e.g. KV block budget)
        returning ``None`` to admit, a verdict string, or a
        ``(kind, message)`` tuple. A verdict sheds the request — marked
        done with the typed verdict, zero compute spent — on both the
        scheduler and the FIFO path."""
        if self.scheduler is None:
            admitted = []
            while self._queue and len(admitted) < free_slots:
                req = self._queue.pop(0)
                verdict = feasible(req) if feasible is not None else None
                if verdict:
                    kind, msg = split_verdict(verdict)
                    req.shed, req.done = True, True
                    req.verdict, req.verdict_kind = msg, kind
                    continue
                req.verdict = "admitted"
                admitted.append(req)
            return admitted
        admitted = []
        wrapped = None if feasible is None else \
            (lambda s: feasible(s.payload) if s.payload is not None else None)
        for s in self.scheduler.admit(free_slots, feasible=wrapped):
            if s.payload is not None:
                s.payload.verdict = s.verdict
                admitted.append(s.payload)
        for s in self.scheduler.drain_shed():
            # shed == done, with a caller-observable typed verdict: the
            # request never reaches a slot, so no compute is spent on it
            r = s.payload
            if r is not None:
                r.shed, r.done = True, True
                r.verdict, r.verdict_kind = s.verdict, s.verdict_kind
        return admitted

    def _sample(self, logits) -> np.ndarray:
        """(B, V) logits -> (B,) int32 next-token picks. Greedy is a pure
        argmax; otherwise temperature sampling from the engine's PRNG
        stream (one split per sampling event, so replays are
        deterministic for a fixed seed and submission order)."""
        key = None
        if not self.greedy:
            self._key, key = jax.random.split(self._key)
        return np.asarray(sample_tokens(jnp.asarray(logits), self.greedy,
                                        self.temperature, key))

    def _finish(self, slot: int, req: Request) -> bool:
        """Completion check after a decode append. ``max_new`` counts
        DECODE tokens: the prefill-sampled token rides along in
        ``out_tokens`` (so a finished request carries max_new + 1 tokens)
        but does not consume the budget."""
        return (len(req.out_tokens) - 1 >= req.max_new
                or self._pos[slot] >= self.max_seq - 1)

    def pending(self) -> int:
        """Requests waiting for a slot (wherever they queue)."""
        if self.scheduler is not None:
            return self.scheduler.pending()
        return len(self._queue)

    def step(self) -> int:
        raise NotImplementedError

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and self.pending() == 0:
                return


class ServingEngine(EngineBase):
    """Fixed-slot continuous batching (decode batch = n_slots) against a
    dense (L, B, max_seq, Hkv, D) cache — every slot holds worst-case
    sequence memory. The paged engine (serving/paged_engine.py) replaces
    the dense cache with block tables over a shared pool."""

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 256, greedy: bool = True, scheduler=None,
                 mesh: Optional[TileMesh] = None, temperature: float = 1.0,
                 seed: int = 0):
        super().__init__(cfg, params, max_batch, max_seq, greedy, scheduler,
                         mesh, temperature, seed)
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        self._cache = init_params(
            jax.random.PRNGKey(0), tf.cache_specs(cfg, max_batch, max_seq))
        # The RCB program view of this service (paper-faithful packaging).
        self.program = rctc.compile_lm_service(
            cfg, max_batch, max_seq, self._prefill, self._decode)

    def _admit(self) -> None:
        free = [i for i in range(self.max_batch) if self._slots[i] is None]
        placed = list(zip(free, self._pop_admitted(len(free))))
        if not placed:
            return
        # Batched prefill: requests admitted together prefill as ONE
        # fused dispatch per (prompt length, chunk) instead of one
        # dispatch per request — the last per-request call in the
        # serving hot path. Same-shape grouping keeps per-sample
        # numerics bit-identical to the single-prompt prefill (batching
        # a matmul/attention over a leading axis does not reorder any
        # per-sample reduction); power-of-two chunking bounds the jit
        # trace cache at O(#lengths x log2(max_batch)) batch shapes
        # instead of one trace per (length, arrival count) pair.
        by_len: dict = {}
        for i, req in placed:
            by_len.setdefault(req.prompt.shape[0], []).append((i, req))
        groups = []
        for plen, members in by_len.items():
            while members:
                k = 1 << (len(members).bit_length() - 1)   # pow2 <= len
                groups.append((plen, members[:k]))
                members = members[k:]
        for plen, group in groups:
            prompts = jnp.stack([jnp.asarray(r.prompt) for _, r in group])
            logits, cache = self._prefill(self.params,
                                          {"inputs": prompts})
            picks = self._sample(logits)
            for j, (i, req) in enumerate(group):
                self._slots[i] = req
                # splice this prompt's KV into slot i of the shared cache
                for key in self._cache:
                    c = self._cache[key]
                    src = cache[key][:, j:j + 1].astype(c.dtype)
                    if key in ("k", "v"):
                        self._cache[key] = jax.lax.dynamic_update_slice(
                            c, src, (0, i, 0, 0, 0))
                    else:                    # recurrent states (L,B,...)
                        self._cache[key] = jax.lax.dynamic_update_slice(
                            c, src, (0, i) + (0,) * (c.ndim - 2))
                self._pos[i] = plen
                req.out_tokens.append(int(picks[j]))

    def step(self) -> int:
        """One decode step across all live slots. Returns #live."""
        self._admit()
        live = [i for i, r in enumerate(self._slots) if r is not None]
        if not live:
            return 0
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i in live:
            toks[i, 0] = self._slots[i].out_tokens[-1]
        t0 = time.perf_counter()
        logits, self._cache = self._decode(
            self.params, self._cache,
            {"inputs": jnp.asarray(toks), "pos": jnp.asarray(self._pos)})
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.telemetry.record_latency(dt)
        if self.scheduler is not None:
            # feed the admission policy's EWMA with REAL decode latencies
            # (eta/shedding decisions track the measured step cost, not the
            # constructor default)
            self.scheduler.observe_step_latency(dt)
        nxt = self._sample(logits)
        for i in live:
            r = self._slots[i]
            r.out_tokens.append(int(nxt[i]))
            self._pos[i] += 1
            if self._finish(i, r):
                r.done = True
                self._slots[i] = None
        return len(live)
