"""Paged KV-cache management (vLLM-style block tables) on RBL principles.

The paper's RBL owns "dependency and buffer management: tracks intermediate
buffer usage ... maintains buffer lifetimes for efficient memory
utilization". For LM serving the scarce buffer is KV-cache memory; this
module applies the same discipline: physical cache blocks are a flat pool
(a RIMFS-like arena on device), sequences hold *symbolic* block tables, and
binding a logical token position to a physical slot is an O(1) table
lookup — sequences grow/free blocks without ever copying KV data.

Pure-JAX gather/scatter formulation: attention over a paged cache gathers
the sequence's blocks into contiguous (S, H, D) views per step via
``jnp.take`` on the pool's block axis (XLA lowers to dynamic-gather; on
TPU this is the standard paged-attention pattern the Pallas flash-decode
kernel would consume block-by-block).

Device-side addressing (ISSUE 8): the pool carries one extra physical row —
the **null block** — that never enters the free list. Block tables padded
with the null-block id are legal *device inputs*: compiled prefill/decode
programs (launch/steps.py) scatter inactive/padded lanes into the null row
and gather it back masked, so the table array itself can ride inside a
jitted program with a static width. Host-side ``append`` no longer rebuilds
the pool per token: the scatter is a single jitted, donation-annotated
update (on accelerator backends the pool buffer is updated in place).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp


class OutOfBlocksError(RuntimeError):
    """KV block pool exhausted.

    Raised by host-side ``allocate``/``_grow``. On the serving path this
    never escapes a decode step: block-aware admission (PagedServingEngine)
    consults ``free_blocks()`` *before* placing a request and converts an
    infeasible reservation into a scheduler shed verdict.
    """


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_token(k, v, blk, off, layer_k, layer_v):
    """One token's K/V for all layers into pool row ``blk`` slot ``off``.

    Donated pool arguments: XLA reuses the pool buffers instead of
    materializing a fresh (L, NB, bs, Hkv, D) copy per appended token —
    the old ``self.k = self.k.at[...].set(...)`` host loop functionally
    rebuilt the whole pool every call."""
    return (k.at[:, blk, off].set(layer_k.astype(k.dtype)),
            v.at[:, blk, off].set(layer_v.astype(v.dtype)))


@dataclasses.dataclass
class PagedKVCache:
    """Physical pool + symbolic block tables.

    Pool layout: k/v arrays (num_layers, num_blocks + 1, block_size, Hkv,
    D). Row ``num_blocks`` is the null block (write target for padded
    lanes; never allocated). A sequence's logical position t lives in
    physical slot (table[t // block_size], t % block_size).
    """
    num_layers: int
    num_blocks: int
    block_size: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "float32"

    def __post_init__(self):
        shape = (self.num_layers, self.num_blocks + 1, self.block_size,
                 self.num_kv_heads, self.head_dim)
        self.k = jnp.zeros(shape, jnp.dtype(self.dtype))
        self.v = jnp.zeros(shape, jnp.dtype(self.dtype))
        self._free: list[int] = list(range(self.num_blocks))[::-1]
        self.tables: dict[int, list[int]] = {}     # seq id -> block ids
        self.lengths: dict[int, int] = {}
        self._arena_ranges: list = []              # (arena, offset) pairs

    # ------------------------------------------------------------ accounting
    @property
    def null_block(self) -> int:
        """Physical id of the never-allocated pad/garbage row."""
        return self.num_blocks

    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for(self, seq: int) -> list:
        return list(self.tables.get(seq, ()))

    def utilization(self) -> float:
        used = self.num_blocks - len(self._free)
        return used / self.num_blocks

    def blocks_needed(self, tokens: int) -> int:
        """Blocks a ``tokens``-long sequence occupies."""
        return (tokens + self.block_size - 1) // self.block_size

    def can_admit(self, tokens: int) -> bool:
        """Would a worst-case reservation for ``tokens`` fit right now?"""
        return self.blocks_needed(tokens) <= self.free_blocks()

    def pool_bytes(self) -> int:
        return int(self.k.nbytes + self.v.nbytes)

    # ------------------------------------------------------------- lifecycle
    def allocate(self, seq: int, tokens: int = 0) -> None:
        if seq in self.tables:
            raise ValueError(f"seq {seq} already allocated")
        self.tables[seq] = []
        self.lengths[seq] = 0
        if tokens:
            try:
                self._grow(seq, tokens)
            except OutOfBlocksError:
                # failed reservations must not leak a half-grown table
                self.release(seq)
                raise

    def _grow(self, seq: int, new_tokens: int) -> None:
        need = (self.lengths[seq] + new_tokens + self.block_size - 1) \
            // self.block_size
        while len(self.tables[seq]) < need:
            if not self._free:
                raise OutOfBlocksError(
                    f"pool exhausted ({self.num_blocks} blocks)")
            self.tables[seq].append(self._free.pop())

    def advance(self, seq: int, n: int = 1) -> None:
        """Mark ``n`` tokens as written by a device-side scatter (the
        compiled prefill/decode programs own the actual pool writes; the
        host only tracks lifetimes). Grows the table if the reservation
        did not already cover the new length."""
        self._grow(seq, n)
        self.lengths[seq] += n

    def release(self, seq: int) -> int:
        """Free all blocks of a finished sequence (O(1) per block, no data
        movement — the RBL lifetime-management property)."""
        blocks = self.tables.pop(seq, [])
        self.lengths.pop(seq, None)
        self._free.extend(blocks)
        return len(blocks)

    # ------------------------------------------------- device-side addressing
    def table_array(self, seqs: Sequence[int], width: Optional[int] = None,
                    rows: Optional[int] = None) -> np.ndarray:
        """(rows, width) int32 block-table array for a batch of sequences,
        padded with the null block — the device input the compiled
        prefill/decode programs address the pool through. ``rows`` pads
        the batch axis (inactive lanes scatter into the null row)."""
        if width is None:
            width = max((len(self.tables.get(s, ())) for s in seqs),
                        default=1) or 1
        rows = len(seqs) if rows is None else rows
        out = np.full((rows, width), self.null_block, np.int32)
        for i, s in enumerate(seqs):
            t = self.tables.get(s, ())
            out[i, :len(t)] = t[:width]
        return out

    def lengths_array(self, seqs: Sequence[int],
                      rows: Optional[int] = None) -> np.ndarray:
        rows = len(seqs) if rows is None else rows
        out = np.zeros((rows,), np.int32)
        for i, s in enumerate(seqs):
            out[i] = self.lengths.get(s, 0)
        return out

    # ------------------------------------------------------ arena residency
    def register_residency(self, driver) -> int:
        """Register the pool's pages with the driver's DeviceArena so the
        residency layer (fleet reshapes, watchdog revives, arena
        telemetry) sees KV memory like any other resident buffer. Returns
        the bytes registered (0 when the driver has no arena)."""
        arena = getattr(driver, "arena", None)
        if arena is None:
            return 0
        for buf in (self.k, self.v):
            self._arena_ranges.append((arena, arena.alloc(buf.nbytes)))
        return self.pool_bytes()

    def unregister_residency(self) -> None:
        """Return the pool's arena ranges (engine close / pool teardown)."""
        ranges, self._arena_ranges = self._arena_ranges, []
        for arena, off in ranges:
            arena.free(off)

    # ------------------------------------------------------------------- io
    def append(self, seq: int, layer_k: jax.Array, layer_v: jax.Array) -> None:
        """Append one token's K/V for ALL layers.
        layer_k/v: (num_layers, Hkv, D)."""
        self._grow(seq, 1)
        t = self.lengths[seq]
        blk = self.tables[seq][t // self.block_size]
        off = t % self.block_size
        self.k, self.v = _scatter_token(
            self.k, self.v, jnp.int32(blk), jnp.int32(off),
            jnp.asarray(layer_k), jnp.asarray(layer_v))
        self.lengths[seq] = t + 1

    def gather(self, seq: int, layer: int):
        """Contiguous (len, Hkv, D) views of one sequence's K/V at a layer
        (gather over the block axis; no pool copies are retained)."""
        n = self.lengths[seq]
        if n == 0:
            # dtype-correct empties: downstream concatenation/attention on
            # a pool dtype other than float32 must not silently upcast
            empty = jnp.zeros((0, self.num_kv_heads, self.head_dim),
                              self.k.dtype)
            return empty, empty
        table = jnp.asarray(self.tables[seq], jnp.int32)
        kb = jnp.take(self.k[layer], table, axis=0)     # (blocks, bs, H, D)
        vb = jnp.take(self.v[layer], table, axis=0)
        flat_k = kb.reshape(-1, self.num_kv_heads, self.head_dim)[:n]
        flat_v = vb.reshape(-1, self.num_kv_heads, self.head_dim)[:n]
        return flat_k, flat_v


def paged_decode_attention(cache: PagedKVCache, seq: int, layer: int,
                           q: jax.Array) -> jax.Array:
    """Single-token attention against a paged sequence.
    q: (H, D) with H = G * Hkv. Returns (H, D).

    Attention over zero stored tokens has no defined value (the softmax
    normalizes an empty axis into NaNs) — that is a caller bug, surfaced
    as ``ValueError`` instead of NaN propagation."""
    if cache.lengths.get(seq, 0) == 0:
        raise ValueError(
            f"attention over zero-length sequence {seq}: prefill (or "
            f"append) must store at least one token first")
    k, v = cache.gather(seq, layer)                     # (n, Hkv, D)
    h, d = q.shape
    g = h // cache.num_kv_heads
    qg = q.reshape(cache.num_kv_heads, g, d).astype(jnp.float32)
    s = jnp.einsum("hgd,nhd->hgn", qg, k.astype(jnp.float32)) / d ** 0.5
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hgn,nhd->hgd", p, v.astype(jnp.float32))
    return o.reshape(h, d).astype(q.dtype)
