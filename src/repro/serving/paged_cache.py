"""Paged KV-cache management (vLLM-style block tables) on RBL principles.

The paper's RBL owns "dependency and buffer management: tracks intermediate
buffer usage ... maintains buffer lifetimes for efficient memory
utilization". For LM serving the scarce buffer is KV-cache memory; this
module applies the same discipline: physical cache blocks are a flat pool
(a RIMFS-like arena on device), sequences hold *symbolic* block tables, and
binding a logical token position to a physical slot is an O(1) table
lookup — sequences grow/free blocks without ever copying KV data.

Pure-JAX gather/scatter formulation: attention over a paged cache gathers
the sequence's blocks into contiguous (S, H, D) views per step via
``jnp.take`` on the pool's block axis (XLA lowers to dynamic-gather; on
TPU this is the standard paged-attention pattern the Pallas flash-decode
kernel would consume block-by-block).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp


class OutOfBlocksError(RuntimeError):
    pass


@dataclasses.dataclass
class PagedKVCache:
    """Physical pool + symbolic block tables.

    Pool layout: k/v arrays (num_layers, num_blocks, block_size, Hkv, D).
    A sequence's logical position t lives in physical slot
    (table[t // block_size], t % block_size).
    """
    num_layers: int
    num_blocks: int
    block_size: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "float32"

    def __post_init__(self):
        shape = (self.num_layers, self.num_blocks, self.block_size,
                 self.num_kv_heads, self.head_dim)
        self.k = jnp.zeros(shape, jnp.dtype(self.dtype))
        self.v = jnp.zeros(shape, jnp.dtype(self.dtype))
        self._free: list[int] = list(range(self.num_blocks))[::-1]
        self.tables: dict[int, list[int]] = {}     # seq id -> block ids
        self.lengths: dict[int, int] = {}

    # ------------------------------------------------------------ accounting
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for(self, seq: int) -> list:
        return list(self.tables.get(seq, ()))

    def utilization(self) -> float:
        used = self.num_blocks - len(self._free)
        return used / self.num_blocks

    # ------------------------------------------------------------- lifecycle
    def allocate(self, seq: int, tokens: int = 0) -> None:
        if seq in self.tables:
            raise ValueError(f"seq {seq} already allocated")
        self.tables[seq] = []
        self.lengths[seq] = 0
        if tokens:
            self._grow(seq, tokens)

    def _grow(self, seq: int, new_tokens: int) -> None:
        need = (self.lengths[seq] + new_tokens + self.block_size - 1) \
            // self.block_size
        while len(self.tables[seq]) < need:
            if not self._free:
                raise OutOfBlocksError(
                    f"pool exhausted ({self.num_blocks} blocks)")
            self.tables[seq].append(self._free.pop())

    def release(self, seq: int) -> int:
        """Free all blocks of a finished sequence (O(1) per block, no data
        movement — the RBL lifetime-management property)."""
        blocks = self.tables.pop(seq, [])
        self.lengths.pop(seq, None)
        self._free.extend(blocks)
        return len(blocks)

    # ------------------------------------------------------------------- io
    def append(self, seq: int, layer_k: jax.Array, layer_v: jax.Array) -> None:
        """Append one token's K/V for ALL layers.
        layer_k/v: (num_layers, Hkv, D)."""
        self._grow(seq, 1)
        t = self.lengths[seq]
        blk = self.tables[seq][t // self.block_size]
        off = t % self.block_size
        self.k = self.k.at[:, blk, off].set(layer_k.astype(self.k.dtype))
        self.v = self.v.at[:, blk, off].set(layer_v.astype(self.v.dtype))
        self.lengths[seq] = t + 1

    def gather(self, seq: int, layer: int):
        """Contiguous (len, Hkv, D) views of one sequence's K/V at a layer
        (gather over the block axis; no pool copies are retained)."""
        n = self.lengths[seq]
        if n == 0:
            return (jnp.zeros((0, self.num_kv_heads, self.head_dim)),) * 2
        table = jnp.asarray(self.tables[seq], jnp.int32)
        kb = jnp.take(self.k[layer], table, axis=0)     # (blocks, bs, H, D)
        vb = jnp.take(self.v[layer], table, axis=0)
        flat_k = kb.reshape(-1, self.num_kv_heads, self.head_dim)[:n]
        flat_v = vb.reshape(-1, self.num_kv_heads, self.head_dim)[:n]
        return flat_k, flat_v


def paged_decode_attention(cache: PagedKVCache, seq: int, layer: int,
                           q: jax.Array) -> jax.Array:
    """Single-token attention against a paged sequence.
    q: (H, D) with H = G * Hkv. Returns (H, D)."""
    k, v = cache.gather(seq, layer)                     # (n, Hkv, D)
    h, d = q.shape
    g = h // cache.num_kv_heads
    qg = q.reshape(cache.num_kv_heads, g, d).astype(jnp.float32)
    s = jnp.einsum("hgd,nhd->hgn", qg, k.astype(jnp.float32)) / d ** 0.5
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hgn,nhd->hgd", p, v.astype(jnp.float32))
    return o.reshape(h, d).astype(q.dtype)
