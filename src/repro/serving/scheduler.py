"""Deadline-aware request admission for the serving engine.

The paper's headline property is latency *determinism* (CV = 0.03%): worth
protecting at the scheduler level too. This admission policy orders the
queue by (priority, earliest deadline) and sheds requests whose deadline
cannot be met given the measured per-step latency — bounded-tardiness
behaviour instead of queue-length-dependent tail blowup.

Shed verdicts are *typed* (DESIGN.md §14): every refusal carries a
machine-readable ``verdict_kind`` alongside the human-readable string, so
a client can distinguish a retryable shed (``brownout``, ``out_of_blocks``,
``busy``) from a terminal one (``infeasible`` — the deadline is already
unmeetable, re-sending the same request cannot help).

``priority_ceiling`` is the brown-out ladder's priority-class shedding
rung: when set, requests whose priority is *at or past* the ceiling
(higher number = less urgent) are shed at admission with an honest
``brownout`` verdict — load is cut by class, never by silent drop.

Thread-safety: ``submit`` may be called from any producer thread
(connection handlers, client code) while a single dispatcher thread calls
``admit``/``drain_shed`` — the heap is guarded by a lock. Shed requests
are queued on the side and drained by the dispatcher, which marks their
payloads done with the shed verdict (the caller-observable outcome).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from typing import Any, Callable, Optional

# The closed verdict vocabulary (wire-visible: rides Msg.ERROR payloads).
VERDICT_KINDS = ("busy", "shed", "infeasible", "out_of_blocks", "brownout")
# Kinds a client may safely re-send: the request was refused before any
# compute (and before any sampling), so a retry cannot double-run it and
# the condition that refused it is transient.
RETRYABLE_KINDS = frozenset({"busy", "shed", "out_of_blocks", "brownout"})


@dataclasses.dataclass(order=False)
class ScheduledRequest:
    rid: int
    tokens_needed: int                  # decode steps to finish
    priority: int = 1                   # lower = more urgent
    deadline: Optional[float] = None    # absolute seconds (monotonic)
    admitted: bool = False
    shed: bool = False
    verdict: str = ""                   # admission outcome, human-readable
    verdict_kind: str = ""              # machine-readable (VERDICT_KINDS)
    payload: Any = None                 # caller's request object (e.g.
                                        # engine.Request / a reply route)


def split_verdict(verdict) -> tuple:
    """Normalize a feasibility-veto return into ``(kind, message)``.

    Vetoes may return a bare string (kind defaults to ``"shed"`` for
    back-compat) or a ``(kind, message)`` tuple from VERDICT_KINDS."""
    if isinstance(verdict, tuple):
        kind, msg = verdict
        return (kind if kind in VERDICT_KINDS else "shed"), msg
    return "shed", verdict


class DeadlineScheduler:
    def __init__(self, step_latency_estimate: float = 1e-2,
                 clock: Callable[[], float] = time.monotonic):
        self.est = step_latency_estimate
        self.clock = clock
        self._heap: list = []
        self._ctr = itertools.count()
        self._lock = threading.Lock()
        self._shed: list[ScheduledRequest] = []
        self.shed_count = 0
        self.observations = 0     # EWMA sample count (watchdog boot grace)
        # Brown-out priority-class shedding (serving/overload.py): when
        # set, priority >= ceiling is shed at admission, kind "brownout".
        self.priority_ceiling: Optional[int] = None

    # ------------------------------------------------------------------ api
    def observe_step_latency(self, seconds: float, alpha: float = 0.2):
        """EWMA of the engine's decode-step latency."""
        self.est = (1 - alpha) * self.est + alpha * seconds
        self.observations += 1

    def submit(self, req: ScheduledRequest) -> None:
        key = (req.priority,
               req.deadline if req.deadline is not None else float("inf"),
               next(self._ctr))
        with self._lock:
            heapq.heappush(self._heap, (key, req))

    def eta(self, req: ScheduledRequest, queue_depth: int) -> float:
        """Predicted completion time if admitted now."""
        return self.clock() + (req.tokens_needed + queue_depth) * self.est

    def _shed_req(self, req: ScheduledRequest, kind: str,
                  verdict: str) -> None:
        req.shed = True
        req.verdict = verdict
        req.verdict_kind = kind
        self.shed_count += 1
        self._shed.append(req)

    def admit(self, free_slots: int,
              feasible: Optional[Callable[[ScheduledRequest],
                                          Optional[Any]]] = None) -> list:
        """Pop up to `free_slots` feasible requests; shed infeasible ones.

        Returns admitted requests (priority + EDF order). Shedding happens
        at admission — before any compute is spent — keeping live-slot
        latency flat (the determinism property). Shed requests land in the
        side queue for ``drain_shed`` so the dispatcher can fail them back
        to their callers with the verdict.

        ``feasible`` lets the engine veto admission on resources the
        scheduler cannot see (KV block budget, arena headroom): it
        returns ``None`` to admit, a human-readable verdict string
        (kind defaults to ``"shed"``), or a ``(kind, message)`` tuple —
        resource exhaustion becomes a typed admission verdict instead
        of a mid-step crash.
        """
        out: list[ScheduledRequest] = []
        with self._lock:
            while self._heap and len(out) < free_slots:
                _, req = heapq.heappop(self._heap)
                ceiling = self.priority_ceiling
                if ceiling is not None and req.priority >= ceiling:
                    self._shed_req(
                        req, "brownout",
                        f"brownout: priority {req.priority} class shed "
                        f"(ceiling {ceiling})")
                    continue
                if req.deadline is not None:
                    eta = self.eta(req, len(out))
                    if eta > req.deadline:
                        self._shed_req(
                            req, "infeasible",
                            f"shed: eta {eta:.4f}s past deadline "
                            f"{req.deadline:.4f}s "
                            f"(est {self.est:.4f}s/step)")
                        continue
                if feasible is not None:
                    verdict = feasible(req)
                    if verdict:
                        kind, msg = split_verdict(verdict)
                        self._shed_req(req, kind, msg)
                        continue
                req.admitted = True
                req.verdict = "admitted"
                req.verdict_kind = ""
                out.append(req)
        return out

    def drain_shed(self) -> list:
        """Hand back (and clear) requests shed since the last drain."""
        with self._lock:
            out, self._shed = self._shed, []
        return out

    def drain_pending(self) -> list:
        """Remove and return everything still queued (forced shutdown:
        the caller owes each request an explicit refusal)."""
        with self._lock:
            out = [req for _, req in self._heap]
            self._heap.clear()
        return out

    def pending(self) -> int:
        return len(self._heap)
