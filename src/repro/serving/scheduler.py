"""Deadline-aware request admission for the serving engine.

The paper's headline property is latency *determinism* (CV = 0.03%): worth
protecting at the scheduler level too. This admission policy orders the
queue by (priority, earliest deadline) and sheds requests whose deadline
cannot be met given the measured per-step latency — bounded-tardiness
behaviour instead of queue-length-dependent tail blowup.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Callable, Optional


@dataclasses.dataclass(order=False)
class ScheduledRequest:
    rid: int
    tokens_needed: int                  # decode steps to finish
    priority: int = 1                   # lower = more urgent
    deadline: Optional[float] = None    # absolute seconds (monotonic)
    admitted: bool = False
    shed: bool = False


class DeadlineScheduler:
    def __init__(self, step_latency_estimate: float = 1e-2,
                 clock: Callable[[], float] = time.monotonic):
        self.est = step_latency_estimate
        self.clock = clock
        self._heap: list = []
        self._ctr = itertools.count()
        self.shed_count = 0

    # ------------------------------------------------------------------ api
    def observe_step_latency(self, seconds: float, alpha: float = 0.2):
        """EWMA of the engine's decode-step latency."""
        self.est = (1 - alpha) * self.est + alpha * seconds

    def submit(self, req: ScheduledRequest) -> None:
        key = (req.priority,
               req.deadline if req.deadline is not None else float("inf"),
               next(self._ctr))
        heapq.heappush(self._heap, (key, req))

    def eta(self, req: ScheduledRequest, queue_depth: int) -> float:
        """Predicted completion time if admitted now."""
        return self.clock() + (req.tokens_needed + queue_depth) * self.est

    def admit(self, free_slots: int) -> list:
        """Pop up to `free_slots` feasible requests; shed infeasible ones.

        Returns admitted requests (priority + EDF order). Shedding happens
        at admission — before any compute is spent — keeping live-slot
        latency flat (the determinism property).
        """
        out: list[ScheduledRequest] = []
        depth = len(self._heap)
        while self._heap and len(out) < free_slots:
            _, req = heapq.heappop(self._heap)
            if req.deadline is not None and \
                    self.eta(req, len(out)) > req.deadline:
                req.shed = True
                self.shed_count += 1
                continue
            req.admitted = True
            out.append(req)
        return out

    def pending(self) -> int:
        return len(self._heap)
