"""Deadline-aware request admission for the serving engine.

The paper's headline property is latency *determinism* (CV = 0.03%): worth
protecting at the scheduler level too. This admission policy orders the
queue by (priority, earliest deadline) and sheds requests whose deadline
cannot be met given the measured per-step latency — bounded-tardiness
behaviour instead of queue-length-dependent tail blowup.

Thread-safety: ``submit`` may be called from any producer thread
(connection handlers, client code) while a single dispatcher thread calls
``admit``/``drain_shed`` — the heap is guarded by a lock. Shed requests
are queued on the side and drained by the dispatcher, which marks their
payloads done with the shed verdict (the caller-observable outcome).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from typing import Any, Callable, Optional


@dataclasses.dataclass(order=False)
class ScheduledRequest:
    rid: int
    tokens_needed: int                  # decode steps to finish
    priority: int = 1                   # lower = more urgent
    deadline: Optional[float] = None    # absolute seconds (monotonic)
    admitted: bool = False
    shed: bool = False
    verdict: str = ""                   # admission outcome, human-readable
    payload: Any = None                 # caller's request object (e.g.
                                        # engine.Request / a reply route)


class DeadlineScheduler:
    def __init__(self, step_latency_estimate: float = 1e-2,
                 clock: Callable[[], float] = time.monotonic):
        self.est = step_latency_estimate
        self.clock = clock
        self._heap: list = []
        self._ctr = itertools.count()
        self._lock = threading.Lock()
        self._shed: list[ScheduledRequest] = []
        self.shed_count = 0
        self.observations = 0     # EWMA sample count (watchdog boot grace)

    # ------------------------------------------------------------------ api
    def observe_step_latency(self, seconds: float, alpha: float = 0.2):
        """EWMA of the engine's decode-step latency."""
        self.est = (1 - alpha) * self.est + alpha * seconds
        self.observations += 1

    def submit(self, req: ScheduledRequest) -> None:
        key = (req.priority,
               req.deadline if req.deadline is not None else float("inf"),
               next(self._ctr))
        with self._lock:
            heapq.heappush(self._heap, (key, req))

    def eta(self, req: ScheduledRequest, queue_depth: int) -> float:
        """Predicted completion time if admitted now."""
        return self.clock() + (req.tokens_needed + queue_depth) * self.est

    def admit(self, free_slots: int,
              feasible: Optional[Callable[[ScheduledRequest],
                                          Optional[str]]] = None) -> list:
        """Pop up to `free_slots` feasible requests; shed infeasible ones.

        Returns admitted requests (priority + EDF order). Shedding happens
        at admission — before any compute is spent — keeping live-slot
        latency flat (the determinism property). Shed requests land in the
        side queue for ``drain_shed`` so the dispatcher can fail them back
        to their callers with the verdict.

        ``feasible`` lets the engine veto admission on resources the
        scheduler cannot see (KV block budget, arena headroom): it
        returns ``None`` to admit or a human-readable verdict string to
        shed — resource exhaustion becomes an admission verdict instead
        of a mid-step crash.
        """
        out: list[ScheduledRequest] = []
        with self._lock:
            while self._heap and len(out) < free_slots:
                _, req = heapq.heappop(self._heap)
                if req.deadline is not None:
                    eta = self.eta(req, len(out))
                    if eta > req.deadline:
                        req.shed = True
                        req.verdict = (f"shed: eta {eta:.4f}s past deadline "
                                       f"{req.deadline:.4f}s "
                                       f"(est {self.est:.4f}s/step)")
                        self.shed_count += 1
                        self._shed.append(req)
                        continue
                if feasible is not None:
                    verdict = feasible(req)
                    if verdict:
                        req.shed = True
                        req.verdict = verdict
                        self.shed_count += 1
                        self._shed.append(req)
                        continue
                req.admitted = True
                req.verdict = "admitted"
                out.append(req)
        return out

    def drain_shed(self) -> list:
        """Hand back (and clear) requests shed since the last drain."""
        with self._lock:
            out, self._shed = self._shed, []
        return out

    def drain_pending(self) -> list:
        """Remove and return everything still queued (forced shutdown:
        the caller owes each request an explicit refusal)."""
        with self._lock:
            out = [req for _, req in self._heap]
            self._heap.clear()
        return out

    def pending(self) -> int:
        return len(self._heap)
