"""Network-attached inference service (RTPM host-connectivity role).

A socket server speaking the CRC-framed protocol (v1 + v2). The v2 frame
extension (per-frame ``request_id`` + flags) lets one connection pipeline
many INFER_REQUESTs and receive the responses out of order.

Concurrency model — **all device state behind one thread**: connection
handler threads only *parse* frames and enqueue work; a single dispatcher
thread (an ``rtpm.ServiceLoop`` worker, heartbeat-monitored like any tile
worker) owns the ``Platform``, the ``Executor``, the bound program, the
optional ``ServingEngine`` and the optional ``TileMesh``. Handler-side
shared-state races are eliminated by ownership, not by locks.

Flow per request:

  handler thread:  recv_frame -> parse npz + admission metadata
                   -> plain RCB INFER: ScheduledRequest into the
                      DeadlineScheduler (deadline anchored HERE, so queue
                      wait counts against it) + a dispatcher kick;
                      admission-cap overflow -> immediate ERROR/F_BUSY
                   -> everything else: ServiceLoop.submit
                      (queue full -> immediate ERROR/F_BUSY)
  dispatcher:      drains the scheduler through admit(1) in priority/EDF
                   order -> shed? ERROR/F_SHED with the verdict, before
                   any compute -> else linked Executor path, or
                   partitioned over a TileMesh when one is attached;
                   LM prompts go to ServingEngine continuous batching
                   (pumped between queue pops via the loop's on_idle
                   hook; replies routed back by request id)
  SHUTDOWN:        graceful drain — queued work is answered, then stop.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
import select
import socket
import struct
import threading
import time
from typing import Any, Optional

import numpy as np

from repro.core import linker as linker_mod
from repro.core.executor import Executor
from repro.core.integrity import IntegrityError
from repro.core.rhal import TileFailure
from repro.core.rtpm import Platform, ServiceLoop
from repro.serving import protocol as proto
from repro.serving.scheduler import (RETRYABLE_KINDS, DeadlineScheduler,
                                     ScheduledRequest)


class ServerBusy(RuntimeError):
    """Reply carried F_BUSY/F_DRAINING: backpressure, retry later.

    ``kind`` / ``retry_after_ms`` mirror the reply payload when the
    server sent a structured refusal (v2 typed verdicts)."""
    kind: str = "busy"
    retry_after_ms: Optional[float] = None
    retryable: bool = True


class RequestShed(RuntimeError):
    """Reply carried F_SHED: admission policy shed the request.

    ``kind`` is the machine-readable verdict class (busy / shed /
    infeasible / out_of_blocks / brownout); ``retryable`` is False for
    terminal verdicts (an infeasible deadline, or an LM request that
    already sampled tokens and is no longer idempotent)."""
    kind: str = "shed"
    retry_after_ms: Optional[float] = None
    retryable: bool = True


class _Route:
    """Reply path to one connection: socket + send lock (the dispatcher
    and the connection's handler thread may both write to it).

    ``SO_SNDTIMEO`` bounds how long a non-reading client can stall the
    dispatcher — on timeout the route dies and the peer is on its own,
    instead of head-of-line blocking every other connection. The kernel
    option only affects sends, so the handler's blocking recv on the same
    socket is untouched (``settimeout`` would flip the shared file
    description to non-blocking and break it)."""

    def __init__(self, conn: socket.socket, send_timeout: float = 30.0):
        self.conn = conn
        if send_timeout:
            sec = int(send_timeout)
            usec = int((send_timeout - sec) * 1e6)
            conn.setsockopt(socket.SOL_SOCKET, socket.SO_SNDTIMEO,
                            struct.pack("ll", sec, usec))
        self.lock = threading.Lock()
        self.alive = True
        self._finals: dict = {}            # id(token) -> token (reply-once)
        self._finals_lock = threading.Lock()

    def send_final(self, token: Any, kind: proto.Msg, payload: bytes,
                   rid: int = 0, version: int = 1, flags: int = 0) -> bool:
        """Exactly-once terminal reply for ``token`` (the request object).

        A watchdog preemption racing ``close(timeout=)`` can leave two
        parties believing they own the reply — the unwedged dispatcher
        finishing late and the drop path refusing the in-flight item.
        Whichever calls first wins; the loser's send is a silent no-op,
        so a request id is NEVER answered twice. Tokens are held by
        strong reference (id() reuse after gc would break the guard)."""
        with self._finals_lock:
            if id(token) in self._finals:
                return False
            self._finals[id(token)] = token
        return self.send(kind, payload, rid=rid, version=version,
                         flags=flags)

    def send(self, kind: proto.Msg, payload: bytes, rid: int = 0,
             version: int = 1, flags: int = 0) -> bool:
        if not self.alive:
            return False
        try:
            with self.lock:
                if version >= 2:
                    proto.send_frame(self.conn, kind, payload,
                                     request_id=rid, flags=flags)
                else:
                    proto.send_frame(self.conn, kind, payload)
            return True
        except (OSError, ValueError):
            self.alive = False
            # tear the connection down rather than leaving the peer
            # blocked on a truncated frame (and the handler feeding more
            # work to a route that can no longer answer)
            try:
                self.conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            return False

    def close(self) -> None:
        """Retire the route (the handler's ``with conn`` owns the socket)."""
        self.alive = False


@dataclasses.dataclass
class _Work:
    frame: Optional[proto.Frame]        # None == dispatcher kick
    route: Optional[_Route]
    tensors: Optional[dict] = None      # parsed npz (INFER, LM path)
    meta: Optional[dict] = None         # admission metadata (LM path)
    control: Optional[Any] = None       # fleet control op (callable): runs
                                        # ON the dispatcher thread, between
                                        # requests — the natural atomic
                                        # flip point for mesh/binding swaps


_KICK = _Work(frame=None, route=None)   # wake the dispatcher to drain


class InferenceServer:
    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 artifacts: Optional[dict] = None, engine=None, mesh=None,
                 scheduler: Optional[DeadlineScheduler] = None,
                 max_queue: int = 128, max_frame: int = proto.MAX_FRAME,
                 send_timeout: float = 30.0, batch_window: int = 8,
                 watchdog: bool = True, watchdog_slack: float = 16.0,
                 watchdog_floor: float = 2.0, watchdog_poll: float = 0.02):
        self.platform = Platform()
        self.executor = Executor(rtpm=self.platform)
        self.artifacts = artifacts or {}
        self.engine = engine            # optional ServingEngine (LM path)
        self.mesh = mesh                # optional TileMesh (partitioned path)
        # NOTE: the plain-RCB path and the engine each get their OWN
        # scheduler — a shared heap would let admit(1) pop the other
        # path's entries (different payload shapes, misrouted replies)
        self.scheduler = scheduler or DeadlineScheduler()
        if engine is not None and engine.scheduler is None:
            engine.scheduler = DeadlineScheduler()
        self.max_frame = max_frame
        self.max_queue = max_queue
        self.send_timeout = send_timeout
        # Dispatcher request coalescing (DESIGN.md §9): up to this many
        # compatible backlogged plain-RCB requests dispatch as ONE
        # batched execution. 1 disables coalescing. The window never
        # delays a solo request — it only widens over work that is
        # ALREADY queued when the EDF head is popped.
        self.batch_window = max(1, int(batch_window))
        self.batched_stats = {"dispatches": 0, "requests": 0,
                              "max_batch": 0}
        # Canary A/B state (core.fleet.CanaryState), installed/cleared by
        # the FleetController via control ops — dispatcher-owned, so the
        # request path reads it without locks.
        self.canary = None
        # Brown-out rung 2 (serving.overload): admission-time clamp on LM
        # max_new; None = no clamp. Dispatcher-owned like batch_window.
        self.max_new_clamp: Optional[int] = None
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.address = self._sock.getsockname()
        self._bound = None
        self._inflight: dict = {}       # iid -> (Request, _Route, rid, ver)
        self._iid = itertools.count(1)
        self._stop = threading.Event()
        self._stop_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        # Execution watchdog policy: per-dispatch budget = scheduler
        # EWMA × slack (floored — cold caches and compile stalls must
        # not read as hangs), with a boot grace until the first EWMA
        # observation. Only plain-RCB dispatches are watched; PROVISION
        # / control ops / LM pumping have no defensible deadline.
        self.watchdog_slack = watchdog_slack
        self.watchdog_floor = watchdog_floor
        self._executing: Any = None     # in-flight ScheduledRequest (or run)
        # the dispatcher: the ONE thread that touches device state
        self._loop = ServiceLoop(
            self.platform, self._dispatch_one,
            name="dispatcher", max_queue=max_queue,
            on_idle=self._on_idle, on_drop=self._drop_work,
            watchdog_budget=self._watchdog_budget if watchdog else None,
            on_hang=self._preempt_hung if watchdog else None,
            watchdog_poll=watchdog_poll)

    # ----------------------------------------------------------- lifecycle
    def start(self) -> tuple:
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()
        return self.address

    def stop(self, drain: bool = True) -> None:
        with self._stop_lock:
            if not self._stop.is_set():
                self._stop.set()
                try:
                    # unblock accept()
                    socket.create_connection(self.address, timeout=1).close()
                except OSError:
                    pass
                self._loop.close(drain=drain)
                # every accepted request still gets an explicit refusal:
                # a forced stop leaves the whole backlog, a graceful one
                # only stragglers that raced the dispatcher's exit
                payload = proto.pack_json({"error": "draining"})
                for s in self.scheduler.drain_pending():
                    r, srid, sver, _ = s.payload
                    r.send(proto.Msg.ERROR, payload, rid=srid,
                           flags=proto.F_DRAINING, version=sver)
                if not self._loop.alive():
                    # only touch dispatcher-owned state once the worker is
                    # really gone (a wedged worker may still resume)
                    for req, route, rid, ver in self._inflight.values():
                        route.send(proto.Msg.ERROR, payload, rid=rid,
                                   flags=proto.F_DRAINING, version=ver)
                    self._inflight.clear()
                self._sock.close()
        if self._thread and self._thread is not threading.current_thread():
            self._thread.join(timeout=5)

    # ------------------------------------------------------------- serving
    def _serve(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            if self._stop.is_set():
                conn.close()
                return
            t = threading.Thread(target=self._handle, args=(conn,),
                                 daemon=True)
            t.start()

    def _handle(self, conn: socket.socket) -> None:
        """Per-connection frame pump: parse + enqueue ONLY — device state
        is never touched from here."""
        route = _Route(conn, send_timeout=self.send_timeout)
        with conn:
            try:
                self._pump_frames(conn, route)
            finally:
                route.close()

    def _pump_frames(self, conn: socket.socket, route: _Route) -> None:
        while not self._stop.is_set():
            try:
                frame = proto.recv_frame_ex(conn, max_frame=self.max_frame)
            except (ConnectionError, OSError):
                return
            except proto.ProtocolError as e:
                # malformed frame mid-stream: report + close cleanly
                # (previously this escaped the guard and silently killed
                # the handler thread). Sent as a v2 frame with the
                # reserved id 0 so pipelined waiters don't mistake the
                # connection-level error for their own reply.
                route.send(proto.Msg.ERROR,
                           proto.pack_json({"error": f"protocol: {e}"}),
                           rid=0, version=2)
                return
            try:
                if frame.kind == proto.Msg.HEARTBEAT:
                    self.platform.heartbeats.beat(
                        proto.unpack_json(frame.payload).get("worker", "?"))
                elif frame.kind == proto.Msg.SHUTDOWN:
                    route.send(proto.Msg.TELEMETRY,
                               proto.pack_json({"status": "draining"}),
                               rid=frame.request_id, version=frame.version)
                    self.stop(drain=True)       # graceful: queued work runs
                    return
                elif frame.kind == proto.Msg.INFER_REQUEST:
                    self._enqueue_infer(frame, route)
                elif not self._loop.submit(_Work(frame, route)):
                    flags = proto.F_DRAINING if self._stop.is_set() \
                        else proto.F_BUSY
                    route.send(
                        proto.Msg.ERROR,
                        self._busy_payload("busy: dispatch queue full",
                                           pending=self._loop.depth()),
                        rid=frame.request_id, flags=flags,
                        version=frame.version)
            except Exception as e:              # report, keep serving
                route.send(proto.Msg.ERROR,
                           proto.pack_json({"error": str(e)}),
                           rid=frame.request_id, version=frame.version)

    def _enqueue_infer(self, frame: proto.Frame, route: _Route) -> None:
        """Handler-thread half of an INFER_REQUEST: parse the npz +
        admission metadata, then either enqueue a ScheduledRequest (plain
        RCB — deadline anchored NOW, so dispatch-queue wait counts
        against it and priority/EDF can reorder a backlog) or ship the
        parsed prompt to the dispatcher (LM path, engine state stays
        single-owner). No device state is touched here."""
        tensors = proto.unpack_tensors(frame.payload)
        meta = {k: tensors.pop(k) for k in list(tensors)
                if k.startswith("__")}
        priority = int(meta["__priority"]) if "__priority" in meta else 1
        deadline = None
        if "__deadline_ms" in meta:
            deadline = time.monotonic() + float(meta["__deadline_ms"]) / 1e3
        rid, ver = frame.request_id, frame.version

        if self.engine is not None and "prompt" in tensors:
            admission = {"priority": priority, "deadline": deadline,
                         "max_new": int(meta.get("__max_new", 16))}
            if not self._loop.submit(_Work(frame, route, tensors=tensors,
                                           meta=admission)):
                route.send(proto.Msg.ERROR,
                           self._busy_payload("busy: dispatch queue full"),
                           rid=rid, flags=proto.F_BUSY, version=ver)
            return

        if self.scheduler.pending() >= self.max_queue:
            self._loop.reject()
            route.send(proto.Msg.ERROR,
                       self._busy_payload("busy: admission queue full",
                                          pending=self.scheduler.pending()),
                       rid=rid, flags=proto.F_BUSY, version=ver)
            return
        # the kick IS the admission ticket: an accepted kick guarantees a
        # live dispatcher will drain this request (the idle hook covers
        # the kick-lands-first race); a refused kick means the dispatcher
        # is full or draining, so the request is refused too — never
        # parked where nothing will ever answer it
        if not self._loop.submit(_KICK):
            flags = proto.F_DRAINING if self._stop.is_set() \
                else proto.F_BUSY
            route.send(proto.Msg.ERROR,
                       self._busy_payload("busy: dispatch queue full"),
                       rid=rid, flags=flags, version=ver)
            return
        self.scheduler.submit(ScheduledRequest(
            rid=rid, tokens_needed=1, priority=priority, deadline=deadline,
            payload=(route, rid, ver, tensors)))

    # ------------------------------------------------------------ watchdog
    def _watchdog_budget(self, token: Any) -> Optional[float]:
        """Deadline for one armed dispatch; None == unwatched.

        ``token`` is a ScheduledRequest (single dispatch) or a list of
        them (coalesced batch — the budget scales with the run length).
        ``_Work`` items (PROVISION, control ops, LM pump kicks) are never
        watched at the loop level; the server arms the actual request
        around ``_infer`` instead, so the EDF drain inside an idle hook
        is covered identically to a kicked drain."""
        if isinstance(token, _Work):
            return None
        if self.scheduler.observations == 0:
            return None                 # boot grace: no EWMA evidence yet
        n = len(token) if isinstance(token, list) else 1
        return max(self.watchdog_floor,
                   self.scheduler.est * self.watchdog_slack * n)

    def _preempt_hung(self, token: Any) -> None:
        """Watchdog hook (runs on the watchdog thread): a dispatch blew
        its deadline. Kill the stage's tile group through the existing
        ``TileFailure`` path — the guarded driver slots start raising in
        the hung handler thread, which unwedges and fails the stage over
        to a survivor (PR 3's re-queue); the dead group's arena is
        quarantined by ``kill`` until re-validated against RIMFS CRCs."""
        mesh = self.mesh
        gid = getattr(mesh, "active_gid", None) if mesh is not None else None
        self.platform.post("watchdog_preempt", {"group": gid})
        if mesh is not None and gid is not None and mesh.alive(gid):
            mesh.kill(gid)

    # ------------------------------------------------------ typed refusals
    def _retry_after_ms(self) -> int:
        """Server-side backpressure hint: roughly how long the current
        backlog takes to drain at the admission EWMA's pace. Clients that
        honor it (Client.retries) re-arrive when capacity plausibly
        exists instead of hammering a saturated dispatcher."""
        est = self.scheduler.est if self.scheduler.observations else 0.01
        depth = self._loop.depth() + self.scheduler.pending()
        return int(min(2000.0, max(1.0, est * (depth + 1) * 1000.0)))

    def _shed_payload(self, kind: str, verdict: str,
                      retryable: Optional[bool] = None) -> bytes:
        """Machine-readable shed reply (DESIGN.md §14): ``kind`` tells
        the client WHY (busy/shed/infeasible/out_of_blocks/brownout) so
        it can distinguish retryable pressure from terminal verdicts."""
        kind = kind or "shed"
        if retryable is None:
            retryable = kind in RETRYABLE_KINDS
        return proto.pack_json(
            {"error": "shed", "kind": kind, "verdict": verdict,
             "retryable": bool(retryable),
             "retry_after_ms": self._retry_after_ms() if retryable else 0})

    def _busy_payload(self, msg: str, **extra) -> bytes:
        return proto.pack_json(
            {"error": msg, "kind": "busy", "retryable": True,
             "retry_after_ms": self._retry_after_ms(), **extra})

    # ---------------------------------------------------------- dispatcher
    def _dispatch_one(self, work: _Work) -> None:
        """Runs ONLY on the ServiceLoop worker thread."""
        if work.control is not None:            # fleet control op: between
            work.control()                      # requests IS the drain point
            return
        if work.frame is None:                  # kick: drain the admission q
            self._drain_plain()
            return
        frame, route = work.frame, work.route
        rid, ver = frame.request_id, frame.version
        try:
            if frame.kind == proto.Msg.PROVISION:
                self._provision(frame.payload)
                route.send(proto.Msg.TELEMETRY,
                           proto.pack_json({"status": "ready"}),
                           rid=rid, version=ver)
            elif frame.kind == proto.Msg.INFER_REQUEST:
                self._infer_lm(work)
            elif frame.kind == proto.Msg.TELEMETRY:
                route.send(proto.Msg.TELEMETRY,
                           proto.pack_json(self._telemetry_summary()),
                           rid=rid, version=ver)
            else:
                raise RuntimeError(f"unexpected message {frame.kind!r}")
        except Exception as e:                  # report, keep serving
            route.send(proto.Msg.ERROR, proto.pack_json({"error": str(e)}),
                       rid=rid, version=ver)

    def _coalescible(self) -> bool:
        """True when backlogged plain-RCB requests may batch: coalescing
        is a plain linked-path feature (the partitioned path pipelines
        one sample per stage), and the bound program must pass the batch
        analysis — otherwise batched dispatch would just serialize
        inside run_batched and inflate queue wait for nothing."""
        # canary active: requests must route individually (the A/B split
        # and per-request compare are defined per rid, not per batch)
        return (self.batch_window > 1 and self.mesh is None
                and self._bound is not None and self.canary is None
                and linker_mod.batch_analysis(self._bound).batchable)

    @staticmethod
    def _tensor_sig(tensors: dict) -> tuple:
        """Shape/dtype signature two requests must share to ride one
        batched dispatch (they stack on a new leading axis)."""
        return tuple(sorted((k, np.shape(v), str(np.asarray(v).dtype))
                            for k, v in tensors.items()))

    def _drain_plain(self) -> bool:
        """Drain the plain-RCB admission queue in priority/EDF order:
        shed infeasible requests with their verdicts, execute the rest
        through the linked (or partitioned) executor path.

        Coalescing: EDF picks the head as before; when the program is
        batchable, a bounded batch window then gathers up to
        ``batch_window - 1`` more requests that are ALREADY in the
        backlog (``admit`` pops only queued work — a solo request is
        never delayed waiting for company). Same-signature runs dispatch
        as one batched execution (replies scatter back by request id);
        signature changes split the window, preserving admission order.
        """
        progressed = False
        while True:
            admitted = self.scheduler.admit(1)
            if admitted and self._coalescible():
                admitted += self.scheduler.admit(self.batch_window - 1)
            for s in self.scheduler.drain_shed():
                r, srid, sver, _ = s.payload
                r.send(proto.Msg.ERROR,
                       self._shed_payload(s.verdict_kind, s.verdict),
                       rid=srid, flags=proto.F_SHED, version=sver)
                progressed = True
            if not admitted:
                return progressed
            # split the admitted window into maximal same-signature runs
            # (EDF order preserved across runs)
            runs: list = []
            for s in admitted:
                sig = self._tensor_sig(s.payload[3])
                if runs and runs[-1][0] == sig:
                    runs[-1][1].append(s)
                else:
                    runs.append((sig, [s]))
            for _, run in runs:
                if len(run) == 1:
                    self._dispatch_single(run[0])
                else:
                    self._dispatch_batch(run)
                progressed = True

    def _execute_request(self, tensors: dict, rid: int) -> tuple:
        """One plain-RCB execution, canary-aware. Returns (out, flags).

        With a canary installed, a hash-routed fraction of requests runs
        on the shadow binding; a sampled subset of those ALSO runs the
        primary and bit-compares, feeding the SPRT an agree/disagree
        observation. A sampled disagreement is answered with the
        PRIMARY's bytes — the canary never serves a byte it has been
        caught getting wrong. Shadow-served replies carry F_CANARY."""
        canary = self.canary
        if canary is None or not canary.routes(rid):
            return self._infer(tensors), 0
        canary.stats["routed"] += 1
        shadow_out = self._infer(tensors, bound=canary.bound, fs=canary.fs)
        if canary.samples(rid):
            primary_out = self._infer(tensors)
            agree = canary.judge(primary_out, shadow_out)
            canary.record(agree)
            self.platform.post("canary_sample",
                               {"rid": rid, "agree": agree})
            if not (agree and canary.serve_shadow):
                return primary_out, 0
        elif not canary.serve_shadow:
            return self._infer(tensors), 0
        canary.stats["served_shadow"] += 1
        return shadow_out, proto.F_CANARY

    def _dispatch_single(self, s) -> None:
        r, srid, sver, sts = s.payload
        wd = self._loop.watchdog
        self._executing = s
        t0 = time.perf_counter()
        try:
            if wd is not None:
                wd.arm(s)
            try:
                out, oflags = self._execute_request(sts, srid)
            except (TileFailure, IntegrityError) as e:
                # recoverable fault taxonomy (DESIGN.md §11): one re-run
                # on healthy resources — the dead group is excluded by
                # the partition failover, a corrupted transfer re-issues
                # from its retained source
                kind = "integrity_error" if isinstance(e, IntegrityError) \
                    else "tile_failure"
                self.platform.post(kind, {"stage": "dispatch",
                                          "error": str(e)})
                if wd is not None:
                    wd.arm(s)           # fresh budget for the re-run
                out, oflags = self._execute_request(sts, srid)
        except Exception as e:                  # report, keep draining
            r.send_final(s, proto.Msg.ERROR,
                         proto.pack_json({"error": str(e)}),
                         rid=srid, version=sver)
            return
        finally:
            if wd is not None:
                wd.disarm()
            self._executing = None
        dt = time.perf_counter() - t0
        self.platform.telemetry.record_latency(dt)
        self.scheduler.observe_step_latency(dt)
        r.send_final(s, proto.Msg.INFER_RESPONSE, proto.pack_tensors(out),
                     rid=srid, version=sver, flags=oflags)

    def _dispatch_batch(self, run: list) -> None:
        """One coalesced dispatch for a same-signature request run.

        The whole run executes through ``Executor.run_batched`` (staged
        once per batch bucket); replies scatter back by request id, and
        the scheduler EWMA is fed the per-request AMORTIZED latency —
        feeding it the whole batch's wall time would make the admission
        policy believe a step costs batch_size times what a request
        actually experiences, and shed feasible work."""
        if self._bound is None:
            for s in run:                       # mirror _infer's refusal
                r, srid, sver, _ = s.payload
                r.send_final(s, proto.Msg.ERROR,
                             proto.pack_json({"error": "not provisioned"}),
                             rid=srid, version=sver)
            return
        wd = self._loop.watchdog
        self._executing = run
        t0 = time.perf_counter()
        try:
            if wd is not None:
                wd.arm(run)
            outs = self.executor.run_batched(
                self._bound, [s.payload[3] for s in run],
                rimfs=self.platform.rimfs)
            outs = [{k: np.asarray(v) for k, v in out.items()}
                    for out in outs]
        except Exception:
            # fault isolation: a failed batched dispatch (e.g. the wider
            # batch shape fails to stage) must not take down requests
            # that the batch-1 path can still serve — retry each member
            # serially, which reports its own per-request error if the
            # failure is really the request's
            for s in run:
                self._dispatch_single(s)
            return
        finally:
            if wd is not None:
                wd.disarm()
            self._executing = None
        amortized = (time.perf_counter() - t0) / len(run)
        st = self.batched_stats
        st["dispatches"] += 1
        st["requests"] += len(run)
        st["max_batch"] = max(st["max_batch"], len(run))
        for s, out in zip(run, outs):
            r, srid, sver, _ = s.payload
            self.platform.telemetry.record_latency(amortized)
            self.scheduler.observe_step_latency(amortized)
            r.send_final(s, proto.Msg.INFER_RESPONSE,
                         proto.pack_tensors(out), rid=srid, version=sver)

    def _infer_lm(self, work: _Work) -> None:
        """LM service program: continuous batching via the engine; the
        reply is routed back by request id when the slot finishes (see
        _pump_engine). The engine's queue+slots are bounded the same way
        the dispatch queue is — pipelining past the cap gets
        backpressure, not unbounded buffering."""
        from repro.serving.engine import Request
        frame, route = work.frame, work.route
        rid, ver = frame.request_id, frame.version
        if len(self._inflight) >= self.max_queue:
            self._loop.reject()
            route.send(proto.Msg.ERROR,
                       self._busy_payload(
                           "busy: too many in-flight prompts",
                           inflight=len(self._inflight)),
                       rid=rid, flags=proto.F_BUSY, version=ver)
            return
        max_new = work.meta["max_new"]
        if self.max_new_clamp is not None:
            # brown-out rung 2: bound every admission's decode budget so
            # a queue of long generations can't starve the fleet
            max_new = min(max_new, self.max_new_clamp)
        prompt = np.asarray(work.tensors["prompt"]).astype(
            np.int32).reshape(-1)
        if prompt.size + max_new >= self.engine.max_seq:
            raise RuntimeError(
                f"prompt ({prompt.size} tokens) + max_new ({max_new}) "
                f"exceeds engine max_seq {self.engine.max_seq}")
        iid = next(self._iid)
        req = Request(rid=iid, prompt=prompt, max_new=max_new,
                      priority=work.meta["priority"],
                      deadline=work.meta["deadline"])
        self.engine.submit(req)
        self._inflight[iid] = (req, route, rid, ver)

    def _on_idle(self) -> bool:
        plain = self._drain_plain()
        lm = self._pump_engine()
        return plain or lm

    def _drop_work(self, work: _Work) -> None:
        """close(drain=False) hand-back: refuse explicitly, never drop
        a request whose submit was already acknowledged."""
        if work.control is not None:
            if work.meta is not None:           # fail the waiting caller
                work.meta["error"] = RuntimeError(
                    "control op dropped: dispatcher closing")
                work.meta["done"].set()
            return
        if work.frame is not None:
            work.route.send(proto.Msg.ERROR,
                            proto.pack_json({"error": "draining"}),
                            rid=work.frame.request_id,
                            flags=proto.F_DRAINING,
                            version=work.frame.version)
            return
        # a dropped KICK may represent a dispatch the wedged worker is
        # still executing (close(timeout=) racing a watchdog preemption):
        # refuse the in-flight request explicitly. send_final makes the
        # race with a late-completing handler safe — exactly one of the
        # refusal and the real reply reaches the wire.
        ex = self._executing
        if ex is None:
            return
        payload = proto.pack_json({"error": "preempted: dispatcher "
                                   "closing"})
        for s in (ex if isinstance(ex, list) else [ex]):
            r, srid, sver, _ = s.payload
            r.send_final(s, proto.Msg.ERROR, payload, rid=srid,
                         flags=proto.F_DRAINING, version=sver)

    def run_on_dispatcher(self, fn, timeout: float = 60.0):
        """Execute ``fn`` ON the dispatcher thread and return its result.

        The dispatcher runs exactly one work item at a time, so a control
        op observes the server between requests — no request is ever
        mid-execution while it runs. That makes it the fleet controller's
        atomic flip point for mesh reshapes and binding swaps: no lock
        is added to the request path, the single-owner model IS the
        mutual exclusion. Called from the dispatcher thread itself the
        op runs inline (re-entrant control flows)."""
        if threading.current_thread() is self._loop._thread:
            return fn()
        box: dict = {"done": threading.Event(), "result": None,
                     "error": None}

        def ctl():
            try:
                box["result"] = fn()
            except BaseException as e:
                box["error"] = e
            finally:
                box["done"].set()

        if not self._loop.submit(_Work(frame=None, route=None, control=ctl,
                                       meta=box)):
            raise ServerBusy("dispatcher refused control op "
                             "(draining or queue full)")
        if not box["done"].wait(timeout):
            raise TimeoutError(f"control op not executed in {timeout}s "
                               f"(dispatcher wedged?)")
        if box["error"] is not None:
            raise box["error"]
        return box["result"]

    def _pump_engine(self) -> bool:
        """ServiceLoop idle hook: one continuous-batching decode step,
        then route finished (or shed) requests back by id. Returns True
        while in-flight work remains so the loop keeps spinning hot."""
        if self.engine is None or not self._inflight:
            return False
        try:
            self.engine.step()
        except Exception as e:
            # poisoned engine state would re-raise on every pump and hang
            # every in-flight client: fail them all explicitly instead
            for iid, (req, route, rid, ver) in list(self._inflight.items()):
                route.send(proto.Msg.ERROR,
                           proto.pack_json({"error": f"engine: {e}"}),
                           rid=rid, version=ver)
            self._inflight.clear()
            raise
        for iid, (req, route, rid, ver) in list(self._inflight.items()):
            if not req.done:
                continue
            self._inflight.pop(iid, None)
            if req.shed:
                # idempotency cap: an LM request that already sampled
                # tokens is NOT safe to blind-retry (a re-run would draw
                # fresh samples) — admission-time sheds always are
                kind = req.verdict_kind
                retryable = kind in RETRYABLE_KINDS and \
                    not req.out_tokens
                route.send(proto.Msg.ERROR,
                           self._shed_payload(kind, req.verdict,
                                              retryable=retryable),
                           rid=rid, flags=proto.F_SHED, version=ver)
            else:
                route.send(proto.Msg.INFER_RESPONSE,
                           proto.pack_tensors(
                               {"tokens": np.asarray(req.out_tokens,
                                                     np.int32)}),
                           rid=rid, version=ver)
        return bool(self._inflight)

    def _telemetry_summary(self) -> dict:
        s = dict(self.platform.telemetry.summary(warmup=1))
        shed = self.scheduler.shed_count
        if self.engine is not None and self.engine.scheduler is not None:
            shed += self.engine.scheduler.shed_count
        s["serving"] = {**self._loop.summary(), "shed": shed,
                        "inflight": len(self._inflight),
                        "batched": dict(self.batched_stats)}
        s["counters"] = self.platform.telemetry.counters()
        if self.engine is not None:
            s["engine"] = self.engine.telemetry.summary(warmup=1)
            if hasattr(self.engine, "kv_stats"):
                # paged-KV engines report pool occupancy: the capacity
                # signal behind block-aware admission (shed verdicts)
                s["engine"]["kv"] = self.engine.kv_stats()
        return s

    def _provision(self, payload: bytes) -> None:
        # payload = frame-in-frame: [image_frame][program_frame]
        k1, image = proto.decode_frame(payload, max_frame=self.max_frame)
        rest = payload[proto.HEADER.size + len(image) + 4:]
        k2, prog = proto.decode_frame(rest, max_frame=self.max_frame)
        self.platform.provision(image=image, program_bytes=prog)
        if self.artifacts:
            self.platform.program.artifacts.update(self.artifacts)
        self._bound = self.platform.bind()

    def _infer(self, tensors: dict, bound=None, fs=None) -> dict:
        """Execute on the primary binding, or — when the fleet layer
        passes a (bound, fs) pair — on a canary shadow binding."""
        if bound is None:
            bound, fs = self._bound, self.platform.rimfs
        if bound is None:
            raise RuntimeError("not provisioned")
        if self.mesh is not None:
            out = self.executor.run_partitioned(
                bound, inputs=tensors, rimfs=fs,
                mesh=self.mesh, platform=self.platform)
        else:
            out = self.executor.run(bound, inputs=tensors, rimfs=fs)
        return {k: np.asarray(v) for k, v in out.items()}


# ------------------------------------------------------------------ client
class Client:
    """Protocol v2 client with request pipelining.

    ``infer`` is the synchronous one-shot; ``infer_async``/``result`` pipe
    many requests down one connection and collect responses out of order
    (frames for other request ids are parked for their waiters, so one
    ``Client`` may be shared across threads). ``version=1`` speaks the
    legacy rid-less protocol for back-compat testing.

    Backpressure retry: ``retries > 0`` makes ``infer`` re-send a request
    refused with F_BUSY/F_SHED up to that many times, sleeping a jittered
    exponential backoff (``backoff * 2**attempt``, capped, ×[0.5, 1.0)
    jitter so a refused burst doesn't re-arrive in lockstep). Scale
    events and drain windows then read as added latency instead of hard
    failures. Off by default — zero-retry callers see refusals
    immediately, exactly as before.
    """

    def __init__(self, address: tuple, version: int = 2,
                 max_frame: int = proto.MAX_FRAME, retries: int = 0,
                 backoff: float = 0.05, backoff_cap: float = 2.0,
                 retry_seed: Optional[int] = None):
        self.sock = socket.create_connection(address)
        self.version = version
        self.max_frame = max_frame
        self.retries = int(retries)
        self.backoff = backoff
        self.backoff_cap = backoff_cap
        self._retry_rng = random.Random(retry_seed)
        self.retry_stats = {"retries": 0, "busy": 0, "shed": 0,
                            "hinted": 0}
        self._send_lock = threading.Lock()
        self._cond = threading.Condition()
        self._parked: dict = {}           # rid -> Frame (out-of-order)
        self._receiving = False
        self._dead: Optional[BaseException] = None
        self._rids = itertools.count(1)

    # -------------------------------------------------------------- frames
    def _send(self, kind: proto.Msg, payload: bytes, rid: int = 0) -> None:
        with self._send_lock:
            if self.version >= 2:
                proto.send_frame(self.sock, kind, payload, request_id=rid)
            else:
                proto.send_frame(self.sock, kind, payload)

    def _await(self, rid: int,
               timeout: Optional[float] = None) -> proto.Frame:
        """Block until the reply for ``rid`` arrives. Exactly one thread
        receives at a time; frames for other ids are parked and their
        waiters notified. A receive failure marks the connection dead so
        every parked waiter errors out instead of waiting forever.

        ``timeout`` bounds the whole wait: a request id orphaned by a
        server that never replies raises ``TimeoutError`` instead of
        parking forever. The receive slot polls the socket with
        ``select`` slices (``settimeout`` would flip the shared file
        description and break concurrent senders) so a timed waiter
        holding the slot still hands it back promptly on expiry."""
        deadline = None if timeout is None \
            else time.monotonic() + timeout

        def _expired() -> float:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"no reply for request {rid} within {timeout}s")
            return remaining

        with self._cond:
            while True:
                if rid in self._parked:
                    return self._parked.pop(rid)
                if self._dead is not None:
                    raise ConnectionError(
                        f"connection failed: {self._dead!r}")
                if not self._receiving:
                    self._receiving = True
                    break
                self._cond.wait(None if deadline is None
                                else min(_expired(), 0.1))
        try:
            while True:
                if deadline is not None:
                    ready, _, _ = select.select(
                        [self.sock], [], [], min(_expired(), 0.1))
                    if not ready:
                        continue
                try:
                    f = proto.recv_frame_ex(self.sock,
                                            max_frame=self.max_frame)
                except Exception as e:
                    with self._cond:
                        self._dead = e
                    raise
                # v1 frames carry no id: deliver to the active waiter
                if f.version == 1 or f.request_id == rid:
                    return f
                with self._cond:
                    self._parked[f.request_id] = f
                    self._cond.notify_all()
        finally:
            with self._cond:
                self._receiving = False
                self._cond.notify_all()

    @staticmethod
    def _raise_error(f: proto.Frame) -> None:
        info = proto.unpack_json(f.payload)
        msg = info.get("error", str(info))
        if f.flags & proto.F_SHED:
            exc: Any = RequestShed(info.get("verdict", msg))
            exc.kind = info.get("kind", "shed")
        elif f.flags & (proto.F_BUSY | proto.F_DRAINING):
            exc = ServerBusy(msg)
            exc.kind = info.get("kind", "busy")
        else:
            raise RuntimeError(msg)
        exc.retry_after_ms = info.get("retry_after_ms")
        exc.retryable = bool(info.get("retryable", True))
        raise exc

    def _rpc(self, kind: proto.Msg, payload: bytes) -> proto.Frame:
        rid = next(self._rids)
        self._send(kind, payload, rid=rid)
        f = self._await(rid)
        if f.kind == proto.Msg.ERROR:
            self._raise_error(f)
        return f

    # ----------------------------------------------------------------- api
    def provision(self, image: bytes, program_bytes: bytes) -> dict:
        inner = proto.encode_frame(proto.Msg.PROVISION, image) + \
            proto.encode_frame(proto.Msg.PROVISION, program_bytes)
        return proto.unpack_json(
            self._rpc(proto.Msg.PROVISION, inner).payload)

    def infer_async(self, deadline_ms: Optional[float] = None,
                    priority: Optional[int] = None,
                    max_new: Optional[int] = None, **tensors) -> int:
        """Send one pipelined INFER_REQUEST; returns its request id.
        Admission metadata rides as reserved ``__``-prefixed npz entries."""
        rid = next(self._rids)
        meta: dict = {}
        if deadline_ms is not None:
            meta["__deadline_ms"] = np.float64(deadline_ms)
        if priority is not None:
            meta["__priority"] = np.int32(priority)
        if max_new is not None:
            meta["__max_new"] = np.int32(max_new)
        self._send(proto.Msg.INFER_REQUEST,
                   proto.pack_tensors({**tensors, **meta}), rid=rid)
        return rid

    def result(self, rid: int, timeout: Optional[float] = None,
               with_flags: bool = False):
        """Collect the response for a pipelined request id (any order).
        ``timeout`` raises ``TimeoutError`` for an orphaned id (e.g. a
        dead server that will never answer) instead of parking forever.
        ``with_flags=True`` returns ``(tensors, flags)`` so callers can
        see reply metadata such as F_CANARY (shadow-served bytes)."""
        f = self._await(rid, timeout=timeout)
        if f.kind == proto.Msg.ERROR:
            self._raise_error(f)
        out = proto.unpack_tensors(f.payload)
        return (out, f.flags) if with_flags else out

    def infer(self, deadline_ms: Optional[float] = None,
              priority: Optional[int] = None,
              max_new: Optional[int] = None,
              timeout: Optional[float] = None, **tensors) -> dict:
        """One-shot inference; with ``retries`` set, bounded re-send on
        backpressure refusals (a refused request was never executed, so
        re-sending cannot double-run it)."""
        attempt = 0
        while True:
            try:
                return self.result(self.infer_async(
                    deadline_ms=deadline_ms, priority=priority,
                    max_new=max_new, **tensors), timeout=timeout)
            except (ServerBusy, RequestShed) as e:
                kind = "busy" if isinstance(e, ServerBusy) else "shed"
                self.retry_stats[kind] += 1
                if not getattr(e, "retryable", True):
                    # terminal verdict (infeasible deadline, or a non-
                    # idempotent mid-sampling shed): retrying is either
                    # futile or unsafe — fail fast regardless of budget
                    raise
                if attempt >= self.retries:
                    raise
                delay = min(self.backoff_cap, self.backoff * (2 ** attempt))
                delay *= 0.5 + self._retry_rng.random() / 2
                hint = getattr(e, "retry_after_ms", None)
                if hint:
                    # the server told us when capacity plausibly exists;
                    # arriving earlier only burns a retry on the same wall
                    self.retry_stats["hinted"] += 1
                    delay = max(delay, float(hint) / 1e3)
                time.sleep(delay)
                attempt += 1
                self.retry_stats["retries"] += 1

    def telemetry(self) -> dict:
        return proto.unpack_json(self._rpc(proto.Msg.TELEMETRY, b"").payload)

    def shutdown(self) -> dict:
        """Graceful server drain; returns the server's drain ack."""
        return proto.unpack_json(
            self._rpc(proto.Msg.SHUTDOWN, b"").payload)

    def close(self) -> None:
        self.sock.close()
