"""Network-attached inference service (RTPM host-connectivity role).

A socket server speaking the CRC-framed protocol: a client PROVISIONs a
model (RIMFS image + RCB program bytes), then streams INFER_REQUESTs; the
server executes them through the generic RCB executor and answers with
INFER_RESPONSEs plus TELEMETRY on demand — the paper's "baremetal runtime as
a network-attached inference service" operating mode.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core.executor import Executor
from repro.core.rtpm import Platform
from repro.serving import protocol as proto


class InferenceServer:
    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 artifacts: Optional[dict] = None):
        self.platform = Platform()
        self.executor = Executor(rtpm=self.platform)
        self.artifacts = artifacts or {}
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(8)
        self.address = self._sock.getsockname()
        self._bound = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- lifecycle
    def start(self) -> tuple:
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()
        return self.address

    def stop(self) -> None:
        self._stop.set()
        try:
            # unblock accept()
            socket.create_connection(self.address, timeout=1).close()
        except OSError:
            pass
        if self._thread:
            self._thread.join(timeout=5)
        self._sock.close()

    # ------------------------------------------------------------- serving
    def _serve(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            if self._stop.is_set():
                conn.close()
                return
            t = threading.Thread(target=self._handle, args=(conn,),
                                 daemon=True)
            t.start()

    def _handle(self, conn: socket.socket) -> None:
        with conn:
            while not self._stop.is_set():
                try:
                    kind, payload = proto.recv_frame(conn)
                except (ConnectionError, OSError):
                    return
                try:
                    if kind == proto.Msg.PROVISION:
                        self._provision(payload)
                        proto.send_frame(conn, proto.Msg.TELEMETRY,
                                         proto.pack_json({"status": "ready"}))
                    elif kind == proto.Msg.INFER_REQUEST:
                        out = self._infer(proto.unpack_tensors(payload))
                        proto.send_frame(conn, proto.Msg.INFER_RESPONSE,
                                         proto.pack_tensors(out))
                    elif kind == proto.Msg.TELEMETRY:
                        proto.send_frame(
                            conn, proto.Msg.TELEMETRY,
                            proto.pack_json(
                                self.platform.telemetry.summary(warmup=1)))
                    elif kind == proto.Msg.HEARTBEAT:
                        self.platform.heartbeats.beat(
                            proto.unpack_json(payload).get("worker", "?"))
                    elif kind == proto.Msg.SHUTDOWN:
                        self._stop.set()
                        return
                except Exception as e:  # report, keep serving
                    proto.send_frame(conn, proto.Msg.ERROR,
                                     proto.pack_json({"error": str(e)}))

    def _provision(self, payload: bytes) -> None:
        # payload = frame-in-frame: [image_frame][program_frame]
        k1, image = proto.decode_frame(payload)
        rest = payload[proto.HEADER.size + len(image) + 4:]
        k2, prog = proto.decode_frame(rest)
        self.platform.provision(image=image, program_bytes=prog)
        if self.artifacts:
            self.platform.program.artifacts.update(self.artifacts)
        self._bound = self.platform.bind()

    def _infer(self, tensors: dict) -> dict:
        if self._bound is None:
            raise RuntimeError("not provisioned")
        t0 = time.perf_counter()
        out = self.executor.run(self._bound, inputs=tensors,
                                rimfs=self.platform.rimfs)
        self.platform.telemetry.record_latency(time.perf_counter() - t0)
        return {k: np.asarray(v) for k, v in out.items()}


# ------------------------------------------------------------------ client
class Client:
    def __init__(self, address: tuple):
        self.sock = socket.create_connection(address)

    def provision(self, image: bytes, program_bytes: bytes) -> dict:
        inner = proto.encode_frame(proto.Msg.PROVISION, image) + \
            proto.encode_frame(proto.Msg.PROVISION, program_bytes)
        proto.send_frame(self.sock, proto.Msg.PROVISION, inner)
        kind, payload = proto.recv_frame(self.sock)
        return proto.unpack_json(payload)

    def infer(self, **tensors) -> dict:
        proto.send_frame(self.sock, proto.Msg.INFER_REQUEST,
                         proto.pack_tensors(tensors))
        kind, payload = proto.recv_frame(self.sock)
        if kind == proto.Msg.ERROR:
            raise RuntimeError(proto.unpack_json(payload)["error"])
        return proto.unpack_tensors(payload)

    def telemetry(self) -> dict:
        proto.send_frame(self.sock, proto.Msg.TELEMETRY, b"")
        _, payload = proto.recv_frame(self.sock)
        return proto.unpack_json(payload)

    def close(self) -> None:
        self.sock.close()
