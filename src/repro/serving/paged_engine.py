"""Paged-KV continuous batching on the compiled path (ISSUE 8 tentpole).

``PagedServingEngine`` replaces the dense (L, B, max_seq, Hkv, D) slot
cache with block tables over a shared physical pool (serving/paged_cache):

* **Device-side addressing** — block tables are int32 device inputs of
  AOT-compiled prefill/decode programs; the pool is gathered/scattered
  over its block axis *inside* the compiled graphs. The host never
  rebuilds pool arrays; it only tracks lifetimes.
* **Prefill/decode disaggregation** — prefill groups ride the PR 5
  batch-bucket ladder (one fused dispatch per (prompt length, pow2
  group)); decode rides a persistent multi-token step program: a
  ``lax.scan`` window runs forward → sample → feed-back on device, so a
  dispatch advances every lane up to 8 tokens for one host round-trip of
  (B, window) ints.
* **AOT executables in the CRC cache** — every compiled shape is keyed
  ``(service program CRC, shape descriptor)`` in ``Executor``'s
  module-wide batch cache: engines over the same service program share
  executables, under the same capacity bound/eviction as batched RCB
  dispatch.
* **Occupancy-aware admission** — a feasibility veto reserves worst-case
  blocks (prompt + max_new) at admission; an infeasible reservation is a
  scheduler shed verdict, so ``OutOfBlocksError`` cannot fire mid-step.
  Completion releases the sequence's blocks defrag-free.
* **Residency** — the pool registers with the driver's DeviceArena so
  fleet reshapes / watchdog revives account KV memory like any other
  resident buffer.
"""
from __future__ import annotations

import itertools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import rctc
from repro.core.executor import Executor
from repro.core.rhal import TileMesh
from repro.launch.steps import make_paged_decode_step, make_paged_prefill_step
from repro.models import transformer as tf
from repro.serving.engine import EngineBase, Request, params_from_rimfs
from repro.serving.paged_cache import PagedKVCache

#: Decode-window ladder: one dispatch advances every lane w tokens
#: (largest rung that no live lane's remaining budget would overshoot).
DECODE_WINDOWS = (8, 4, 2, 1)


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


class PagedServingEngine(EngineBase):
    """Continuous batching with paged KV: slots hold block tables, not
    worst-case dense cache stripes, so capacity is bounded by *blocks in
    use*, not ``max_batch * max_seq``."""

    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 256, greedy: bool = True, scheduler=None,
                 mesh: Optional[TileMesh] = None, temperature: float = 1.0,
                 seed: int = 0, block_size: int = 16,
                 num_blocks: Optional[int] = None, driver=None):
        tf._check_paged_family(cfg)
        if cfg.input_kind != "tokens":
            raise NotImplementedError("paged serving takes token prompts")
        super().__init__(cfg, params, max_batch, max_seq, greedy, scheduler,
                         mesh, temperature, seed)
        self.block_size = block_size
        self.blocks_per_seq = (max_seq + block_size - 1) // block_size
        if num_blocks is None:
            # full capacity: every slot can hold a max_seq sequence (the
            # dense engine's memory envelope); callers shrink this to
            # trade capacity for admission pressure
            num_blocks = max_batch * self.blocks_per_seq
        self.cache = PagedKVCache(
            num_layers=cfg.num_layers, num_blocks=num_blocks,
            block_size=block_size, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, dtype=cfg.dtype)
        self._seqs: list[Optional[int]] = [None] * max_batch
        self._seq_ctr = itertools.count(1)
        if driver is None and mesh is not None:
            driver = mesh.primary
        self.driver = driver
        if driver is not None:
            self.cache.register_residency(driver)
        # the RCB service program; its CRC keys every AOT executable
        self.program = rctc.compile_paged_lm_service(
            cfg, max_batch, max_seq, block_size, num_blocks,
            make_paged_prefill_step(cfg),
            make_paged_decode_step(cfg, greedy=greedy,
                                   temperature=temperature),
            greedy=greedy, temperature=temperature)
        self._crc = self.program.crc()

    @classmethod
    def from_rimfs(cls, cfg, fs, driver=None, **kwargs):
        """Like the base provisioner, but the pool also registers with the
        driver's arena (a mesh anchors on its primary group)."""
        if isinstance(driver, TileMesh):
            kwargs.setdefault("mesh", driver)
        elif driver is not None:
            kwargs.setdefault("driver", driver)
        return cls(cfg, params_from_rimfs(cfg, fs, driver), **kwargs)

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Release any blocks still held and return arena ranges."""
        for seq in list(self.cache.tables):
            self.cache.release(seq)
        self.cache.unregister_residency()

    def kv_stats(self) -> dict:
        c = self.cache
        return {"num_blocks": c.num_blocks, "free_blocks": c.free_blocks(),
                "block_size": c.block_size,
                "utilization": round(c.utilization(), 4),
                "pool_bytes": c.pool_bytes()}

    # ----------------------------------------------------------- executables
    def _exe(self, desc: tuple, build):
        key = (self._crc, desc)
        fn = Executor.aot_cache_get(key)
        if fn is None:
            fn = build()
            Executor.aot_cache_put(key, fn)
        return fn

    def _prefill_exe(self, plen: int, batch: int, width: int):
        def build():
            step = make_paged_prefill_step(self.cfg)
            return jax.jit(step, donate_argnums=(1, 2)).lower(
                self.params, self.cache.k, self.cache.v,
                {"inputs": jax.ShapeDtypeStruct((batch, plen), jnp.int32),
                 "tables": jax.ShapeDtypeStruct((batch, width),
                                                jnp.int32)}).compile()
        return self._exe(("paged_prefill", plen, batch, width), build)

    def _decode_exe(self, batch_args: tuple):
        bucket, span, window = batch_args

        def build():
            step = make_paged_decode_step(self.cfg, window=window,
                                          greedy=self.greedy,
                                          temperature=self.temperature)
            batch = {"tokens": jax.ShapeDtypeStruct((bucket,), jnp.int32),
                     "pos": jax.ShapeDtypeStruct((bucket,), jnp.int32),
                     "tables": jax.ShapeDtypeStruct((bucket, span),
                                                    jnp.int32)}
            if not self.greedy:
                batch["key"] = self._key     # concrete aval donor
            return jax.jit(step, donate_argnums=(1, 2)).lower(
                self.params, self.cache.k, self.cache.v, batch).compile()
        return self._exe(("paged_decode",) + batch_args, build)

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        free = [i for i in range(self.max_batch) if self._slots[i] is None]
        if not free:
            return
        # worst-case block reservation at admission: a request is placed
        # only if prompt + max_new tokens fit the pool RIGHT NOW (budget
        # is cumulative across this admission round), so OutOfBlocksError
        # can never fire mid-step — infeasible becomes a shed verdict.
        budget = self.cache.free_blocks()

        def feasible(req: Request) -> Optional[str]:
            nonlocal budget
            # max(·, 1): the decode window always emits >= 1 token, even
            # for a degenerate max_new=0 request
            tokens = min(req.prompt.shape[0] + max(req.max_new, 1),
                         self.max_seq)
            need = self.cache.blocks_needed(tokens)
            if need > budget:
                return ("out_of_blocks",
                        f"shed: out of KV blocks (need {need}, free "
                        f"{budget} of {self.cache.num_blocks})")
            budget -= need
            return None

        placed = list(zip(free, self._pop_admitted(len(free), feasible)))
        if not placed:
            return
        # same grouping discipline as the dense engine: one fused prefill
        # dispatch per (prompt length, pow2 chunk) — bucket-ladder shapes
        # keep the AOT cache bounded, per-sample numerics bit-identical
        by_len: dict = {}
        for i, req in placed:
            by_len.setdefault(req.prompt.shape[0], []).append((i, req))
        groups = []
        for plen, members in by_len.items():
            while members:
                k = 1 << (len(members).bit_length() - 1)   # pow2 <= len
                groups.append((plen, members[:k]))
                members = members[k:]
        for plen, group in groups:
            seqs = []
            for i, req in group:
                seq = next(self._seq_ctr)
                self.cache.allocate(
                    seq, tokens=min(plen + max(req.max_new, 1),
                                    self.max_seq))
                seqs.append(seq)
            width = self.cache.blocks_needed(plen)
            tables = self.cache.table_array(seqs, width=width)
            prompts = np.stack([r.prompt for _, r in group]).astype(np.int32)
            fn = self._prefill_exe(plen, len(group), width)
            logits, self.cache.k, self.cache.v = fn(
                self.params, self.cache.k, self.cache.v,
                {"inputs": prompts, "tables": tables})
            picks = self._sample(logits)
            for j, (i, req) in enumerate(group):
                self._slots[i] = req
                self._seqs[i] = seqs[j]
                self.cache.advance(seqs[j], plen)
                self._pos[i] = plen
                req.out_tokens.append(int(picks[j]))

    # --------------------------------------------------------------- decode
    def step(self) -> int:
        """One decode dispatch across all live slots — advances every
        lane by the window (up to 8 tokens). Returns #live."""
        self._admit()
        live = [i for i, r in enumerate(self._slots) if r is not None]
        if not live:
            return 0
        # window: largest rung no lane overshoots (budget nor seq cap)
        room = min(
            min(r.max_new - (len(r.out_tokens) - 1) for r in
                (self._slots[i] for i in live)),
            min(self.max_seq - 1 - int(self._pos[i]) for i in live))
        window = next(w for w in DECODE_WINDOWS if w <= max(1, room))
        # lanes compact into a batch bucket; span bucket bounds the
        # gathered block axis to the positions actually live this window
        bucket = _pow2_at_least(len(live))
        span = min(self.blocks_per_seq, _pow2_at_least(max(
            self.cache.blocks_needed(int(self._pos[i]) + window)
            for i in live)))
        seqs = [self._seqs[i] for i in live]
        tables = self.cache.table_array(seqs, width=span, rows=bucket)
        tokens = np.zeros((bucket,), np.int32)
        pos = np.zeros((bucket,), np.int32)
        for j, i in enumerate(live):
            tokens[j] = self._slots[i].out_tokens[-1]
            pos[j] = self._pos[i]
        batch = {"tokens": tokens, "pos": pos, "tables": tables}
        if not self.greedy:
            self._key, batch["key"] = jax.random.split(self._key)
        fn = self._decode_exe((bucket, span, window))
        t0 = time.perf_counter()
        toks, self.cache.k, self.cache.v = fn(
            self.params, self.cache.k, self.cache.v, batch)
        toks = np.asarray(toks)                  # (bucket, window) sync
        dt = time.perf_counter() - t0
        # telemetry + admission EWMA are per-TOKEN quantities: a window-w
        # dispatch is w decode steps' worth of progress
        self.telemetry.record_latency(dt / window)
        if self.scheduler is not None:
            self.scheduler.observe_step_latency(dt / window)
        for j, i in enumerate(live):
            r = self._slots[i]
            r.out_tokens.extend(int(t) for t in toks[j])
            self.cache.advance(self._seqs[i], window)
            self._pos[i] += window
            if self._finish(i, r):
                r.done = True
                self.cache.release(self._seqs[i])   # defrag-free recycle
                self._slots[i] = None
                self._seqs[i] = None
        return len(live)
