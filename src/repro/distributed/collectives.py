"""Distributed-optimization tricks: compressed gradient all-reduce.

``compressed_psum`` runs inside ``shard_map`` over the data axis and
implements three policies:

  * none    — fp32 psum (baseline)
  * bf16    — cast-to-bf16 psum (2x wire traffic reduction)
  * int8_ef — symmetric int8 quantization with error feedback: the
    quantization residual is carried locally and added to the next round's
    gradient, keeping SGD unbiased in the long run (1-bit-Adam family).

At 1000+ nodes DP gradients cross DCN between pods; compression there is the
difference between compute-bound and comms-bound scaling. The dry-run mesh
keeps fp32 reductions (XLA-inserted); this module is the opt-in fast path,
unit-tested on a host mesh in tests/test_distributed.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: new API (``check_vma``) when
    present, ``jax.experimental.shard_map`` (``check_rep``) otherwise."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _psum(x, axis):
    return jax.lax.psum(x, axis_name=axis)


def compressed_psum(grad: jax.Array, axis: str, method: str = "bf16",
                    error: Optional[jax.Array] = None):
    """All-reduce-mean one gradient tensor across `axis` with compression.

    Returns (reduced_grad fp32, new_error). Call inside shard_map.
    """
    n = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
         else jax.lax.psum(1, axis))    # jax 0.4.x compat
    g = grad.astype(jnp.float32)
    if method == "none":
        return _psum(g, axis) / n, error
    if method == "bf16":
        r = _psum(g.astype(jnp.bfloat16), axis).astype(jnp.float32) / n
        return r, error
    if method == "int8_ef":
        if error is not None:
            g = g + error
        # shared scale must be the fleet-wide MAX (mean would clip shards
        # holding larger gradients)
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis) + 1e-12
        q = jnp.clip(jnp.round(g / scale * 127.0), -127, 127)
        deq_local = q * (scale / 127.0)
        new_error = g - deq_local                                 # feedback
        total = _psum(q.astype(jnp.int32), axis).astype(jnp.float32)
        return total * (scale / 127.0) / n, new_error
    raise ValueError(f"unknown compression {method!r}")


def compressed_psum_tree(grads, axis: str, method: str = "bf16",
                         errors=None):
    """Tree version; threads per-leaf error-feedback state."""
    leaves, treedef = jax.tree.flatten(grads)
    errs = (jax.tree.leaves(errors) if errors is not None
            else [None] * len(leaves))
    out, new_errs = [], []
    for g, e in zip(leaves, errs):
        r, ne = compressed_psum(g, axis, method, e)
        out.append(r)
        new_errs.append(ne if ne is not None else jnp.zeros_like(g))
    return treedef.unflatten(out), treedef.unflatten(new_errs)


def make_dp_train_step(loss_fn, optimizer_update, mesh, axis: str = "data",
                       method: str = "int8_ef"):
    """Data-parallel train step with compressed gradient exchange.

    ``loss_fn(params, batch) -> scalar``; params replicated, batch sharded
    on `axis`. Demonstrates the shard_map composition used between pods.
    """
    @functools.partial(
        shard_map_compat, mesh=mesh,
        in_specs=(P(), P(axis), P()),
        out_specs=(P(), P()))
    def step(params, batch, errors):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, new_errors = compressed_psum_tree(grads, axis, method, errors)
        new_params = optimizer_update(params, grads)
        return new_params, new_errors

    return step
