from repro.distributed.sharding import (  # noqa: F401
    RULE_SETS,
    axis_rules,
    current_context,
    logical_to_pspec,
    shard,
    sharding_for,
)
