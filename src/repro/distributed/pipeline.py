"""Pipeline parallelism (GPipe schedule) via shard_map + ppermute.

The assigned production meshes dedicate their axes to DP/FSDP x TP, so the
dry-run table does not use PP; this module provides the stage-parallel
schedule for deployments that add a "stage" axis (e.g. (pp, data, model)
within a pod, or pp across pods over DCN). Microbatches stream through
stages with ``ppermute`` hops; bubble fraction is the usual
(S-1)/(M+S-1).

Semantics test (tests/test_distributed.py): a 4-stage pipeline over a host
mesh must reproduce the single-device stacked forward exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import shard_map_compat


def pipeline_forward(stage_fn, mesh, axis: str = "stage"):
    """Build fn(stage_params, microbatches) -> outputs.

    ``stage_params``: pytree with leading stage dim (sharded over `axis`).
    ``microbatches``: (M, mb, ...) batch-major microbatch stack (replicated).
    ``stage_fn(params_i, x) -> y`` with y.shape == x.shape.
    """
    n_stage = mesh.shape[axis]

    @functools.partial(
        shard_map_compat, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P())
    def run(stage_params, mbs):
        params_local = jax.tree.map(lambda a: a[0], stage_params)
        idx = jax.lax.axis_index(axis)
        M = mbs.shape[0]
        T = M + n_stage - 1
        x_shape = mbs.shape[1:]
        state = jnp.zeros(x_shape, mbs.dtype)      # stage input register
        outs = jnp.zeros_like(mbs)

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (if any); others take the wire
            feed = mbs[jnp.minimum(t, M - 1)]
            x = jnp.where(idx == 0, feed, state)
            y = stage_fn(params_local, x)
            # push to next stage over the ring
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stage) for i in range(n_stage)])
            # last stage commits microbatch (t - (n_stage-1)) when valid
            commit = t - (n_stage - 1)
            valid = jnp.logical_and(idx == n_stage - 1,
                                    jnp.logical_and(commit >= 0, commit < M))
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(commit, 0), 0),
                lambda o: o, outs)
            return nxt, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (state, outs))
        # everyone but the last stage holds zeros; psum broadcasts the result
        outs = jnp.where(idx == n_stage - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return run
