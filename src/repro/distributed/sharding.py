"""Logical-axis sharding: the distributed half of the paper's RBL.

In AEG, the Runtime Binding Layer resolves *symbolic* buffer IDs into
*physical* addresses. On a TPU pod the physical address space of a tensor is
its shard layout, so binding == resolving logical axis names ("batch",
"heads", "mlp", ...) into mesh ``PartitionSpec``s.

The resolver is shape-aware and fault-tolerant by construction: a logical
axis maps to an *ordered list of candidate mesh-axis groups*; the first
candidate whose mesh axes are (a) not already used by an earlier dim of the
same tensor and (b) evenly divide the dim size wins, otherwise the dim is
replicated. This one mechanism absorbs every irregularity in the assigned
architecture pool (40/56/25-head attention vs a 16-way model axis, vocab
32001, batch-1 long-context decode) without per-arch special cases.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# A candidate is a mesh axis name or tuple of mesh axis names.
Candidate = Union[str, tuple]
# Rules: logical axis name -> ordered candidates.
Rules = dict[str, tuple]


def _norm(c: Candidate) -> tuple:
    return (c,) if isinstance(c, str) else tuple(c)


# ---------------------------------------------------------------------------
# Rule sets (mode-keyed). Mesh axes: ("pod",) "data", "model".
# ---------------------------------------------------------------------------

def _rules(**kw) -> Rules:
    return {k: tuple(v) for k, v in kw.items()}


RULE_SETS: dict[str, Rules] = {
    # Training: DP over (pod, data); TP over model on mlp/experts/vocab and,
    # where divisible, heads; sequence falls back onto model for attention
    # tensors whose head count does not divide the model axis. Params carry
    # an "fsdp" logical axis on their largest dim -> ZeRO-3 style sharding.
    "train": _rules(
        batch=(("pod", "data"), "data"),
        seq=("model",),
        embed=(),
        fsdp=(("pod", "data"), "data"),
        opt_shard=(("pod", "data"), "data"),
        heads=("model",),
        kv_heads=("model",),
        head_dim=(),
        mlp=("model",),
        experts=("model",),
        vocab=("model",),
        state=(),
        layers=(),
    ),
    # ZeRO-1 train variant (§Perf H3): params replicated over data (they
    # must fit per-device after TP/EP), moments stay data-sharded. Removes
    # the 2x-params fwd/bwd all-gather; gradients still reduce once.
    "train_zero1": _rules(
        batch=(("pod", "data"), "data"),
        seq=("model",),
        embed=(),
        fsdp=(),
        opt_shard=(("pod", "data"), "data"),
        heads=("model",),
        kv_heads=("model",),
        head_dim=(),
        mlp=("model",),
        experts=("model",),
        vocab=("model",),
        state=(),
        layers=(),
    ),
    # Prefill: same as train but no fsdp gathering pressure (params already
    # bound); keep activations batch+TP sharded.
    "prefill": _rules(
        batch=(("pod", "data"), "data"),
        seq=("model",),
        embed=(),
        fsdp=(("pod", "data"), "data"),
        heads=("model",),
        kv_heads=("model",),
        head_dim=(),
        mlp=("model",),
        experts=("model",),
        vocab=("model",),
        state=(),
        layers=(),
    ),
    # Decode: batch over (pod,data); KV-cache sequence over model (flash-
    # decode style SP — XLA inserts the partial-softmax collectives); at
    # batch=1 (long_500k) batch replicates and seq grabs (data, model).
    # Weights additionally shard their fsdp/embed dims over "data"
    # (inference weight sharding, §Perf iteration H2): per-step weight
    # reads drop 16x while the gathered activations are a single token.
    "decode": _rules(
        batch=(("pod", "data"), "data"),
        seq=(("data", "model"), "model", "data"),
        embed=("data",),
        fsdp=("data",),
        heads=("model",),
        kv_heads=("model",),
        head_dim=(),
        mlp=("model",),
        experts=("model",),
        vocab=("model",),
        state=("model",),
        layers=(),
    ),
}


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Union[str, Rules, None]):
    """Activate a (mesh, rules) binding context (no-op if mesh is None)."""
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def current_context():
    return _CTX.mesh, _CTX.rules


# ---------------------------------------------------------------------------
# Resolver
# ---------------------------------------------------------------------------

def logical_to_pspec(shape: Sequence[int],
                     axes: Sequence[Optional[str]],
                     rules: Rules,
                     mesh: Mesh) -> PartitionSpec:
    """Shape-aware logical->physical resolution (see module docstring)."""
    assert len(shape) == len(axes), (shape, axes)
    used: set[str] = set()
    out: list = []
    sizes = dict(mesh.shape)      # works for Mesh and AbstractMesh alike
    for dim, name in zip(shape, axes):
        entry = None
        if name is not None:
            for cand in rules.get(name, ()):
                cand = _norm(cand)
                if any(a not in sizes for a in cand):   # axis absent from mesh
                    continue
                if any(a in used for a in cand):
                    continue
                total = 1
                for a in cand:
                    total *= sizes[a]
                if dim % total != 0 or total == 1:
                    continue
                entry = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def sharding_for(shape, axes, mesh=None, rules=None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None or rules is None:
        return None
    if isinstance(rules, str):
        rules = RULE_SETS[rules]
    return NamedSharding(mesh, logical_to_pspec(shape, axes, rules, mesh))


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active binding context (1 if absent)."""
    if _CTX.mesh is None:
        return 1
    return dict(_CTX.mesh.shape).get(name, 1)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op
    outside an ``axis_rules`` context, e.g. in single-device smoke tests)."""
    s = sharding_for(x.shape, axes)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)
