"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles (interpret mode executes the kernel body on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.int8_matmul.ops import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


@pytest.mark.parametrize("shape", [
    (1, 128, 2, 2, 32), (2, 64, 4, 2, 64), (1, 256, 2, 1, 16),
    (2, 128, 6, 3, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(shape, dtype, rng):
    B, S, H, Hkv, D = shape
    q = jnp.asarray(rng.randn(B, S, H, D), dtype)
    k = jnp.asarray(rng.randn(B, S, Hkv, D), dtype)
    v = jnp.asarray(rng.randn(B, S, Hkv, D), dtype)
    o = flash_attention(q, k, v, block_q=64, block_k=64)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    r = attention_ref(qr, kr, vr, group=H // Hkv)
    r = r.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("shape,chunk", [
    ((2, 128, 2, 64), 64), ((1, 64, 4, 32), 16), ((2, 96, 1, 16), 32),
    ((1, 32, 2, 8), 8),
])
def test_wkv6(shape, chunk, rng):
    B, T, H, K = shape
    r = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, T, H, K), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.randn(B, T, H, K), jnp.float32))
    u = jnp.asarray(rng.randn(H, K), jnp.float32) * 0.5
    y = wkv6(r, k, v, lw, u, chunk=chunk)

    def fold(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, T, K)

    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    yr = wkv6_ref(fold(r), fold(k), fold(v), fold(lw), uf)
    yr = yr.reshape(B, H, T, K).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)


def test_wkv6_extreme_decay_no_overflow(rng):
    """Decay ~0 (log-weight very negative) must not overflow/NaN — the
    pairwise-difference formulation guarantees non-positive exponents."""
    B, T, H, K = 1, 64, 1, 16
    r = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    v = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    lw = jnp.full((B, T, H, K), -80.0)            # decay ~ e^-80
    u = jnp.zeros((H, K), jnp.float32)
    y = wkv6(r, k, v, lw, u, chunk=16)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("shape,chunk,db", [
    ((2, 64, 32, 8), 16, 16), ((1, 32, 64, 16), 8, 32),
    ((1, 128, 16, 4), 32, 16),
])
def test_ssm_scan(shape, chunk, db, rng):
    B, T, di, N = shape
    da = -jnp.exp(jnp.asarray(rng.randn(B, T, di, N), jnp.float32))
    bx = jnp.asarray(rng.randn(B, T, di, N), jnp.float32)
    c = jnp.asarray(rng.randn(B, T, N), jnp.float32)
    y = ssm_scan(da, bx, c, chunk=chunk, d_block=db)
    yr = ssm_scan_ref(da, bx, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("mkn,blocks", [
    ((128, 256, 128), (64, 64, 64)), ((64, 64, 64), (32, 32, 32)),
    ((256, 128, 64), (128, 64, 128)),
])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul(mkn, blocks, out_dtype, rng):
    M, K, N = mkn
    bm, bn, bk = blocks
    x = jnp.asarray(rng.randint(-127, 128, (M, K)), jnp.int8)
    w = jnp.asarray(rng.randint(-127, 128, (K, N)), jnp.int8)
    s = jnp.asarray(rng.rand(N).astype(np.float32))
    y = int8_matmul(x, w, s, block_m=bm, block_n=bn, block_k=bk,
                    out_dtype=out_dtype)
    yr = int8_matmul_ref(x, w, s, out_dtype=out_dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=1e-2 if out_dtype == jnp.bfloat16 else 0,
                               atol=1e-2 if out_dtype == jnp.bfloat16 else 0)


def test_model_chunked_wkv_matches_oracle(rng):
    """models/rwkv6.wkv_chunked (the lowering path) against the oracle."""
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, K = 2, 64, 2, 32
    r = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    k = jnp.asarray(rng.randn(B, T, H, K), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, T, H, K), jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.randn(B, T, H, K), jnp.float32))
    u = jnp.asarray(rng.randn(H, K), jnp.float32) * 0.5
    y, _ = wkv_chunked(r, k, v, lw, u, jnp.zeros((B, H, K, K)), chunk=16)

    def fold(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, T, K)

    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    yr = wkv6_ref(fold(r), fold(k), fold(v), fold(lw), uf)
    yr = yr.reshape(B, H, T, K).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
