"""Data pipeline: determinism, sharding consistency, replay."""
import numpy as np

from repro.data.pipeline import SyntheticLM


def test_shards_tile_global_batch():
    ds = SyntheticLM(vocab_size=256, seq_len=16, global_batch=8)
    g = ds.global_batch_at(3)
    parts = [ds.shard_at(3, i, 4) for i in range(4)]
    stitched = np.concatenate([p["inputs"] for p in parts], axis=0)
    np.testing.assert_array_equal(g["inputs"], stitched)


def test_deterministic_replay():
    ds = SyntheticLM(vocab_size=512, seq_len=8, global_batch=4)
    a = ds.global_batch_at(11)
    b = ds.global_batch_at(11)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = ds.global_batch_at(12)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_elastic_resharding_preserves_stream():
    """8-way and 2-way fleets must see the same global batch (elastic
    restart correctness)."""
    ds = SyntheticLM(vocab_size=128, seq_len=8, global_batch=8)
    wide = np.concatenate([ds.shard_at(5, i, 8)["inputs"] for i in range(8)])
    narrow = np.concatenate([ds.shard_at(5, i, 2)["inputs"]
                             for i in range(2)])
    np.testing.assert_array_equal(wide, narrow)


def test_targets_are_shifted_inputs():
    ds = SyntheticLM(vocab_size=64, seq_len=12, global_batch=2)
    b = ds.global_batch_at(0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])


def test_learnable_structure():
    """Next token is a (mostly) deterministic function of hidden state —
    a bigram table should beat uniform entropy by a wide margin."""
    ds = SyntheticLM(vocab_size=64, seq_len=256, global_batch=4)
    b = ds.global_batch_at(0)
    x = b["inputs"].reshape(-1)
    y = b["targets"].reshape(-1)
    table = {}
    for xi, yi in zip(x, y):
        table.setdefault(int(xi), {}).setdefault(int(yi), 0)
        table[int(xi)][int(yi)] += 1
    correct = sum(max(c.values()) for c in table.values())
    assert correct / len(x) > 0.25      # >> 1/64 uniform
