"""Linked dispatch path: linked == interpreted == fused (bit-identical),
scratch free-list correctness, and the core/opt.py peephole rules."""
import numpy as np
import pytest

import jax

from repro.core import linker, opt, rbl, rctc, rimfs
from repro.core.executor import Executor
from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc


def _vocab_program():
    """Touch every dispatchable op family once, incl. the fused slots."""
    t = {
        "x": TensorDesc("x", (4, 8, 8, 3), "float32", "input"),
        "w": TensorDesc("w", (3, 3, 3, 4), "float32", "weight"),
        "scale": TensorDesc("scale", (4,), "float32", "weight"),
        "shift": TensorDesc("shift", (4,), "float32", "weight"),
        "fcw": TensorDesc("fcw", (4, 6), "float32", "weight"),
        "fcb": TensorDesc("fcb", (6,), "float32", "weight"),
        "t1": TensorDesc("t1", (4, 8, 8, 4), "float32", "scratch"),
        "t2": TensorDesc("t2", (4, 8, 8, 4), "float32", "scratch"),
        "t2b": TensorDesc("t2b", (4, 8, 8, 4), "float32", "scratch"),
        "t3": TensorDesc("t3", (4, 4, 4, 4), "float32", "scratch"),
        "t4": TensorDesc("t4", (4, 4), "float32", "scratch"),
        "t4q": TensorDesc("t4q", (4, 4), "int8", "scratch"),
        "t4d": TensorDesc("t4d", (4, 4), "float32", "scratch"),
        "t4r": TensorDesc("t4r", (2, 8), "float32", "scratch"),
        "t4c": TensorDesc("t4c", (2, 8), "float32", "scratch"),
        "t4u": TensorDesc("t4u", (4, 4), "float32", "scratch"),
        "zero": TensorDesc("zero", (1,), "float32", "scratch"),
        "ta": TensorDesc("ta", (2, 2), "float32", "scratch"),
        "t5": TensorDesc("t5", (4, 6), "float32", "scratch"),
        "t6": TensorDesc("t6", (4, 6), "float32", "scratch"),
        "out": TensorDesc("out", (4, 6), "float32", "output"),
    }
    ops = [
        RCBOp(Op.NOP),
        RCBOp(Op.ALLOC, ("ta",), (), {"shape": [2, 2],
                                      "dtype": "float32"}),
        RCBOp(Op.FREE, ("ta",)),
        RCBOp(Op.BIND_CONST, ("zero",), (), {"value": [0.0]}),
        RCBOp(Op.CONV2D, ("t1",), ("x", "w"), {"stride": [1, 1],
                                               "padding": "SAME"}),
        RCBOp(Op.SCALE_SHIFT_RELU, ("t2",), ("t1", "scale", "shift")),
        RCBOp(Op.ADD_RELU, ("t2b",), ("t2", "t2")),
        RCBOp(Op.MAXPOOL, ("t3",), ("t2b",), {"window": [2, 2],
                                              "stride": [2, 2]}),
        RCBOp(Op.AVGPOOL_GLOBAL, ("t4",), ("t3",)),
        RCBOp(Op.QUANTIZE, ("t4q",), ("t4",), {"scale": 0.01}),
        RCBOp(Op.DEQUANT, ("t4d",), ("t4q",), {"scale": 0.01}),
        RCBOp(Op.RESHAPE, ("t4r",), ("t4d",), {"shape": [2, 8]}),
        RCBOp(Op.COLLECTIVE, ("t4c",), ("t4r",), {"kind": "all_reduce"}),
        RCBOp(Op.RESHAPE, ("t4u",), ("t4c",), {"shape": [4, 4]}),
        RCBOp(Op.DENSE, ("t5",), ("t4u", "fcw", "fcb")),
        RCBOp(Op.SOFTMAX, ("t6",), ("t5",)),
        RCBOp(Op.PASSTHROUGH, ("out",), ("t6",)),
        RCBOp(Op.POLL, (), ("out",)),
        RCBOp(Op.FENCE),
        RCBOp(Op.HALT),
    ]
    return RCBProgram("vocab", t, [RCB(0, "layer", (), tuple(ops))])


def _weights(rng):
    return {
        "w": rng.randn(3, 3, 3, 4).astype(np.float32),
        "scale": rng.rand(4).astype(np.float32) + 0.5,
        "shift": rng.randn(4).astype(np.float32),
        "fcw": rng.randn(4, 6).astype(np.float32),
        "fcb": rng.randn(6).astype(np.float32),
    }


def test_linked_equals_interpreted_full_vocab(rng):
    prog = _vocab_program()
    fs = rimfs.mount(rimfs.pack(_weights(rng)))
    x = rng.randn(4, 8, 8, 3).astype(np.float32)
    ex = Executor()
    bound_i = rbl.bind(prog, rimfs=fs, inputs={"x": x})
    out_i = np.asarray(ex.run_interpreted(bound_i)["out"])
    bound_l = rbl.bind(prog, rimfs=fs, inputs={"x": x})
    out_l = np.asarray(jax.block_until_ready(ex.run(bound_l)["out"]))
    np.testing.assert_array_equal(out_i, out_l)       # bit-identical


def test_linked_equals_fused_full_vocab(rng):
    prog = _vocab_program()
    fs = rimfs.mount(rimfs.pack(_weights(rng)))
    x = rng.randn(4, 8, 8, 3).astype(np.float32)
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs, inputs={"x": x})
    out_l = np.asarray(jax.block_until_ready(ex.run(bound)["out"]))
    bound2 = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound2)
    out_f = np.asarray(fused({"x": x}, ex.weights_from(bound2))["out"])
    np.testing.assert_array_equal(out_l, out_f)       # bit-identical


def test_free_lists_match_liveness(rng):
    prog = _vocab_program()
    fs = rimfs.mount(rimfs.pack(_weights(rng)))
    bound = rbl.bind(prog, rimfs=fs,
                     inputs={"x": rng.randn(4, 8, 8, 3)
                             .astype(np.float32)})
    ex = Executor()
    linked = ex.link(bound)
    # every scratch symbol that is read appears in exactly one free list
    released = [linked.names[i] for fl in linked.free_lists for i in fl]
    assert len(released) == len(set(released))
    read = {s for op in prog.ops() for s in op.srcs}
    scratch_read = {n for n, t in prog.tensors.items()
                    if t.kind == "scratch" and n in read}
    assert set(released) == scratch_read
    # and at the thunk of its LAST use, per the RBL liveness plan
    last = rbl.liveness(prog)
    thunk_ops = [m.op for m in linked.metas]
    for k, fl in enumerate(linked.free_lists):
        for i in fl:
            sym = linked.names[i]
            # find linear index of this thunk among the program ops
            assert thunk_ops[k] is not None
            # the symbol must be a source of the op this thunk executes
            srcs_of_thunk = [op for op in prog.ops()
                             if sym in op.srcs]
            assert srcs_of_thunk, sym
    # run to completion: all scratch released, outputs intact
    out = ex.run(bound)
    assert "out" in out


def test_linked_missing_input_raises(rng):
    prog = rctc.compile_matmul(8)
    img = rimfs.pack({"b": rng.randn(8, 8).astype(np.float32)})
    bound = rbl.bind(prog, rimfs=rimfs.mount(img))
    with pytest.raises(ValueError, match="missing input"):
        Executor().run(bound)


def test_linked_probe_matches_interpreted(rng):
    prog = rctc.compile_conv_relu_softmax(n=1, h=8, w=8, cin=3, cout=9)
    w = rng.randn(3, 3, 3, 9).astype(np.float32)
    fs = rimfs.mount(rimfs.pack({"w_conv": w}))
    x = rng.randn(1, 8, 8, 3).astype(np.float32)
    ex = Executor()
    p_lnk: dict = {}
    ex.run(rbl.bind(prog, rimfs=fs, inputs={"input": x}), probe=p_lnk)
    p_int: dict = {}
    ex.run_interpreted(rbl.bind(prog, rimfs=fs, inputs={"input": x}),
                       probe=p_int)
    assert set(p_lnk) == set(p_int)
    for k in p_int:
        np.testing.assert_allclose(p_lnk[k], p_int[k], rtol=1e-6)


def test_linked_graph_exec_artifact(rng):
    t = {
        "a": TensorDesc("a", (4,), "float32", "input"),
        "y": TensorDesc("y", (4,), "float32", "output"),
    }
    prog = RCBProgram(
        "g", t, [RCB(0, "layer", (),
                     (RCBOp(Op.GRAPH_EXEC, ("y",), ("a",),
                            {"artifact": "double"}),))],
        artifacts={"double": lambda a: a * 2})
    a = rng.randn(4).astype(np.float32)
    out = Executor().run(rbl.bind(prog, inputs={"a": a}))
    np.testing.assert_allclose(np.asarray(out["y"]), a * 2)


# ---------------------------------------------------------------------------
# Peephole pass (core/opt.py)
# ---------------------------------------------------------------------------

def test_opt_fuses_and_is_bit_identical(rng):
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    from repro.models import resnet as rn
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    folded = rn.fold_bn(params)
    raw, image = rctc.compile_resnet18(cfg, folded, batch=1,
                                       optimize=False)
    optd, _ = rctc.compile_resnet18(cfg, folded, batch=1, optimize=True)
    n_raw, n_opt = opt.op_count(raw), opt.op_count(optd)
    assert n_opt <= n_raw * 0.85, (n_raw, n_opt)      # >= 15% reduction
    assert any(op.op is Op.SCALE_SHIFT_RELU for op in optd.ops())
    assert any(op.op is Op.ADD_RELU for op in optd.ops())
    fs = rimfs.mount(image)
    x = rng.rand(1, cfg.image_size, cfg.image_size, 3).astype(np.float32)
    ex = Executor()
    o_raw = np.asarray(jax.block_until_ready(
        ex.run(rbl.bind(raw, rimfs=fs, inputs={"input": x}))["output"]))
    o_opt = np.asarray(jax.block_until_ready(
        ex.run(rbl.bind(optd, rimfs=fs, inputs={"input": x}))["output"]))
    np.testing.assert_array_equal(o_raw, o_opt)       # bit-identical


def test_opt_dequant_quantize_elision_exact():
    """E1 fires when the int8 source provably came from a clipping
    QUANTIZE, where the round-trip reproduces the input bits."""
    t = {
        "x": TensorDesc("x", (8,), "float32", "input"),
        "q": TensorDesc("q", (8,), "int8", "scratch"),
        "f": TensorDesc("f", (8,), "float32", "scratch"),
        "q2": TensorDesc("q2", (8,), "int8", "output"),
    }
    ops = [RCBOp(Op.QUANTIZE, ("q",), ("x",), {"scale": 0.125}),
           RCBOp(Op.DEQUANT, ("f",), ("q",), {"scale": 0.125}),
           RCBOp(Op.QUANTIZE, ("q2",), ("f",), {"scale": 0.125})]
    prog = RCBProgram("rt", t, [RCB(0, "layer", (), tuple(ops))])
    optd = opt.optimize(prog)
    kinds = [op.op for op in optd.ops()]
    assert kinds == [Op.QUANTIZE, Op.PASSTHROUGH]
    assert "f" not in optd.tensors                    # dead scratch dropped
    x = np.linspace(-20, 20, 8).astype(np.float32)
    out_o = np.asarray(Executor().run(rbl.bind(optd,
                                               inputs={"x": x}))["q2"])
    out_r = np.asarray(Executor().run(rbl.bind(prog,
                                               inputs={"x": x}))["q2"])
    np.testing.assert_array_equal(out_o, out_r)       # bit-identical


def test_opt_dequant_quantize_unknown_provenance_gated():
    """An int8 INPUT may legally hold -128, which the round-trip would
    re-clip to -127 — so E1 must not fire without ``lossy=True``."""
    t = {
        "q": TensorDesc("q", (8,), "int8", "input"),
        "f": TensorDesc("f", (8,), "float32", "scratch"),
        "q2": TensorDesc("q2", (8,), "int8", "output"),
    }
    ops = [RCBOp(Op.DEQUANT, ("f",), ("q",), {"scale": 0.125}),
           RCBOp(Op.QUANTIZE, ("q2",), ("f",), {"scale": 0.125})]
    prog = RCBProgram("rt2", t, [RCB(0, "layer", (), tuple(ops))])
    assert opt.op_count(opt.optimize(prog)) == 2
    assert opt.op_count(opt.optimize(prog, lossy=True)) == 1


def test_linked_poll_releases_scratch(rng):
    """A scratch symbol whose LAST reader is a POLL op must still be
    released by the linked path (free-list chained onto the POLL thunk)."""
    t = {
        "x": TensorDesc("x", (4,), "float32", "input"),
        "s": TensorDesc("s", (4,), "float32", "scratch"),
        "y": TensorDesc("y", (4,), "float32", "output"),
    }
    ops = [RCBOp(Op.RELU, ("s",), ("x",)),
           RCBOp(Op.PASSTHROUGH, ("y",), ("x",)),
           RCBOp(Op.POLL, (), ("s",))]
    prog = RCBProgram("poll", t, [RCB(0, "layer", (), tuple(ops))])
    bound = rbl.bind(prog, inputs={"x": np.ones(4, np.float32)})
    ex = Executor()
    linked = ex.link(bound)
    released = [linked.names[i] for fl in linked.free_lists for i in fl]
    assert released == ["s"]
    assert "y" in ex.run(bound)


def test_opt_quantize_dequant_stays_without_lossy():
    t = {
        "x": TensorDesc("x", (8,), "float32", "input"),
        "q": TensorDesc("q", (8,), "int8", "scratch"),
        "y": TensorDesc("y", (8,), "float32", "output"),
    }
    ops = [RCBOp(Op.QUANTIZE, ("q",), ("x",), {"scale": 0.5}),
           RCBOp(Op.DEQUANT, ("y",), ("q",), {"scale": 0.5})]
    prog = RCBProgram("qd", t, [RCB(0, "layer", (), tuple(ops))])
    assert opt.op_count(opt.optimize(prog)) == 2       # lossy rule gated
    assert opt.op_count(opt.optimize(prog, lossy=True)) == 1


def test_opt_dead_op_elimination():
    t = {
        "x": TensorDesc("x", (4,), "float32", "input"),
        "dead1": TensorDesc("dead1", (4,), "float32", "scratch"),
        "dead2": TensorDesc("dead2", (4,), "float32", "scratch"),
        "y": TensorDesc("y", (4,), "float32", "output"),
    }
    ops = [RCBOp(Op.RELU, ("dead1",), ("x",)),
           RCBOp(Op.RELU, ("dead2",), ("dead1",)),    # cascades
           RCBOp(Op.PASSTHROUGH, ("y",), ("x",))]
    prog = RCBProgram("dead", t, [RCB(0, "layer", (), tuple(ops))])
    optd = opt.optimize(prog)
    assert [op.op for op in optd.ops()] == [Op.PASSTHROUGH]
    assert "dead1" not in optd.tensors and "dead2" not in optd.tensors


def test_opt_dma_coalescing():
    t = {
        "x": TensorDesc("x", (4,), "float32", "input"),
        "d1": TensorDesc("d1", (4,), "float32", "scratch"),
        "d2": TensorDesc("d2", (4,), "float32", "scratch"),
        "y": TensorDesc("y", (4,), "float32", "output"),
    }
    ops = [RCBOp(Op.DMA_H2D, ("d1",), ("x",)),
           RCBOp(Op.DMA_D2D, ("d2",), ("d1",)),
           RCBOp(Op.DMA_D2H, ("y",), ("d2",))]
    prog = RCBProgram("dma", t, [RCB(0, "layer", (), tuple(ops))])
    optd = opt.optimize(prog)
    assert opt.op_count(optd) < 3
    x = np.arange(4, dtype=np.float32)
    out_o = np.asarray(Executor().run(rbl.bind(optd, inputs={"x": x}))["y"])
    out_r = np.asarray(Executor().run(rbl.bind(prog, inputs={"x": x}))["y"])
    np.testing.assert_array_equal(out_o, out_r)


def test_opt_preserves_outputs_and_multiuse():
    """An intermediate read twice must NOT be fused away."""
    t = {
        "x": TensorDesc("x", (4,), "float32", "input"),
        "s": TensorDesc("s", (4,), "float32", "weight"),
        "b": TensorDesc("b", (4,), "float32", "weight"),
        "m": TensorDesc("m", (4,), "float32", "scratch"),
        "r": TensorDesc("r", (4,), "float32", "scratch"),
        "y": TensorDesc("y", (4,), "float32", "output"),
    }
    ops = [RCBOp(Op.SCALE_SHIFT, ("m",), ("x", "s", "b")),
           RCBOp(Op.RELU, ("r",), ("m",)),
           RCBOp(Op.ADD, ("y",), ("r", "m"))]         # m read again
    prog = RCBProgram("mu", t, [RCB(0, "layer", (), tuple(ops))])
    optd = opt.optimize(prog)
    assert Op.SCALE_SHIFT in [op.op for op in optd.ops()]
