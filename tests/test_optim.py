"""Optimizer + schedule unit tests."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, init_params
from repro.optim.adamw import AdamWConfig, adamw_init_specs, adamw_update, \
    global_norm
from repro.optim.schedules import cosine_warmup


def _setup():
    specs = {"w": ParamSpec((8, 8), "float32", (None, None)),
             "b": ParamSpec((8,), "float32", (None,), "zeros")}
    params = init_params(jax.random.PRNGKey(0), specs)
    opt = init_params(jax.random.PRNGKey(1), adamw_init_specs(specs))
    return specs, params, opt


def test_adamw_minimizes_quadratic():
    specs, params, opt = _setup()
    target = jax.tree.map(lambda a: jnp.ones_like(a) * 0.3, params)

    def loss_fn(p):
        return sum(jnp.sum((a - t) ** 2)
                   for a, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    cfg = AdamWConfig(weight_decay=0.0)
    l0 = float(loss_fn(params))
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params,
                                      jnp.asarray(0.05))
    assert float(loss_fn(params)) < 0.01 * l0


def test_grad_clip_bounds_update():
    specs, params, opt = _setup()
    huge = jax.tree.map(lambda a: jnp.full_like(a, 1e6), params)
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    _, _, m = adamw_update(cfg, huge, opt, params, jnp.asarray(1e-3))
    clipped_norm = float(m["grad_norm"] * m["clip_scale"])
    assert clipped_norm <= 1.0 + 1e-4


def test_global_norm():
    t = {"a": jnp.ones((3,)) * 2.0, "b": jnp.zeros((4,))}
    assert np.isclose(float(global_norm(t)), np.sqrt(12.0))


def test_cosine_warmup_shape():
    xs = [float(cosine_warmup(jnp.asarray(s), 1e-3, 10, 100))
          for s in range(0, 100, 5)]
    assert xs[0] < xs[1]                       # warming up
    assert max(xs) <= 1e-3 + 1e-9
    assert xs[-1] < xs[3]                      # decaying
    assert xs[-1] >= 1e-4 - 1e-9               # min_ratio floor


def test_moments_sharded_like_params():
    specs = {"w": ParamSpec((64, 128), "bfloat16", ("fsdp", "mlp"))}
    st = adamw_init_specs(specs)
    # fsdp renames to opt_shard: same placement under default rules, but
    # ZeRO-1 can replicate params while keeping moments sharded (§Perf H3)
    assert st.m["w"].axes == ("opt_shard", "mlp")
    assert st.m["w"].dtype == "float32"        # fp32 master moments
