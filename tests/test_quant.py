"""INT8 quantization properties (hypothesis-driven)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dependency
from hypothesis import given, settings, strategies as st

from repro.core.quant import per_channel_scales, quantize_weight


@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 8),
       st.floats(0.01, 100.0))
@settings(max_examples=60, deadline=None)
def test_quant_error_bounded_by_half_step(kh, kw, cout, magnitude):
    rng = np.random.RandomState(kh * 31 + kw * 7 + cout)
    w = (rng.randn(kh, kw, 3, cout) * magnitude).astype(np.float32)
    s = per_channel_scales(w)
    q = quantize_weight(w, s)
    deq = q.astype(np.float32) * s.reshape(1, 1, 1, -1)
    # symmetric PTQ: |w - deq| <= scale/2 per channel (no clipping occurs
    # because scale = amax/127)
    err = np.abs(w - deq)
    bound = s.reshape(1, 1, 1, -1) / 2 + 1e-7
    assert np.all(err <= bound)


@given(st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_scales_positive_and_cover_amax(cout):
    rng = np.random.RandomState(cout)
    w = rng.randn(3, 3, 2, cout).astype(np.float32)
    s = per_channel_scales(w)
    assert np.all(s > 0)
    q = quantize_weight(w, s)
    assert q.dtype == np.int8
    assert np.all(np.abs(q) <= 127)


def test_zero_weight_channel_safe():
    w = np.zeros((3, 3, 2, 4), np.float32)
    s = per_channel_scales(w)
    q = quantize_weight(w, s)
    assert np.all(q == 0) and np.all(np.isfinite(s))
