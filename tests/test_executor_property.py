"""Property test: RANDOM RCB programs execute identically in eager and
fused modes — the strongest form of the paper's portability claim (the same
control stream drives both execution environments, for *any* program in the
op vocabulary, not just hand-picked ones)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dependency
from hypothesis import given, settings, strategies as st

from repro.core import rbl, rimfs
from repro.core.executor import Executor
from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc


def _build_random_program(draw_ops, rng):
    """A random straight-line tensor program over (4,6)-shaped f32 buffers.

    Each step applies a random unary/binary op to previously defined
    symbols; the final symbol becomes the output.
    """
    tensors = {
        "in0": TensorDesc("in0", (4, 6), "float32", "input"),
        "w0": TensorDesc("w0", (6, 6), "float32", "weight"),
    }
    syms = ["in0"]
    ops = []
    for i, choice in enumerate(draw_ops):
        src = syms[choice % len(syms)]
        dst = f"t{i}"
        kind = choice % 4
        if kind == 0:
            tensors[dst] = TensorDesc(dst, (4, 6), "float32", "scratch")
            ops.append(RCBOp(Op.RELU, (dst,), (src,)))
        elif kind == 1:
            tensors[dst] = TensorDesc(dst, (4, 6), "float32", "scratch")
            ops.append(RCBOp(Op.SOFTMAX, (dst,), (src,), {"axis": -1}))
        elif kind == 2:
            other = syms[(choice // 4) % len(syms)]
            tensors[dst] = TensorDesc(dst, (4, 6), "float32", "scratch")
            ops.append(RCBOp(Op.ADD, (dst,), (src, other)))
        else:
            tensors[dst] = TensorDesc(dst, (4, 6), "float32", "scratch")
            ops.append(RCBOp(Op.GEMM, (dst,), (src, "w0")))
        syms.append(dst)
    out = syms[-1]
    tensors[out] = TensorDesc(out, tensors[out].shape, "float32", "output")
    prog = RCBProgram("rand", tensors, [RCB(0, "layer", (), tuple(ops))])
    prog.validate()
    return prog


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=12),
       st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_random_programs_eager_equals_fused(draw_ops, seed):
    rng = np.random.RandomState(seed)
    prog = _build_random_program(draw_ops, rng)
    w = rng.randn(6, 6).astype(np.float32) * 0.5
    x = rng.randn(4, 6).astype(np.float32)
    fs = rimfs.mount(rimfs.pack({"w0": w}))
    ex = Executor()

    bound = rbl.bind(prog, rimfs=fs, inputs={"in0": x})
    out_name = next(n for n, t in prog.tensors.items() if t.kind == "output")
    eager = np.asarray(ex.run(bound)[out_name])

    bound2 = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound2)
    out = fused({"in0": x}, ex.weights_from(bound2))[out_name]
    np.testing.assert_allclose(eager, np.asarray(out), rtol=1e-5, atol=1e-5)

    # control-as-data: the binary roundtrip of the same random program
    # still validates and produces identical eager results
    prog2 = RCBProgram.decode(prog.encode())
    bound3 = rbl.bind(prog2, rimfs=fs, inputs={"in0": x})
    eager2 = np.asarray(ex.run(bound3)[out_name])
    np.testing.assert_array_equal(eager, eager2)
