"""The Pallas flash-attention kernel wired into the real model stack must
reproduce the jnp attention path (full model forward, interpret mode)."""
import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def test_model_forward_flash_vs_jnp():
    # subprocess so the env toggle can't leak into other tests
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    script = textwrap.dedent("""
    import os
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.models.common import init_params

    cfg = get_config("qwen3-14b-smoke")        # full attention, GQA, qk_norm
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    x = jnp.asarray(np.random.RandomState(0)
                    .randint(0, cfg.vocab_size, (2, 64)))

    os.environ["AEG_ATTN_IMPL"] = "jnp"
    ref, _, _ = tf.forward_full(cfg, params, x)

    os.environ["AEG_ATTN_IMPL"] = "flash"
    out, _, _ = tf.forward_full(cfg, params, x)

    err = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                - out.astype(jnp.float32))))
    assert err < 5e-4, err
    print("ok", err)
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
