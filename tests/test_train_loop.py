"""End-to-end training: loss goes down; checkpoint/restart is bit-exact."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.optim.adamw import adamw_init_specs


def _mk(seed=0):
    cfg = get_config("qwen2-1.5b-smoke")
    specs = tf.model_specs(cfg)
    params = init_params(jax.random.PRNGKey(seed), specs)
    opt = init_params(jax.random.PRNGKey(seed + 1), adamw_init_specs(specs))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    step = jax.jit(make_train_step(cfg, peak_lr=5e-3, warmup=5,
                                   total_steps=300))
    return cfg, params, opt, ds, step


def _batch(ds, i):
    b = ds.global_batch_at(i)
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases():
    # init is already near ln(V) (sane 1/sqrt(d) embed init), so the drop
    # toward the generator's structural entropy is gradual
    cfg, params, opt, ds, step = _mk()
    losses = []
    for i in range(80):
        params, opt, m = step(params, opt, _batch(ds, i))
        losses.append(float(m["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert np.isfinite(last)
    assert last < first - 0.5, (first, last)


def test_checkpoint_restart_bit_exact(tmp_path):
    """Kill-and-restart at step 10 must reproduce the uninterrupted run."""
    cfg, params, opt, ds, step = _mk()
    mgr = CheckpointManager(tmp_path, async_save=False)

    # uninterrupted run: 20 steps
    p, o = params, opt
    for i in range(20):
        p, o, _ = step(p, o, _batch(ds, i))
    ref = jax.tree.leaves(p)

    # interrupted run: 10 steps, checkpoint, "crash", restore, 10 more
    p2, o2 = params, opt
    for i in range(10):
        p2, o2, _ = step(p2, o2, _batch(ds, i))
    mgr.save({"params": p2, "opt": o2}, step=10)
    del p2, o2                                     # crash
    state, step_no, _ = mgr.restore_latest(
        {"params": params, "opt": opt})
    assert step_no == 10
    p3, o3 = state["params"], state["opt"]
    for i in range(10, 20):
        p3, o3, _ = step(p3, o3, _batch(ds, i))

    for a, b in zip(ref, jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-6)
