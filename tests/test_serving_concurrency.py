"""Concurrent network serving: protocol v2 pipelining, single-dispatcher
ownership, backpressure/shed replies, malformed-frame handling, graceful
drain — the many-clients scenario class.

Determinism contract (ISSUE 4): N client threads x M pipelined requests
against one server produce bit-identical outputs to the same requests run
serially, with zero dropped or garbled frames.
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest

import jax

from repro.configs.resnet18 import CONFIG as RESNET
from repro.core import rctc
from repro.models import resnet as rn
from repro.serving import protocol as proto
from repro.serving.scheduler import DeadlineScheduler
from repro.serving.server import (Client, InferenceServer, RequestShed,
                                  ServerBusy)


@pytest.fixture(scope="module")
def resnet_setup():
    cfg = RESNET.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    return cfg, prog, image


def _input(cfg, seed: int) -> np.ndarray:
    r = np.random.RandomState(seed)
    return r.rand(1, cfg.image_size, cfg.image_size, 3).astype(np.float32)


def _start(prog, image, **kw):
    server = InferenceServer(**kw)
    addr = server.start()
    client = Client(addr)
    client.provision(image, prog.encode())
    return server, addr, client


# ---------------------------------------------------------------- pipelining
def test_pipelined_multiclient_bit_identical(resnet_setup):
    """4 concurrent connections x 3 pipelined requests each == the same 12
    requests run serially, bit for bit."""
    cfg, prog, image = resnet_setup
    n_clients, per_client = 4, 3
    inputs = {(c, i): _input(cfg, 100 * c + i)
              for c in range(n_clients) for i in range(per_client)}
    server, addr, client = _start(prog, image)
    try:
        serial = {k: client.infer(input=v)["output"]
                  for k, v in sorted(inputs.items())}

        results: dict = {}
        errors: list = []

        def worker(c: int) -> None:
            cl = Client(addr)
            try:
                rids = [(i, cl.infer_async(input=inputs[(c, i)]))
                        for i in range(per_client)]
                for i, rid in reversed(rids):       # out-of-order collection
                    results[(c, i)] = cl.result(rid)["output"]
            except Exception as e:                  # pragma: no cover
                errors.append(e)
            finally:
                cl.close()

        threads = [threading.Thread(target=worker, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert set(results) == set(inputs)          # zero dropped frames
        for k in inputs:
            np.testing.assert_array_equal(results[k], serial[k])
    finally:
        client.close()
        server.stop()


def test_interleaved_request_ids_one_connection(resnet_setup):
    """One connection pipelines 6 requests and collects the responses in a
    scrambled order — request ids route every response to its waiter."""
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image)
    try:
        xs = [_input(cfg, 50 + i) for i in range(6)]
        refs = [client.infer(input=x)["output"] for x in xs]
        rids = [client.infer_async(input=x) for x in xs]
        order = [3, 0, 5, 1, 4, 2]
        got: dict = {}
        for j in order:
            got[j] = client.result(rids[j])["output"]
        for j in range(6):
            np.testing.assert_array_equal(got[j], refs[j])
    finally:
        client.close()
        server.stop()


def test_midstream_provision_does_not_corrupt_inflight(resnet_setup):
    """A PROVISION racing pipelined INFERs serializes behind the
    dispatcher: in-flight inferences stay bit-identical and requests after
    the re-provision still serve."""
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image)
    other = Client(addr)
    try:
        xs = [_input(cfg, 200 + i) for i in range(4)]
        refs = [client.infer(input=x)["output"] for x in xs]
        rids = [client.infer_async(input=x) for x in xs[:2]]
        status = other.provision(image, prog.encode())   # mid-stream
        rids += [client.infer_async(input=x) for x in xs[2:]]
        assert status["status"] == "ready"
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(client.result(rid)["output"], ref)
    finally:
        other.close()
        client.close()
        server.stop()


def test_v1_client_backcompat(resnet_setup):
    """A legacy v1 (rid-less) client still provisions and infers."""
    cfg, prog, image = resnet_setup
    server = InferenceServer()
    addr = server.start()
    client = Client(addr, version=1)
    try:
        assert client.provision(image, prog.encode())["status"] == "ready"
        x = _input(cfg, 7)
        out = client.infer(input=x)["output"]
        v2 = Client(addr)
        np.testing.assert_array_equal(out, v2.infer(input=x)["output"])
        v2.close()
        assert "serving" in client.telemetry()
    finally:
        client.close()
        server.stop()


# ----------------------------------------------------- malformed frames
def test_bad_magic_gets_error_reply_and_clean_close(resnet_setup):
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image)
    try:
        s = socket.create_connection(addr)
        s.sendall(b"XXXX" + bytes([int(proto.Msg.INFER_REQUEST)])
                  + struct.pack("<I", 4))
        f = proto.recv_frame_ex(s)
        assert f.kind == proto.Msg.ERROR
        assert "protocol" in proto.unpack_json(f.payload)["error"]
        s.settimeout(5)
        assert s.recv(1) == b""                     # clean close
        s.close()
        # the handler death is contained: the server still serves
        x = _input(cfg, 9)
        assert client.infer(input=x)["output"].shape[0] == 1
    finally:
        client.close()
        server.stop()


def test_corrupted_crc_gets_error_reply_and_clean_close(resnet_setup):
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image)
    try:
        frame = bytearray(proto.encode_frame(proto.Msg.INFER_REQUEST,
                                             b"x" * 64))
        frame[20] ^= 0xFF                           # corrupt the payload
        s = socket.create_connection(addr)
        s.sendall(bytes(frame))
        f = proto.recv_frame_ex(s)
        assert f.kind == proto.Msg.ERROR
        assert "protocol" in proto.unpack_json(f.payload)["error"]
        s.settimeout(5)
        assert s.recv(1) == b""
        s.close()
        x = _input(cfg, 10)
        assert client.infer(input=x)["output"].shape[0] == 1
    finally:
        client.close()
        server.stop()


# ------------------------------------------------------------ length cap
def test_recv_frame_length_cap_rejects_before_allocation():
    a, b = socket.socketpair()
    try:
        b.sendall(proto.HEADER.pack(proto.MAGIC,
                                    int(proto.Msg.INFER_REQUEST),
                                    0xFFFF_FFF0))
        with pytest.raises(proto.ProtocolError, match="MAX_FRAME"):
            proto.recv_frame_ex(a, max_frame=1 << 10)
    finally:
        a.close()
        b.close()


def test_server_enforces_max_frame(resnet_setup):
    cfg, prog, image = resnet_setup
    server = InferenceServer(max_frame=1 << 16)
    addr = server.start()
    try:
        s = socket.create_connection(addr)
        s.sendall(proto.HEADER.pack(proto.MAGIC,
                                    int(proto.Msg.INFER_REQUEST), 1 << 20))
        f = proto.recv_frame_ex(s)
        assert f.kind == proto.Msg.ERROR
        assert "MAX_FRAME" in proto.unpack_json(f.payload)["error"]
        s.settimeout(5)
        assert s.recv(1) == b""
        s.close()
    finally:
        server.stop()


def test_provision_inner_frames_honor_server_cap(resnet_setup, monkeypatch):
    """The inner image/program frames of PROVISION are decoded under the
    server's configured cap, not the module default."""
    cfg, prog, image = resnet_setup
    monkeypatch.setattr(proto, "MAX_FRAME", 1 << 10)   # shrink the default
    server = InferenceServer(max_frame=64 << 20)       # explicit larger cap
    addr = server.start()
    client = Client(addr, max_frame=64 << 20)
    try:
        # image/program are far beyond 1 KiB: only the explicit cap admits
        assert client.provision(image, prog.encode())["status"] == "ready"
    finally:
        client.close()
        server.stop()


def test_route_send_timeout_isolates_slow_reader():
    """A peer that never reads cannot block a sender forever: the route's
    send timeout trips and the route is marked dead."""
    from repro.serving.server import _Route
    a, b = socket.socketpair()
    try:
        a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 4096)
        b.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
        route = _Route(a, send_timeout=0.2)
        ok = route.send(proto.Msg.INFER_RESPONSE, b"x" * (1 << 22))
        assert not ok and not route.alive
        route.close()
    finally:
        a.close()
        b.close()


def test_client_waiters_all_error_on_dead_connection():
    """When the connection dies, parked waiters error out too — nobody
    waits forever on a response that cannot arrive."""
    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    client = Client(lst.getsockname())
    conn, _ = lst.accept()
    errors = []

    def wait_on(rid):
        try:
            client.result(rid)
        except (ConnectionError, OSError) as e:
            errors.append(e)

    threads = [threading.Thread(target=wait_on, args=(r,)) for r in (1, 2)]
    for t in threads:
        t.start()
    time.sleep(0.2)
    conn.close()
    for t in threads:
        t.join(timeout=10)
    assert not any(t.is_alive() for t in threads)
    assert len(errors) == 2
    client.close()
    lst.close()


# --------------------------------------------------- client error handling
def test_client_provision_raises_on_error_frame():
    server = InferenceServer()
    addr = server.start()
    client = Client(addr)
    try:
        with pytest.raises(RuntimeError):
            client.provision(b"garbage-image", b"garbage-program")
    finally:
        client.close()
        server.stop()


def test_client_telemetry_raises_on_error_frame():
    server = InferenceServer()
    addr = server.start()
    client = Client(addr)
    try:
        def boom(**kw):
            raise RuntimeError("telemetry exploded")
        server.platform.telemetry.summary = boom
        with pytest.raises(RuntimeError, match="telemetry exploded"):
            client.telemetry()
    finally:
        client.close()
        server.stop()


# ------------------------------------------------------------ backpressure
def _gate_dispatcher(server):
    """Hold the dispatcher worker at its next item (and keep the idle
    hook from draining around the gate); returns (gate, started)."""
    gate, started = threading.Event(), threading.Event()
    inner = server._loop.handler
    idle = server._loop.on_idle

    def gated(item):
        started.set()
        gate.wait(30)
        inner(item)

    server._loop.handler = gated
    server._loop.on_idle = lambda: idle() if gate.is_set() else False
    return gate, started


def test_backpressure_busy_replies(resnet_setup):
    """Bounded admission queue: overflow gets an immediate ERROR/F_BUSY
    instead of unbounded buffering (or a hang)."""
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image, max_queue=1)
    try:
        gate, started = _gate_dispatcher(server)
        x = _input(cfg, 11)
        rid1 = client.infer_async(input=x)      # admitted, kick gated
        assert started.wait(10)
        rid2 = client.infer_async(input=x)      # admission queue full
        rid3 = client.infer_async(input=x)
        with pytest.raises(ServerBusy):
            client.result(rid2)
        with pytest.raises(ServerBusy):
            client.result(rid3)
        gate.set()
        assert client.result(rid1)["output"].shape[0] == 1
        assert client.telemetry()["serving"]["rejected"] >= 2
    finally:
        client.close()
        server.stop()


def test_priority_reorders_backlogged_requests(resnet_setup):
    """With the dispatcher backlogged, a later high-priority request is
    admitted (and executed) before an earlier low-priority one.
    ``batch_window=1`` disables coalescing so the two requests provably
    execute as separate dispatches in EDF order (with the window open
    they would legally ride one batched dispatch instead)."""
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image, max_queue=8, batch_window=1)
    try:
        order = []
        inner_infer = server._infer

        def tracking(tensors):
            order.append(float(np.asarray(tensors["input"]).flat[0]))
            return inner_infer(tensors)

        server._infer = tracking
        gate, started = _gate_dispatcher(server)
        x_low = np.full((1, cfg.image_size, cfg.image_size, 3), 1.0,
                        np.float32)
        x_high = np.full((1, cfg.image_size, cfg.image_size, 3), 2.0,
                         np.float32)
        rid_low = client.infer_async(input=x_low, priority=9)
        assert started.wait(10)                 # worker gated on the kick
        rid_high = client.infer_async(input=x_high, priority=0)
        deadline = time.monotonic() + 10        # both requests enqueued
        while server.scheduler.pending() < 2 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert server.scheduler.pending() == 2
        gate.set()
        client.result(rid_low)
        client.result(rid_high)
        assert order == [2.0, 1.0]              # high priority ran first
    finally:
        client.close()
        server.stop()


def test_backlog_coalesces_into_batched_dispatch(resnet_setup):
    """A backlog of same-program, same-shape INFERs rides ONE batched
    dispatch (Executor.run_batched), with replies scattered back by
    request id and bit-identical to serial execution. A solo request
    must NOT count as a batched dispatch (the window never waits)."""
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image, max_queue=32)
    try:
        xs = [_input(cfg, 40 + i) for i in range(6)]
        refs = [client.infer(input=x)["output"] for x in xs]
        assert server.batched_stats["dispatches"] == 0   # solos stay solo

        gate, started = _gate_dispatcher(server)
        rids = [client.infer_async(input=x) for x in xs]
        assert started.wait(10)
        deadline = time.monotonic() + 10
        while server.scheduler.pending() < len(xs) - 1 and \
                time.monotonic() < deadline:
            time.sleep(0.005)
        gate.set()
        outs = [client.result(rid)["output"] for rid in rids]
        for out, ref in zip(outs, refs):
            np.testing.assert_array_equal(out, ref)
        st = server.batched_stats
        assert st["dispatches"] >= 1 and st["requests"] >= 2
        assert st["max_batch"] <= server.batch_window
        tel = client.telemetry()["serving"]["batched"]
        assert tel["dispatches"] == st["dispatches"]
    finally:
        client.close()
        server.stop()


def test_coalescing_disabled_over_tile_mesh(resnet_setup):
    """The partitioned path pipelines one sample per stage — a mesh-
    attached server must keep dispatching per-request (and still be
    bit-identical)."""
    from repro.core import rhal

    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image, mesh=rhal.TileMesh(2),
                                  max_queue=32)
    try:
        xs = [_input(cfg, 60 + i) for i in range(3)]
        refs = [client.infer(input=x)["output"] for x in xs]
        gate, started = _gate_dispatcher(server)
        rids = [client.infer_async(input=x) for x in xs]
        assert started.wait(10)
        gate.set()
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(client.result(rid)["output"],
                                          ref)
        assert server.batched_stats["dispatches"] == 0
    finally:
        client.close()
        server.stop()


# ---------------------------------------------------------------- shedding
def test_deadline_shed_reply_carries_verdict(resnet_setup):
    cfg, prog, image = resnet_setup
    # estimate is enormous: any deadline-carrying request is infeasible
    server, addr, client = _start(
        prog, image,
        scheduler=DeadlineScheduler(step_latency_estimate=100.0))
    try:
        x = _input(cfg, 12)
        with pytest.raises(RequestShed, match="shed"):
            client.infer(input=x, deadline_ms=1.0)
        # no-deadline requests are untouched by the shed policy
        assert client.infer(input=x)["output"].shape[0] == 1
        assert client.telemetry()["serving"]["shed"] == 1
    finally:
        client.close()
        server.stop()


def test_infer_after_shutdown_refused_not_hung(resnet_setup):
    """A plain INFER arriving after the dispatcher has drained away is
    refused explicitly (F_DRAINING) — it is never parked in the scheduler
    where nothing will ever answer it."""
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image)
    try:
        other = Client(addr)
        other.shutdown()
        other.close()
        deadline = time.monotonic() + 15
        while server._loop.alive() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert not server._loop.alive()
        with pytest.raises((ServerBusy, ConnectionError, OSError)):
            client.infer(input=_input(cfg, 40))
        assert server.scheduler.pending() == 0
    finally:
        client.close()
        server.stop()


def test_forced_stop_refuses_pending_admissions(resnet_setup):
    """stop(drain=False) still answers every accepted request: pending
    admissions get ERROR/F_DRAINING instead of a silent drop."""
    from repro.serving.scheduler import ScheduledRequest

    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image)
    try:
        sent = []

        class StubRoute:
            def send(self, kind, payload, rid=0, version=1, flags=0):
                sent.append((kind, flags, rid))
                return True

        server._loop.close(drain=True)           # park the dispatcher
        server.scheduler.submit(ScheduledRequest(
            rid=77, tokens_needed=1, payload=(StubRoute(), 77, 2, {})))
        server.stop(drain=False)
        assert sent == [(proto.Msg.ERROR, proto.F_DRAINING, 77)]
    finally:
        client.close()
        server.stop()


# ------------------------------------------------------------ graceful drain
def test_shutdown_drains_queued_requests(resnet_setup):
    cfg, prog, image = resnet_setup
    server, addr, client = _start(prog, image)
    try:
        xs = [_input(cfg, 300 + i) for i in range(5)]
        refs = [client.infer(input=x)["output"] for x in xs]
        rids = [client.infer_async(input=x) for x in xs]
        ack = client.shutdown()                 # queued work still answered
        assert ack["status"] == "draining"
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(client.result(rid)["output"], ref)
        deadline = time.monotonic() + 15
        while server._loop._thread.is_alive() and time.monotonic() < deadline:
            time.sleep(0.02)
        assert not server._loop._thread.is_alive()
    finally:
        client.close()
        server.stop()


# --------------------------------------------------------- partitioned path
def test_server_dispatches_over_tile_mesh(resnet_setup):
    """A server constructed with a TileMesh routes plain-RCB INFERs through
    the partitioned executor path, bit-identical to single-device serving,
    with real inter-tile movement accounted."""
    from repro.core import rhal

    cfg, prog, image = resnet_setup
    mesh = rhal.TileMesh(2)
    server, addr, client = _start(prog, image, mesh=mesh)
    single, saddr, sclient = _start(prog, image)
    try:
        x = _input(cfg, 13)
        out = client.infer(input=x)["output"]
        ref = sclient.infer(input=x)["output"]
        np.testing.assert_array_equal(out, ref)
        assert mesh.moved_bytes() > 0          # cut edges actually streamed
    finally:
        client.close()
        sclient.close()
        server.stop()
        single.stop()


# ------------------------------------------------------------- LM over wire
def _lm_setup(rng, **engine_kw):
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.models.common import init_params
    from repro.serving.engine import ServingEngine

    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    engine_kw.setdefault("max_batch", 2)
    engine_kw.setdefault("max_seq", 64)
    return cfg, params, ServingEngine(cfg, params, **engine_kw)


def test_lm_engine_over_network(rng):
    """INFER with a prompt routes through ServingEngine continuous
    batching; pipelined tokens match a local engine run token for token."""
    from repro.serving.engine import Request, ServingEngine

    cfg, params, eng = _lm_setup(rng)
    server = InferenceServer(engine=eng)
    addr = server.start()
    client = Client(addr)
    try:
        prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
                   for _ in range(3)]
        rids = [client.infer_async(prompt=p, max_new=3) for p in prompts]
        outs = [client.result(rid)["tokens"] for rid in rids]

        ref_eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
        refs = [Request(rid=i, prompt=p, max_new=3)
                for i, p in enumerate(prompts)]
        for r in refs:
            ref_eng.submit(r)
        ref_eng.run_until_drained()
        for out, r in zip(outs, refs):
            assert list(out) == r.out_tokens
    finally:
        client.close()
        server.stop()


def test_lm_inflight_cap_gives_backpressure(rng):
    """The engine path is bounded too: pipelining past the in-flight cap
    gets ERROR/F_BUSY instead of unbounded scheduler/inflight growth."""
    cfg, params, eng = _lm_setup(rng, max_batch=1)
    server = InferenceServer(engine=eng, max_queue=2)
    addr = server.start()
    client = Client(addr)
    try:
        prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
        rids = [client.infer_async(prompt=prompt, max_new=8)
                for _ in range(6)]
        tokens, busy = [], 0
        for rid in rids:
            try:
                tokens.append(list(client.result(rid)["tokens"]))
            except ServerBusy:
                busy += 1
        assert busy >= 1                       # cap enforced
        assert tokens                          # admitted ones complete...
        assert all(t == tokens[0] for t in tokens)   # ...identically
    finally:
        client.close()
        server.stop()


def test_lm_bad_prompt_rejected_engine_survives(rng):
    """An over-long prompt is refused with an ERROR before touching the
    engine; the dispatcher and engine keep serving afterwards."""
    cfg, params, eng = _lm_setup(rng)        # max_seq=64
    server = InferenceServer(engine=eng)
    addr = server.start()
    client = Client(addr)
    try:
        long_prompt = rng.randint(0, cfg.vocab_size, (62,)).astype(np.int32)
        with pytest.raises(RuntimeError, match="max_seq"):
            client.infer(prompt=long_prompt, max_new=8)
        ok = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
        assert len(client.infer(prompt=ok, max_new=3)["tokens"]) >= 3
    finally:
        client.close()
        server.stop()


def test_mixed_lm_and_rcb_requests_one_server(resnet_setup, rng):
    """A server with BOTH an engine and a provisioned RCB program routes
    each request by shape without cross-contaminating admission state."""
    cfg_r, prog, image = resnet_setup
    _, _, eng = _lm_setup(rng)
    server = InferenceServer(engine=eng)
    addr = server.start()
    client = Client(addr)
    try:
        client.provision(image, prog.encode())
        x = _input(cfg_r, 21)
        ref = client.infer(input=x)["output"]
        prompt = np.arange(6, dtype=np.int32)
        rid_lm = client.infer_async(prompt=prompt, max_new=3)
        rid_r = client.infer_async(input=x)
        toks = client.result(rid_lm)["tokens"]
        np.testing.assert_array_equal(client.result(rid_r)["output"], ref)
        assert len(toks) >= 3
    finally:
        client.close()
        server.stop()


# ----------------------------------------------------- integrity (ISSUE 7)
def test_client_result_timeout_on_never_replying_server():
    """Satellite: a request id orphaned by a server that never replies
    raises TimeoutError instead of parking the waiter forever."""
    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    client = Client(lst.getsockname())
    conn, _ = lst.accept()                  # accept, then go silent
    try:
        rid = client.infer_async(input=np.zeros(4, np.float32))
        t0 = time.monotonic()
        with pytest.raises(TimeoutError, match="no reply"):
            client.result(rid, timeout=0.4)
        assert time.monotonic() - t0 < 5.0  # bounded, not parked
        # the receive slot was handed back: a second waiter can still
        # time out too (a wedged slot would hang it forever)
        with pytest.raises(TimeoutError):
            client.result(rid + 1, timeout=0.2)
        # and infer(timeout=) surfaces the same thing end-to-end
        with pytest.raises(TimeoutError):
            client.infer(input=np.zeros(4, np.float32), timeout=0.2)
    finally:
        client.close()
        conn.close()
        lst.close()


def test_watchdog_preempts_hung_dispatch_end_to_end(rng):
    """ISSUE 7 tentpole: a dispatch wedged in a DMA redemption blows its
    EWMA-derived deadline, the watchdog kills the hung tile group
    (quarantining its arena), the stage fails over, and the client gets
    the bit-identical answer — a hang becomes bounded latency."""
    import chaos
    from repro.core import rhal, rimfs as rimfs_mod
    depth, n = 4, 16
    prog = rctc.compile_gemm_chain(depth, n)
    image = rimfs_mod.pack(rctc.gemm_chain_weights(depth, n))
    server = InferenceServer(mesh=rhal.TileMesh(2), watchdog_floor=0.3,
                             watchdog_slack=8.0, watchdog_poll=0.01)
    addr = server.start()
    client = Client(addr)
    try:
        client.provision(image, prog.encode())
        x = rng.randn(n, n).astype(np.float32)
        ref = client.infer(input=x)          # warms the scheduler EWMA
        undo, state = chaos.hang_until_killed(server.mesh, 1)
        try:
            out = client.infer(input=x, timeout=30)
        finally:
            undo()
        assert state["released"]             # the kill broke the wedge
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        assert server.platform.telemetry.counter(
            "watchdog_preemptions") >= 1
        assert not server.mesh.alive(1)      # hung group killed...
        assert server.mesh.group(1).driver.arena.poisoned   # ...poisoned
    finally:
        client.close()
        server.stop()
