"""Deadline scheduler: EDF ordering, shedding, latency-estimate tracking."""
from repro.serving.scheduler import DeadlineScheduler, ScheduledRequest


def _sched(est=0.01, t0=0.0):
    clock = {"t": t0}
    s = DeadlineScheduler(step_latency_estimate=est,
                          clock=lambda: clock["t"])
    return s, clock


def test_priority_then_edf_order():
    s, _ = _sched()
    s.submit(ScheduledRequest(1, tokens_needed=4, priority=2, deadline=1.0))
    s.submit(ScheduledRequest(2, tokens_needed=4, priority=1, deadline=9.0))
    s.submit(ScheduledRequest(3, tokens_needed=4, priority=1, deadline=0.5))
    admitted = s.admit(free_slots=3)
    assert [r.rid for r in admitted] == [3, 2, 1]


def test_infeasible_deadline_is_shed():
    s, clock = _sched(est=0.1)
    clock["t"] = 10.0
    s.submit(ScheduledRequest(1, tokens_needed=100, deadline=10.5))  # needs 10s
    s.submit(ScheduledRequest(2, tokens_needed=2, deadline=11.0))
    admitted = s.admit(free_slots=2)
    assert [r.rid for r in admitted] == [2]
    assert s.shed_count == 1


def test_no_deadline_always_feasible():
    s, _ = _sched()
    for i in range(5):
        s.submit(ScheduledRequest(i, tokens_needed=1000))
    assert len(s.admit(3)) == 3
    assert s.pending() == 2


def test_latency_ewma_moves_estimate():
    s, _ = _sched(est=0.01)
    for _ in range(50):
        s.observe_step_latency(0.05)
    assert abs(s.est - 0.05) < 5e-3


def test_shed_requests_drain_once_with_verdict():
    s, clock = _sched(est=1.0)
    clock["t"] = 5.0
    r = ScheduledRequest(1, tokens_needed=100, deadline=6.0, payload="me")
    s.submit(r)
    assert s.admit(free_slots=1) == []
    shed = s.drain_shed()
    assert shed == [r] and r.shed and not r.admitted
    assert "shed" in r.verdict and r.payload == "me"
    assert s.drain_shed() == []          # drained exactly once


def test_admitted_requests_carry_verdict():
    s, _ = _sched()
    r = ScheduledRequest(1, tokens_needed=2)
    s.submit(r)
    assert s.admit(free_slots=1) == [r]
    assert r.admitted and r.verdict == "admitted"


def test_concurrent_submit_admit_loses_nothing():
    """Producer threads submit while a dispatcher admits: every request
    comes out exactly once (admitted or shed), none vanish."""
    import threading
    s, _ = _sched()
    n_threads, per_thread = 4, 50
    out: list = []

    def produce(base):
        for i in range(per_thread):
            s.submit(ScheduledRequest(base + i, tokens_needed=1))

    threads = [threading.Thread(target=produce, args=(t * 1000,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    deadline = 200
    while len(out) < n_threads * per_thread and deadline:
        out.extend(s.admit(free_slots=7))
        deadline -= 1
    for t in threads:
        t.join()
    out.extend(s.admit(free_slots=n_threads * per_thread))
    rids = [r.rid for r in out]
    assert len(rids) == len(set(rids)) == n_threads * per_thread
