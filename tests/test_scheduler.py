"""Deadline scheduler: EDF ordering, shedding, latency-estimate tracking."""
from repro.serving.scheduler import DeadlineScheduler, ScheduledRequest


def _sched(est=0.01, t0=0.0):
    clock = {"t": t0}
    s = DeadlineScheduler(step_latency_estimate=est,
                          clock=lambda: clock["t"])
    return s, clock


def test_priority_then_edf_order():
    s, _ = _sched()
    s.submit(ScheduledRequest(1, tokens_needed=4, priority=2, deadline=1.0))
    s.submit(ScheduledRequest(2, tokens_needed=4, priority=1, deadline=9.0))
    s.submit(ScheduledRequest(3, tokens_needed=4, priority=1, deadline=0.5))
    admitted = s.admit(free_slots=3)
    assert [r.rid for r in admitted] == [3, 2, 1]


def test_infeasible_deadline_is_shed():
    s, clock = _sched(est=0.1)
    clock["t"] = 10.0
    s.submit(ScheduledRequest(1, tokens_needed=100, deadline=10.5))  # needs 10s
    s.submit(ScheduledRequest(2, tokens_needed=2, deadline=11.0))
    admitted = s.admit(free_slots=2)
    assert [r.rid for r in admitted] == [2]
    assert s.shed_count == 1


def test_no_deadline_always_feasible():
    s, _ = _sched()
    for i in range(5):
        s.submit(ScheduledRequest(i, tokens_needed=1000))
    assert len(s.admit(3)) == 3
    assert s.pending() == 2


def test_latency_ewma_moves_estimate():
    s, _ = _sched(est=0.01)
    for _ in range(50):
        s.observe_step_latency(0.05)
    assert abs(s.est - 0.05) < 5e-3
