"""Differential conformance matrix: the SAME RCB program must produce
bit-identical outputs through every execution path the runtime offers —

    run_interpreted (per-op decode + host sync, the OS-mediated baseline)
    run             (linked thunks, compiled dispatch)
    fuse            (one staged XLA program, the baremetal analogue)
    run_partitioned (per-tile-group stages pipelined over a TileMesh)

— and partitioned execution must be invariant to the tile-group count
(1 / 2 / 4). This is the interpreter/compiled-path boundary contract
OS-free runtimes live or die by (TFLM's conformance-testing lesson), over
a corpus spanning conv / matmul / quant / DMA / ALLOC-FREE mixes and the
ResNet-18 case study.
"""
import numpy as np
import pytest

import jax

from repro.core import rbl, rctc, rhal, rimfs
from repro.core.executor import Executor
from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc

TILE_COUNTS = (1, 2, 4)


def _np(v):
    return np.asarray(jax.block_until_ready(v))


def _quant_mix_program():
    """QUANTIZE/DEQUANT + ALLOC/FREE + explicit DMA in one stream."""
    t = {
        "x": TensorDesc("x", (8, 8), "float32", "input"),
        "w": TensorDesc("w", (8, 8), "float32", "weight"),
        "xd": TensorDesc("xd", (8, 8), "float32", "scratch"),
        "g": TensorDesc("g", (8, 8), "float32", "scratch"),
        "q": TensorDesc("q", (8, 8), "int8", "scratch"),
        "dq": TensorDesc("dq", (8, 8), "float32", "scratch"),
        "s": TensorDesc("s", (8, 8), "float32", "scratch"),
        "a": TensorDesc("a", (8, 8), "float32", "scratch"),
        "output": TensorDesc("output", (8, 8), "float32", "output"),
    }
    blocks = [
        RCB(0, "layer", (), (
            RCBOp(Op.DMA_H2D, ("xd",), ("x",)),
            RCBOp(Op.GEMM, ("g",), ("xd", "w")),
        )),
        RCB(1, "layer", (0,), (
            RCBOp(Op.QUANTIZE, ("q",), ("g",), {"scale": 0.05}),
            RCBOp(Op.DEQUANT, ("dq",), ("q",), {"scale": 0.05}),
        )),
        RCB(2, "layer", (1,), (
            RCBOp(Op.ALLOC, ("s",), (), {"shape": [8, 8],
                                         "dtype": "float32"}),
            RCBOp(Op.ADD, ("a",), ("dq", "s")),
            RCBOp(Op.FREE, ("s",)),
            RCBOp(Op.RELU, ("output",), ("a",)),
            RCBOp(Op.FENCE),
        )),
    ]
    prog = RCBProgram("quant_mix", t, blocks)
    prog.validate()
    return prog


def _corpus(rng):
    """(name, program, weight files, inputs) for the conformance matrix."""
    n = 16
    cases = []
    cases.append((
        "matmul_dma",
        rctc.compile_matmul(n, with_dma=True),
        {"b": rng.randn(n, n).astype(np.float32)},
        {"a": rng.randn(n, n).astype(np.float32)},
    ))
    cases.append((
        "conv_relu_softmax",
        rctc.compile_conv_relu_softmax(),
        {"w_conv": rng.randn(3, 3, 3, 9).astype(np.float32)},
        {"input": rng.randn(1, 8, 8, 3).astype(np.float32)},
    ))
    K = 4
    cases.append((
        "dma_pipeline",
        rctc.compile_dma_pipeline(K, n),
        {"b": rng.randn(n, n).astype(np.float32)},
        {f"in{i}": rng.randn(n, n).astype(np.float32) for i in range(K)},
    ))
    cases.append((
        "transfer_stream",
        rctc.compile_transfer_pipeline(K, 256),
        {},
        {f"in{i}": rng.randn(256).astype(np.float32) for i in range(K)},
    ))
    cases.append((
        "gemm_chain",
        rctc.compile_gemm_chain(5, n),
        rctc.gemm_chain_weights(5, n),
        {"input": rng.randn(n, n).astype(np.float32)},
    ))
    cases.append((
        "quant_mix",
        _quant_mix_program(),
        {"w": rng.randn(8, 8).astype(np.float32)},
        {"x": rng.randn(8, 8).astype(np.float32)},
    ))
    return cases


def _reference(prog, files, inputs):
    """Single-device interpreted outputs (the conformance reference)."""
    fs = rimfs.mount(rimfs.pack(files)) if files else None
    ex = Executor()
    ref = ex.run_interpreted(rbl.bind(prog, rimfs=fs, inputs=dict(inputs)))
    return fs, ex, {k: _np(v) for k, v in ref.items()}


def _assert_same(ref: dict, got: dict, label: str):
    assert set(got) == set(ref), \
        f"{label}: outputs {sorted(got)} != {sorted(ref)}"
    for k in ref:
        np.testing.assert_array_equal(
            ref[k], _np(got[k]), err_msg=f"{label}: output {k!r} diverged")


_CASES = None


def _cases():
    global _CASES
    if _CASES is None:
        _CASES = _corpus(np.random.RandomState(0))
    return _CASES


@pytest.mark.parametrize("name", [c[0] for c in _corpus(
    np.random.RandomState(0))])
def test_conformance_linked_and_fused(name):
    name, prog, files, inputs = next(c for c in _cases() if c[0] == name)
    fs, ex, ref = _reference(prog, files, inputs)
    _assert_same(ref, ex.run(rbl.bind(prog, rimfs=fs, inputs=dict(inputs))),
                 f"{name}/linked")
    bound_f = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound_f)
    _assert_same(ref, fused(dict(inputs), ex.weights_from(bound_f)),
                 f"{name}/fused")


@pytest.mark.parametrize("n_groups", TILE_COUNTS)
@pytest.mark.parametrize("name", [c[0] for c in _corpus(
    np.random.RandomState(0))])
def test_conformance_partitioned(name, n_groups):
    name, prog, files, inputs = next(c for c in _cases() if c[0] == name)
    fs, ex, ref = _reference(prog, files, inputs)
    mesh = rhal.TileMesh(n_groups)
    bound = rbl.bind(prog, rimfs=fs, inputs=dict(inputs))
    got = ex.run_partitioned(bound, rimfs=fs, mesh=mesh)
    _assert_same(ref, got, f"{name}/partitioned@{n_groups}")
    part = bound._partitions[mesh.n_groups]
    # the mesh's movement accounting covers exactly the cut-edge table
    assert mesh.moved_bytes() == part.cut_bytes()


@pytest.mark.parametrize("n_groups", TILE_COUNTS)
def test_conformance_resnet18(n_groups):
    """The paper's case study through all four paths at every tile count."""
    from repro.models import resnet as rn
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    fs = rimfs.mount(image)
    rng = np.random.RandomState(1)
    x = rng.rand(1, cfg.image_size, cfg.image_size, 3).astype(np.float32)
    ex = Executor()
    ref = {k: _np(v) for k, v in ex.run_interpreted(
        rbl.bind(prog, rimfs=fs, inputs={"input": x})).items()}
    _assert_same(ref, ex.run(rbl.bind(prog, rimfs=fs,
                                      inputs={"input": x})),
                 "resnet/linked")
    bound_f = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound_f)
    _assert_same(ref, fused({"input": x}, ex.weights_from(bound_f)),
                 "resnet/fused")
    bound_p = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    mesh = rhal.TileMesh(n_groups)
    _assert_same(ref, ex.run_partitioned(bound_p, rimfs=fs, mesh=mesh),
                 f"resnet/partitioned@{n_groups}")
    if n_groups > 1:
        part = bound_p._partitions[mesh.n_groups]
        assert part.edges, "ResNet partition must have cut edges"
        assert mesh.moved_bytes() == part.cut_bytes()
        # every tile group that ran compute got its own residency plan
        plans = [t.residency(mesh.group(t.gid).driver)
                 for t in part.tiles]
        assert all(p is not None for p in plans)
        assert all(p.high_water >= 0 for p in plans)


def test_partitioned_reuses_bound_weights_without_rimfs():
    """Regression: a BoundProgram whose weights already resolved at bind
    time must run partitioned WITHOUT re-supplying the image — the tile
    re-binds reuse the original bind's weight buffers."""
    rng = np.random.RandomState(3)
    prog = rctc.compile_gemm_chain(4, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(4, 8)))
    x = rng.randn(8, 8).astype(np.float32)
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    ref = {k: _np(v) for k, v in ex.run(bound).items()}
    got = ex.run_partitioned(bound, mesh=rhal.TileMesh(2))   # no rimfs=
    _assert_same(ref, got, "bound-weights/partitioned@2")


def test_tile_bind_cache_stays_bounded():
    """Regression: orchestrating the same BoundProgram over many FRESH
    meshes (post-failure replacement) must not retain every discarded
    mesh's bindings — the per-tile bind cache evicts."""
    from repro.core.partition import _BIND_CACHE_CAP
    prog = rctc.compile_gemm_chain(3, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(3, 8)))
    x = np.random.RandomState(0).randn(8, 8).astype(np.float32)
    bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    ex = Executor()
    ref = {k: _np(v) for k, v in ex.run(bound).items()}
    for _ in range(_BIND_CACHE_CAP + 4):
        got = ex.run_partitioned(bound, rimfs=fs, mesh=rhal.TileMesh(2))
        _assert_same(ref, got, "fresh-mesh loop")
    part = bound._partitions[2]
    assert all(len(t._bound) <= _BIND_CACHE_CAP for t in part.tiles)


def test_partition_is_deterministic():
    """Re-partitioning yields the identical cut-edge table (the partition
    is static data, like every other plan in the runtime)."""
    from repro.core import partition as partition_mod
    prog = rctc.compile_gemm_chain(6, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(6, 8)))
    bound = rbl.bind(prog, rimfs=fs)
    p1 = partition_mod.partition(bound, 3)
    p2 = partition_mod.partition(bound, 3)
    assert p1.edges == p2.edges
    assert [t.program.name for t in p1.tiles] == \
        [t.program.name for t in p2.tiles]
    for a, b in zip(p1.tiles, p2.tiles):
        assert a.cut_ins == b.cut_ins and a.cut_outs == b.cut_outs


# ---------------------------------------------------------------------------
# Batched execution (run_batched): the batch axis must be invisible
# ---------------------------------------------------------------------------

BATCH_NS = (1, 3, 4, 6, 11)       # covers exact buckets AND pad-to-bucket


def _rand_inputs_like(inputs: dict, rng) -> dict:
    return {k: rng.randn(*np.asarray(v).shape).astype(
        np.asarray(v).dtype) for k, v in inputs.items()}


@pytest.mark.parametrize("n", BATCH_NS)
@pytest.mark.parametrize("name", ["conv_relu_softmax", "gemm_chain"])
def test_batched_matches_serial(name, n):
    """run_batched over N random inputs == N serial run / run_interpreted
    calls, bit for bit — including N that are not bucket sizes (the
    pad-to-bucket + slice-back path)."""
    from repro.core import linker
    name, prog, files, inputs = next(c for c in _cases() if c[0] == name)
    rng = np.random.RandomState(100 + n)
    batch = [_rand_inputs_like(inputs, rng) for _ in range(n)]
    fs = rimfs.mount(rimfs.pack(files)) if files else None
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs)
    assert linker.batch_analysis(bound).batchable
    outs = ex.run_batched(bound, batch)
    assert ex.batch_stats["batchable"] and len(outs) == n
    assert sum(ex.batch_stats["buckets"]) - ex.batch_stats["padded"] == n
    for req, got in zip(batch, outs):
        ref = ex.run_interpreted(rbl.bind(prog, rimfs=fs,
                                          inputs=dict(req)))
        _assert_same({k: _np(v) for k, v in ref.items()}, got,
                     f"{name}/batched@{n} vs interpreted")
        ref2 = ex.run(bound, inputs=dict(req), rimfs=fs)
        _assert_same({k: _np(v) for k, v in ref2.items()}, got,
                     f"{name}/batched@{n} vs linked")


@pytest.mark.parametrize("n", (1, 5, 8))
def test_batched_resnet18_matches_serial(n):
    """The paper's case study through the batch-axis path (the benchmark
    gate's correctness side)."""
    from repro.models import resnet as rn
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    fs = rimfs.mount(image)
    rng = np.random.RandomState(n)
    batch = [{"input": rng.rand(1, cfg.image_size, cfg.image_size, 3)
              .astype(np.float32)} for _ in range(n)]
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs)
    outs = ex.run_batched(bound, batch)
    assert ex.batch_stats["batchable"]
    for req, got in zip(batch, outs):
        ref = {k: _np(v) for k, v in ex.run(bound, inputs=req).items()}
        _assert_same(ref, got, f"resnet/batched@{n}")


@pytest.mark.parametrize("name", ["matmul_dma", "dma_pipeline",
                                  "transfer_stream", "quant_mix"])
def test_non_batchable_split_phase_dma_falls_back(name):
    """Programs with host-split-phase DMA (prefetch/drain schedules) must
    NOT stage under vmap — run_batched falls back to serial linked
    execution with identical results, and reports why."""
    from repro.core import linker
    name, prog, files, inputs = next(c for c in _cases() if c[0] == name)
    rng = np.random.RandomState(7)
    batch = [_rand_inputs_like(inputs, rng) for _ in range(3)]
    fs = rimfs.mount(rimfs.pack(files)) if files else None
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs)
    verdict = linker.batch_analysis(bound)
    assert not verdict.batchable and "DMA" in verdict.reason
    outs = ex.run_batched(bound, batch, rimfs=fs)
    assert not ex.batch_stats["batchable"]
    assert ex.batch_stats["buckets"] == []       # nothing staged
    for req, got in zip(batch, outs):
        ref = ex.run_interpreted(rbl.bind(prog, rimfs=fs,
                                          inputs=dict(req)), rimfs=fs)
        _assert_same({k: _np(v) for k, v in ref.items()}, got,
                     f"{name}/fallback")


def test_non_batchable_graph_exec_falls_back():
    """GRAPH_EXEC artifacts are opaque host callables — the analysis must
    reject them and the fallback must still run them correctly."""
    from repro.core import linker
    from repro.core.rcb import RCBOp
    t = {
        "x": TensorDesc("x", (4, 4), "float32", "input"),
        "y": TensorDesc("y", (4, 4), "float32", "scratch"),
        "output": TensorDesc("output", (4, 4), "float32", "output"),
    }
    prog = RCBProgram("ge", t, [RCB(0, "layer", (), (
        RCBOp(Op.GRAPH_EXEC, ("y",), ("x",), {"artifact": "double"}),
        RCBOp(Op.RELU, ("output",), ("y",)),
    ))], {"double": lambda x: x * 2.0})
    prog.validate()
    rng = np.random.RandomState(0)
    batch = [{"x": rng.randn(4, 4).astype(np.float32)} for _ in range(3)]
    ex = Executor()
    bound = rbl.bind(prog)
    verdict = linker.batch_analysis(bound)
    assert not verdict.batchable and "GRAPH_EXEC" in verdict.reason
    outs = ex.run_batched(bound, batch)
    assert not ex.batch_stats["batchable"]
    for req, got in zip(batch, outs):
        np.testing.assert_array_equal(
            np.maximum(req["x"] * 2.0, 0), _np(got["output"]))


def test_batched_callable_cache_shared_across_binds():
    """The bucket cache is keyed (program CRC, bucket): a re-bind of the
    same program must reuse the staged executable, not re-trace."""
    prog = rctc.compile_gemm_chain(3, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(3, 8)))
    ex = Executor()
    b1, b2 = rbl.bind(prog, rimfs=fs), rbl.bind(prog, rimfs=fs)
    f1 = ex._batched_callable(b1, 4)
    f2 = Executor()._batched_callable(b2, 4)     # fresh executor too
    assert f1 is f2
    assert ex._batched_callable(b1, 2) is not f1  # per-bucket staging


def test_fuse_cached_on_bound_program():
    """Satellite: fuse() must return the SAME jitted callable for
    repeated calls (keyed by donate_weights) instead of re-linking and
    re-tracing per call."""
    prog = rctc.compile_gemm_chain(3, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(3, 8)))
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs)
    f1 = ex.fuse(bound)
    assert ex.fuse(bound) is f1
    assert Executor().fuse(bound) is f1          # cache rides the bound
    fd = ex.fuse(bound, donate_weights=True)
    assert fd is not f1 and ex.fuse(bound, donate_weights=True) is fd
    x = np.random.RandomState(0).randn(8, 8).astype(np.float32)
    ref = {k: _np(v) for k, v in ex.run(
        rbl.bind(prog, rimfs=fs, inputs={"input": x})).items()}
    _assert_same(ref, f1({"input": x}, ex.weights_from(bound)),
                 "fuse-cache")


# ---------------------------------------------------------------------------
# Streaming pipeline fill (execute_stream)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", (True, False))
@pytest.mark.parametrize("n_groups", (1, 2, 4))
def test_stream_matches_serial_in_order(n_groups, fused):
    """execute_stream over M inputs yields, in submission order, outputs
    bit-identical to M serial executions — at every group count, in both
    fused-stage and linked-stage mode, including M smaller than the
    pipeline depth."""
    from repro.core import partition as partition_mod
    prog = rctc.compile_gemm_chain(5, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(5, 8)))
    rng = np.random.RandomState(2)
    xs = [{"input": rng.randn(8, 8).astype(np.float32)}
          for _ in range(7)]
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs)
    refs = [{k: _np(v) for k, v in ex.run(bound, inputs=x).items()}
            for x in xs]
    mesh = rhal.TileMesh(n_groups)
    part = partition_mod.partition(bound, n_groups)
    for depth in (1, 4):
        got = list(partition_mod.execute_stream(
            part, mesh, iter(xs), rimfs=fs, depth=depth, fused=fused))
        assert len(got) == len(xs)
        for i, (ref, out) in enumerate(zip(refs, got)):
            _assert_same(ref, out,
                         f"stream@{n_groups}/depth{depth}/sample{i}")


def test_stream_resnet18_matches_serial():
    from repro.core import partition as partition_mod
    from repro.models import resnet as rn
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    fs = rimfs.mount(image)
    rng = np.random.RandomState(5)
    xs = [{"input": rng.rand(1, cfg.image_size, cfg.image_size, 3)
           .astype(np.float32)} for _ in range(6)]
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs)
    refs = [{k: _np(v) for k, v in ex.run(bound, inputs=x).items()}
            for x in xs]
    mesh = rhal.TileMesh(2)
    part = partition_mod.partition(bound, 2)
    stats: dict = {}
    got = list(partition_mod.execute_stream(part, mesh, iter(xs),
                                            rimfs=fs, depth=4,
                                            stats=stats))
    for i, (ref, out) in enumerate(zip(refs, got)):
        _assert_same(ref, out, f"resnet-stream/sample{i}")
    assert stats["samples"] == len(xs)
    assert all(b >= 0 for b in stats["busy"].values())


def test_stream_without_rimfs_reuses_bound_weights():
    """Stream mode must work from a weights-resolved bind with no image
    round-trip, like execute()."""
    from repro.core import partition as partition_mod
    prog = rctc.compile_gemm_chain(4, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(4, 8)))
    rng = np.random.RandomState(3)
    xs = [{"input": rng.randn(8, 8).astype(np.float32)} for _ in range(4)]
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs)      # weights resolved HERE
    refs = [{k: _np(v) for k, v in ex.run(bound, inputs=x).items()}
            for x in xs]
    part = partition_mod.partition(bound, 2)
    got = list(partition_mod.execute_stream(part, rhal.TileMesh(2),
                                            iter(xs)))   # no rimfs=
    for ref, out in zip(refs, got):
        _assert_same(ref, out, "stream/no-rimfs")


def test_stream_propagates_tile_failure():
    """No silent drops: a dead group surfaces as TileFailure (stream mode
    documents no-requeue; elasticity stays with execute())."""
    from repro.core import partition as partition_mod
    from repro.core.rhal import TileFailure
    prog = rctc.compile_gemm_chain(4, 8)
    fs = rimfs.mount(rimfs.pack(rctc.gemm_chain_weights(4, 8)))
    xs = [{"input": np.random.RandomState(1).randn(8, 8)
           .astype(np.float32)} for _ in range(4)]
    bound = rbl.bind(prog, rimfs=fs)
    mesh = rhal.TileMesh(2)
    part = partition_mod.partition(bound, 2)
    list(partition_mod.execute_stream(part, mesh, iter(xs[:1]),
                                      rimfs=fs))         # healthy warm-up
    mesh.kill(1)
    with pytest.raises(TileFailure):
        # fused stages bypass per-op vtable dispatch, but the cut-edge
        # stream into the dead consumer group still touches its driver
        list(partition_mod.execute_stream(part, mesh, iter(xs),
                                          rimfs=fs))


# ---------------------------------------------------------------------------
# Hypothesis-generated programs (optional dependency, like the other suites)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    def _random_program(draw_ops):
        """Random straight-line program over (4, 6) f32 buffers (the
        test_executor_property generator, reused for the partition
        matrix): every intermediate symbol is a potential cut edge."""
        tensors = {
            "in0": TensorDesc("in0", (4, 6), "float32", "input"),
            "w0": TensorDesc("w0", (6, 6), "float32", "weight"),
        }
        syms = ["in0"]
        ops = []
        for i, choice in enumerate(draw_ops):
            src = syms[choice % len(syms)]
            dst = f"t{i}"
            kind = choice % 4
            tensors[dst] = TensorDesc(dst, (4, 6), "float32", "scratch")
            if kind == 0:
                ops.append(RCBOp(Op.RELU, (dst,), (src,)))
            elif kind == 1:
                ops.append(RCBOp(Op.SOFTMAX, (dst,), (src,), {"axis": -1}))
            elif kind == 2:
                other = syms[(choice // 4) % len(syms)]
                ops.append(RCBOp(Op.ADD, (dst,), (src, other)))
            else:
                ops.append(RCBOp(Op.GEMM, (dst,), (src, "w0")))
            syms.append(dst)
        out = syms[-1]
        tensors[out] = TensorDesc(out, (4, 6), "float32", "output")
        prog = RCBProgram("rand", tensors,
                          [RCB(0, "layer", (), tuple(ops))])
        prog.validate()
        return prog

    @given(st.lists(st.integers(0, 1000), min_size=2, max_size=16),
           st.sampled_from(TILE_COUNTS))
    @settings(max_examples=25, deadline=None)
    def test_property_partitioned_matches_linked(draw_ops, n_groups):
        prog = _random_program(draw_ops)
        rng = np.random.RandomState(0)
        fs = rimfs.mount(rimfs.pack(
            {"w0": rng.randn(6, 6).astype(np.float32)}))
        x = rng.randn(4, 6).astype(np.float32)
        ex = Executor()
        ref = {k: _np(v) for k, v in ex.run(
            rbl.bind(prog, rimfs=fs, inputs={"in0": x})).items()}
        bound = rbl.bind(prog, rimfs=fs, inputs={"in0": x})
        got = ex.run_partitioned(bound, rimfs=fs,
                                 mesh=rhal.TileMesh(n_groups))
        _assert_same(ref, got, f"rand/partitioned@{n_groups}")


# ---------------------------------------------------------------------------
# Kernel conformance matrix (DESIGN.md §13: registry handlers)
# ---------------------------------------------------------------------------
#
# Every kernel × {pallas-interpret, ref} × {fp32, bf16} over deliberately
# awkward shapes (odd head_dim, GQA grouping, ragged sequence lengths): the
# registry's fallback ladder must agree with the pure-jnp reference within
# dtype tolerance, and kernel opcodes dispatched through link_compute must
# match the same registry call made eagerly.

import jax.numpy as jnp

from repro.kernels import registry as kreg

_KTOL = {"float32": 5e-4, "bfloat16": 3e-2}


def _kernel_args(kernel, dtype, shape_tag, rng):
    dt = jnp.dtype(dtype)

    def arr(*shape):
        return jnp.asarray(rng.randn(*shape), dt)

    if kernel == "attention":
        # odd head_dim / GQA grouping / ragged (non-multiple-of-block) seq
        b, s, h, hkv, d = {
            "odd_head": (2, 16, 4, 4, 12),
            "gqa": (2, 16, 6, 2, 16),
            "ragged": (1, 13, 4, 2, 16),
        }[shape_tag]
        return (arr(b, s, h, d), arr(b, s, hkv, d), arr(b, s, hkv, d)), \
            {"causal": True}
    if kernel == "matmul_int8":
        m, k, n = {"odd_head": (8, 24, 16), "gqa": (16, 32, 8),
                   "ragged": (8, 16, 24)}[shape_tag]
        x = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
        w = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
        scale = jnp.asarray(np.abs(rng.randn(n)) + 0.1, jnp.float32)
        return (x, w, scale), {"out_dtype": dtype}
    if kernel == "ssm_scan":
        b, t, di, n = {"odd_head": (2, 8, 6, 3), "gqa": (1, 16, 8, 4),
                       "ragged": (2, 13, 4, 4)}[shape_tag]
        da = -jnp.abs(arr(b, t, di, n))
        return (da, arr(b, t, di, n), arr(b, t, n)), {}
    if kernel == "wkv6":
        b, t, h, k = {"odd_head": (2, 8, 2, 6), "gqa": (1, 16, 3, 8),
                      "ragged": (2, 13, 2, 8)}[shape_tag]
        lw = -jnp.abs(arr(b, t, h, k)).clip(0.05, 3.0)
        return (arr(b, t, h, k), arr(b, t, h, k), arr(b, t, h, k), lw,
                arr(h, k)), {}
    raise AssertionError(kernel)


@pytest.mark.parametrize("shape_tag", ["odd_head", "gqa", "ragged"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("kernel", list(kreg.KERNEL_NAMES))
def test_kernel_matrix_pallas_matches_ref(kernel, dtype, shape_tag):
    rng = np.random.RandomState(7)
    args, kwargs = _kernel_args(kernel, dtype, shape_tag, rng)
    ref = kreg.call(kernel, *args, impl="ref", **kwargs)
    got = kreg.call(kernel, *args, impl="pallas", **kwargs)
    assert got.shape == ref.shape and got.dtype == ref.dtype
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32) -
                                ref.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
    assert err / scale < _KTOL[dtype], \
        f"{kernel}/{dtype}/{shape_tag}: rel err {err / scale:.3e}"


@pytest.mark.parametrize("kernel,opcode", [
    ("attention", Op.ATTENTION), ("matmul_int8", Op.MATMUL_INT8),
    ("ssm_scan", Op.SSM_SCAN), ("wkv6", Op.WKV6)])
def test_linked_kernel_op_matches_eager_registry(kernel, opcode):
    """Op.X through Executor.run's link_compute handler == registry.call."""
    rng = np.random.RandomState(3)
    args, kwargs = _kernel_args(kernel, "float32", "gqa", rng)
    eager = kreg.call(kernel, *args, **kwargs)
    t = {}
    srcs = []
    for i, a in enumerate(args):
        nm = f"in{i}"
        t[nm] = TensorDesc(nm, tuple(a.shape), str(a.dtype), "input")
        srcs.append(nm)
    t["out"] = TensorDesc("out", tuple(eager.shape), str(eager.dtype),
                          "output")
    attrs = {"causal": True} if kernel == "attention" else {}
    prog = RCBProgram(f"k_{kernel}", t, [RCB(0, "layer", (), (
        RCBOp(opcode, ("out",), tuple(srcs), attrs),
        RCBOp(Op.FENCE),
    ))])
    prog.validate()
    ex = Executor()
    ins = {f"in{i}": a for i, a in enumerate(args)}
    for label, out in (
            ("interp", ex.run_interpreted(rbl.bind(prog,
                                                   inputs=dict(ins)))),
            ("linked", ex.run(rbl.bind(prog, inputs=dict(ins))))):
        np.testing.assert_allclose(
            _np(out["out"]), _np(eager), rtol=0, atol=1e-6,
            err_msg=f"{kernel}/{label} diverged from registry.call")


# ---------------------------------------------------------------------------
# Per-layer LM lowering conformance (three families through the engine)
# ---------------------------------------------------------------------------

_LM_CONFIGS = ("qwen2-1.5b", "rwkv6-1.6b", "hymba-1.5b")


def _lm_program(name, B=2, S=8):
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.models.common import init_params
    cfg = get_config(name + "-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits, _, _ = tf.forward_full(cfg, params, tokens)
    prog, image = rctc.compile_transformer_block(cfg, params, B, S)
    glob, _ = tf.split_params(params)
    ins = {"hidden": tf.embed_inputs(cfg, glob, tokens)}
    if "positions" in prog.tensors:
        ins["positions"] = np.broadcast_to(
            np.arange(S, dtype=np.int32)[None], (B, S)).copy()
    return cfg, prog, image, ins, _np(logits)


@pytest.mark.parametrize("name", _LM_CONFIGS)
def test_lm_block_program_matches_eager(name):
    """compile_transformer_block → linked kernel ops == eager forward_full
    (fp32 smoke configs: tight tolerance)."""
    cfg, prog, image, ins, ref_logits = _lm_program(name)
    # the mixers must be exposed as kernel opcodes, not a monolithic artifact
    kinds = {op.op for blk in prog.blocks for op in blk.ops}
    want = {"dense": Op.ATTENTION, "ssm": Op.WKV6,
            "hybrid": Op.SSM_SCAN}[cfg.family]
    assert want in kinds
    fs = rimfs.mount(image)
    ex = Executor()
    for label, runner in (("interp", ex.run_interpreted), ("linked", ex.run)):
        out = runner(rbl.bind(prog, rimfs=fs, inputs=dict(ins)))["logits"]
        np.testing.assert_allclose(
            _np(out), ref_logits, rtol=0, atol=5e-4,
            err_msg=f"{name}/{label} logits diverged from eager model")


def test_lm_block_program_through_platform_engine():
    """Provision → bind → linked dispatch through the RTPM platform — the
    serving-engine path for per-layer programs."""
    from repro.core.rtpm import Platform
    cfg, prog, image, ins, ref_logits = _lm_program("qwen2-1.5b")
    plat = Platform()
    plat.provision(image=image, program=prog)
    ex = Executor()
    out = ex.run(plat.bind(inputs=dict(ins)))["logits"]
    np.testing.assert_allclose(_np(out), ref_logits, rtol=0, atol=5e-4)


def test_autotune_cache_reloads_at_provision_with_zero_trials():
    """Tune → pack winners into the image → fresh provision reloads them:
    the second provision's autotune does ZERO sweep trials."""
    from repro.core.rtpm import Platform
    rng = np.random.RandomState(11)
    args, kwargs = _kernel_args("ssm_scan", "float32", "ragged", rng)
    kreg.reset()
    try:
        params1, trials1 = kreg.autotune("ssm_scan", *args, **kwargs)
        assert trials1 > 0, "first provision must sweep"
        image = kreg.pack_image()
        kreg.reset()
        assert kreg.REGISTRY.sweep_trials == 0
        plat = Platform()
        plat.provision(image=image)          # reload path under test
        params2, trials2 = kreg.autotune("ssm_scan", *args, **kwargs)
        assert trials2 == 0, "second provision re-swept the space"
        assert kreg.REGISTRY.sweep_trials == 0
        assert params2 == params1
    finally:
        kreg.reset()


def test_mamba_routes_through_ssm_kernel(monkeypatch):
    """Regression: AEG_SSM_IMPL=kernel sends mamba_mix through the registry
    ssm_scan handler and matches the jnp associative-scan path."""
    from repro.configs import get_config
    from repro.models import mamba as mam
    from repro.models.common import init_params
    cfg = get_config("hymba-1.5b-smoke")
    specs = mam.mamba_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), specs)
    pl = jax.tree.map(lambda a: a[0], params)
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(2, 12, cfg.d_model), jnp.float32)
    h0 = jnp.asarray(rng.randn(2, cfg.d_model, cfg.ssm_state), jnp.float32)
    monkeypatch.delenv("AEG_SSM_IMPL", raising=False)
    y_jnp, h_jnp = mam.mamba_mix(cfg, pl, x, h0)
    monkeypatch.setenv("AEG_SSM_IMPL", "kernel")
    y_k, h_k = mam.mamba_mix(cfg, pl, x, h0)
    np.testing.assert_allclose(_np(y_k), _np(y_jnp), rtol=0, atol=5e-5)
    np.testing.assert_allclose(_np(h_k), _np(h_jnp), rtol=0, atol=5e-5)


def test_rwkv_routes_through_wkv_kernel(monkeypatch):
    """AEG_WKV_IMPL=kernel sends time_mix through the registry wkv6 handler
    (with the nonzero-s0 correction) and matches the chunked-scan path."""
    from repro.configs import get_config
    from repro.models import rwkv6 as rwkv
    from repro.models.common import init_params
    cfg = get_config("rwkv6-1.6b-smoke")
    params = init_params(jax.random.PRNGKey(0), rwkv.rwkv_specs(cfg))
    pl = jax.tree.map(lambda a: a[0], params)
    rng = np.random.RandomState(6)
    B, T, d = 2, 12, cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    x = jnp.asarray(rng.randn(B, T, d), jnp.float32)
    ts = jnp.asarray(rng.randn(B, d), jnp.float32)
    s0 = jnp.asarray(rng.randn(B, H, K, K), jnp.float32)
    monkeypatch.delenv("AEG_WKV_IMPL", raising=False)
    y_jnp, _, s_jnp = rwkv.time_mix(cfg, pl, x, ts, s0)
    monkeypatch.setenv("AEG_WKV_IMPL", "kernel")
    y_k, _, s_k = rwkv.time_mix(cfg, pl, x, ts, s0)
    np.testing.assert_allclose(_np(y_k), _np(y_jnp), rtol=0, atol=5e-4)
    np.testing.assert_allclose(_np(s_k), _np(s_jnp), rtol=0, atol=5e-4)
