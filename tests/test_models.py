"""Per-architecture smoke tests (reduced same-family configs) + decode
consistency: the incremental decode path must reproduce full-forward logits.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHES, get_config
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.optim.adamw import adamw_init_specs

B, S = 2, 32


def _inputs(cfg, rng, seq=S):
    if cfg.input_kind == "tokens":
        return jnp.asarray(rng.randint(0, cfg.vocab_size, (B, seq)))
    return jnp.asarray(rng.randn(B, seq, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ARCHES)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch + "-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    logits, cache, aux = tf.forward_full(cfg, params, _inputs(cfg, rng),
                                         want_cache=True)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHES)
def test_one_train_step_no_nans(arch, rng):
    cfg = get_config(arch + "-smoke")
    specs = tf.model_specs(cfg)
    params = init_params(jax.random.PRNGKey(0), specs)
    opt = init_params(jax.random.PRNGKey(1), adamw_init_specs(specs))
    step = jax.jit(make_train_step(cfg))
    batch = {"inputs": _inputs(cfg, rng),
             "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), params,
            params2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHES)
def test_decode_matches_full_forward(arch, rng):
    """Prefill S tokens, decode token S; logits must match a full forward
    over S+1 tokens (the strongest single consistency check a serving stack
    can have)."""
    cfg = get_config(arch + "-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    full_inp = _inputs(cfg, rng, seq=S + 1)
    logits_full, _, _ = tf.forward_full(cfg, params, full_inp)

    prefix = full_inp[:, :S]
    _, cache, _ = tf.forward_full(cfg, params, prefix, want_cache=True)

    # widen attention caches from S to S+8 slots (recurrent states keep shape)
    cs = tf.cache_specs(cfg, B, S + 8)
    zc = init_params(jax.random.PRNGKey(2), cs)
    if "k" in cache:
        win = zc["k"].shape[2]
        zc = dict(zc)
        ks = cache["k"][:, :, -win:] if cache["k"].shape[2] > win \
            else cache["k"]
        vs = cache["v"][:, :, -win:] if cache["v"].shape[2] > win \
            else cache["v"]
        zc["k"] = zc["k"].at[:, :, :ks.shape[2]].set(ks.astype(zc["k"].dtype))
        zc["v"] = zc["v"].at[:, :, :vs.shape[2]].set(vs.astype(zc["v"].dtype))
        for key in cache:
            if key not in ("k", "v"):
                zc[key] = cache[key]
    else:
        zc = cache

    pos = jnp.full((B,), S, jnp.int32)
    nxt = full_inp[:, S:S + 1]
    logits_dec, _ = tf.forward_decode(cfg, params, nxt, pos, zc)

    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, 0], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_sliding_window_limits_attention(rng):
    """Hymba SWA: token t must not see tokens older than the window."""
    cfg = get_config("hymba-1.5b-smoke")
    assert cfg.sliding_window == 16
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    x = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 32)))
    l1, _, _ = tf.forward_full(cfg, params, x)
    # perturb a token far outside every later window
    x2 = x.at[0, 0].set((int(x[0, 0]) + 7) % cfg.vocab_size)
    l2, _, _ = tf.forward_full(cfg, params, x2)
    # last position: outside window of position 0 for attention; mamba branch
    # does carry state, so allow small leakage but require strong damping
    d_last = float(jnp.max(jnp.abs(l1[0, -1] - l2[0, -1])))
    d_first = float(jnp.max(jnp.abs(l1[0, 1] - l2[0, 1])))
    assert d_first > 0
    assert d_last < d_first


def test_moe_capacity_drops_gracefully(rng):
    cfg = get_config("arctic-480b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    logits, _, aux = tf.forward_full(cfg, params, _inputs(cfg, rng))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert float(aux) > 0          # router is exercised


def test_param_counts_match_analytic():
    """ParamSpec trees must agree with the analytic count (used for
    MODEL_FLOPS in the roofline) to within 1.5%."""
    from repro.models.common import param_count
    for arch in ARCHES:
        cfg = get_config(arch)
        analytic = cfg.param_count()
        tree = param_count(tf.model_specs(cfg))
        assert abs(tree - analytic) / analytic < 0.015, \
            (arch, tree, analytic)
