"""RCB binary format: control really is data (roundtrip + integrity)."""
import json

import pytest
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False

from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc


def _prog(ops, tensors=None):
    tensors = tensors or {}
    return RCBProgram("t", tensors, [RCB(0, "layer", (), tuple(ops))])


def test_rcb_roundtrip_simple():
    ops = (RCBOp(Op.GEMM, ("y",), ("a", "b"), {"ta": False}),
           RCBOp(Op.FENCE),
           RCBOp(Op.HALT))
    blk = RCB(7, "layer", (3,), ops)
    blob = blk.encode()
    back, consumed = RCB.decode(memoryview(blob))
    assert consumed == len(blob)
    assert back == blk


def test_rcb_crc_detects_tamper():
    blk = RCB(1, "layer", (), (RCBOp(Op.RELU, ("y",), ("x",)),))
    blob = bytearray(blk.encode())
    blob[25] ^= 0xFF
    with pytest.raises(ValueError, match="CRC"):
        RCB.decode(memoryview(bytes(blob)))


def test_program_roundtrip_and_validate():
    tensors = {
        "x": TensorDesc("x", (4, 4), "float32", "input", ("batch", None)),
        "w": TensorDesc("w", (4, 4), "float32", "weight"),
        "y": TensorDesc("y", (4, 4), "float32", "output"),
    }
    prog = RCBProgram("mm", tensors,
                      [RCB(0, "layer", (),
                           (RCBOp(Op.GEMM, ("y",), ("x", "w")),))])
    prog.validate()
    back = RCBProgram.decode(prog.encode())
    assert back.name == "mm"
    assert back.tensors["x"].axes == ("batch", None)
    assert back.blocks[0].ops[0].op == Op.GEMM


def test_validate_catches_unbound_symbol():
    prog = _prog([RCBOp(Op.RELU, ("y",), ("nope",))])
    with pytest.raises(ValueError, match="unbound"):
        prog.validate()


def test_validate_catches_missing_dep():
    prog = RCBProgram("t", {}, [RCB(0, "layer", (99,), (RCBOp(Op.FENCE),))])
    with pytest.raises(ValueError, match="missing dep"):
        prog.validate()


# ---------------------------------------------------------------------------
# Binary v2: interned symtab + packed records; v1 kept for backward compat
# ---------------------------------------------------------------------------

def _rich_program():
    tensors = {
        "x": TensorDesc("x", (4, 4), "float32", "input", ("batch", None)),
        "w": TensorDesc("w", (4, 4), "float32", "weight"),
        "t": TensorDesc("t", (4, 4), "float32", "scratch"),
        "y": TensorDesc("y", (4, 4), "float32", "output"),
    }
    ops0 = (RCBOp(Op.GEMM, ("t",), ("x", "w"),
                  {"ta": False, "acc": "f32", "f": 1.5, "n": -7,
                   "l": [1, 2, 3], "nested": {"k": None, "b": True}}),
            RCBOp(Op.RELU, ("y",), ("t",)),
            RCBOp(Op.FENCE))
    return RCBProgram("rich", tensors,
                      [RCB(0, "layer", (), ops0),
                       RCB(1, "control", (0,), (RCBOp(Op.HALT),))])


def test_v2_roundtrip_equals_v1():
    """Cross-version decode: the same program through either wire format
    yields identical in-memory structures."""
    prog = _rich_program()
    blob_v1 = prog.encode(version=1)
    blob_v2 = prog.encode()                   # v2 is the default
    assert blob_v1 != blob_v2
    p1, p2 = RCBProgram.decode(blob_v1), RCBProgram.decode(blob_v2)
    assert p1.name == p2.name == "rich"
    assert p1.tensors == p2.tensors
    assert p1.blocks == p2.blocks
    assert p2.tensors["x"].axes == ("batch", None)
    assert p2.blocks[0].ops[0].attrs["nested"] == {"k": None, "b": True}


def test_v2_smaller_than_v1():
    prog = _rich_program()
    assert len(prog.encode()) < len(prog.encode(version=1))


def test_v1_decode_backward_compat():
    """A v1 blob (old provisioning payloads) still decodes."""
    prog = _rich_program()
    back = RCBProgram.decode(prog.encode(version=1))
    assert back.blocks[0].ops[0].op == Op.GEMM
    back.validate()


def test_v2_crc_rejects_corrupt_symtab():
    """Integrity first: a flipped byte inside the v2 symbol table fails the
    whole-program CRC before anything is parsed."""
    blob = bytearray(_rich_program().encode())
    # symtab starts right after the 20-byte header + name; corrupt inside it
    sym_off = 20 + len("rich") + 6
    blob[sym_off] ^= 0xFF
    with pytest.raises(ValueError, match="CRC"):
        RCBProgram.decode(bytes(blob))


def test_v2_crc_rejects_corrupt_op_payload():
    blob = bytearray(_rich_program().encode())
    blob[-20] ^= 0xFF                       # inside the last block
    with pytest.raises(ValueError, match="CRC"):
        RCBProgram.decode(bytes(blob))


def test_v2_unknown_version_rejected():
    blob = bytearray(_rich_program().encode())
    blob[4] = 99                            # version field (little-endian)
    import struct as _struct
    import zlib as _zlib
    body = bytes(blob[:-4])
    blob[-4:] = _struct.pack("<I", _zlib.crc32(body) & 0xFFFFFFFF)
    with pytest.raises(ValueError, match="version"):
        RCBProgram.decode(bytes(blob))


if _HAS_HYPOTHESIS:
    _sym = st.text(alphabet="abcdefgh_0123456789", min_size=1, max_size=8)
    _attr_val = st.one_of(st.integers(-1000, 1000), st.booleans(),
                          st.floats(-1e3, 1e3, allow_nan=False),
                          st.lists(st.integers(0, 64), max_size=4))


    @given(st.lists(
        st.builds(RCBOp,
                  st.sampled_from(list(Op)),
                  st.lists(_sym, max_size=3).map(tuple),
                  st.lists(_sym, max_size=3).map(tuple),
                  st.dictionaries(_sym, _attr_val, max_size=4)),
        max_size=16))
    @settings(max_examples=50, deadline=None)
    def test_property_block_roundtrip(ops):
        blk = RCB(3, "pipeline", (0, 1), tuple(ops))
        back, _ = RCB.decode(memoryview(blk.encode()))
        assert back == blk
