"""RCB binary format: control really is data (roundtrip + integrity)."""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc


def _prog(ops, tensors=None):
    tensors = tensors or {}
    return RCBProgram("t", tensors, [RCB(0, "layer", (), tuple(ops))])


def test_rcb_roundtrip_simple():
    ops = (RCBOp(Op.GEMM, ("y",), ("a", "b"), {"ta": False}),
           RCBOp(Op.FENCE),
           RCBOp(Op.HALT))
    blk = RCB(7, "layer", (3,), ops)
    blob = blk.encode()
    back, consumed = RCB.decode(memoryview(blob))
    assert consumed == len(blob)
    assert back == blk


def test_rcb_crc_detects_tamper():
    blk = RCB(1, "layer", (), (RCBOp(Op.RELU, ("y",), ("x",)),))
    blob = bytearray(blk.encode())
    blob[25] ^= 0xFF
    with pytest.raises(ValueError, match="CRC"):
        RCB.decode(memoryview(bytes(blob)))


def test_program_roundtrip_and_validate():
    tensors = {
        "x": TensorDesc("x", (4, 4), "float32", "input", ("batch", None)),
        "w": TensorDesc("w", (4, 4), "float32", "weight"),
        "y": TensorDesc("y", (4, 4), "float32", "output"),
    }
    prog = RCBProgram("mm", tensors,
                      [RCB(0, "layer", (),
                           (RCBOp(Op.GEMM, ("y",), ("x", "w")),))])
    prog.validate()
    back = RCBProgram.decode(prog.encode())
    assert back.name == "mm"
    assert back.tensors["x"].axes == ("batch", None)
    assert back.blocks[0].ops[0].op == Op.GEMM


def test_validate_catches_unbound_symbol():
    prog = _prog([RCBOp(Op.RELU, ("y",), ("nope",))])
    with pytest.raises(ValueError, match="unbound"):
        prog.validate()


def test_validate_catches_missing_dep():
    prog = RCBProgram("t", {}, [RCB(0, "layer", (99,), (RCBOp(Op.FENCE),))])
    with pytest.raises(ValueError, match="missing dep"):
        prog.validate()


_sym = st.text(alphabet="abcdefgh_0123456789", min_size=1, max_size=8)
_attr_val = st.one_of(st.integers(-1000, 1000), st.booleans(),
                      st.floats(-1e3, 1e3, allow_nan=False),
                      st.lists(st.integers(0, 64), max_size=4))


@given(st.lists(
    st.builds(RCBOp,
              st.sampled_from(list(Op)),
              st.lists(_sym, max_size=3).map(tuple),
              st.lists(_sym, max_size=3).map(tuple),
              st.dictionaries(_sym, _attr_val, max_size=4)),
    max_size=16))
@settings(max_examples=50, deadline=None)
def test_property_block_roundtrip(ops):
    blk = RCB(3, "pipeline", (0, 1), tuple(ops))
    back, _ = RCB.decode(memoryview(blk.encode()))
    assert back == blk
