"""RIMFS: zero-copy semantics, alignment, CRC integrity, image roundtrip."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False

from repro.core import rimfs


def test_pack_mount_roundtrip(rng):
    files = {
        "w1": rng.randn(16, 8).astype(np.float32),
        "w2": rng.randint(-128, 127, (3, 5, 7), dtype=np.int8),
        "scalar": np.asarray(3.5, np.float64),
    }
    img = rimfs.pack(files)
    fs = rimfs.mount(img)
    assert sorted(fs.files()) == sorted(files)
    for k, v in files.items():
        np.testing.assert_array_equal(fs.read(k), v)
    assert fs.verify() and fs.verify_image()


def test_zero_copy_view(rng):
    w = rng.randn(64, 64).astype(np.float32)
    img = rimfs.pack({"w": w})
    fs = rimfs.mount(img)
    view = fs.read("w")
    # a true view: no copy — base buffer is the image itself
    assert view.base is not None
    assert not view.flags["OWNDATA"]


def test_alignment(rng):
    files = {f"t{i}": rng.randn(i + 1).astype(np.float32) for i in range(7)}
    fs = rimfs.mount(rimfs.pack(files))
    for name in fs.files():
        off, _ = fs.address_of(name)
        assert off % rimfs.ALIGN == 0


def test_crc_detects_bit_flip(rng):
    img = bytearray(rimfs.pack({"w": rng.randn(32).astype(np.float32)}))
    fs0 = rimfs.mount(bytes(img))
    off, n = fs0.address_of("w")
    img[off + 5] ^= 0x10
    fs = rimfs.mount(bytes(img))
    with pytest.raises(rimfs.RIMFSError, match="CRC"):
        fs.verify()
    with pytest.raises(rimfs.RIMFSError, match="CRC"):
        fs.verify_image()


def test_mount_file_mmap(tmp_path, rng):
    w = rng.randn(128).astype(np.float32)
    rimfs.save_file(tmp_path / "img.rimfs", {"w": w})
    fs = rimfs.mount_file(tmp_path / "img.rimfs")
    np.testing.assert_array_equal(fs.read("w"), w)
    assert fs.verify()


def test_overhead_small(rng):
    """Paper Table 2: runtime memory dominated by weights, minimal overhead."""
    w = rng.randn(512, 512).astype(np.float32)     # 1 MB payload
    fs = rimfs.mount(rimfs.pack({"w": w}))
    assert fs.overhead_bytes() < 0.01 * fs.total_bytes()


if _HAS_HYPOTHESIS:
    @given(st.dictionaries(
        st.text("abcdef", min_size=1, max_size=6),
        st.tuples(st.sampled_from(["float32", "int8", "int32", "float16"]),
                  st.lists(st.integers(1, 5), min_size=0, max_size=3)),
        min_size=1, max_size=8))
    @settings(max_examples=30, deadline=None)
    def test_property_roundtrip(spec):
        rng = np.random.RandomState(42)
        files = {k: (np.asarray(rng.randn(*shape)) * 10).astype(dt)
                 for k, (dt, shape) in spec.items()}
        fs = rimfs.mount(rimfs.pack(files))
        assert fs.verify()
        for k, v in files.items():
            np.testing.assert_array_equal(fs.read(k), v)
