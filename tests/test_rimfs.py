"""RIMFS: zero-copy semantics, alignment, CRC integrity, image roundtrip,
device residency (pin-once, zero re-upload)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False

from repro.core import rhal, rimfs


def test_pack_mount_roundtrip(rng):
    files = {
        "w1": rng.randn(16, 8).astype(np.float32),
        "w2": rng.randint(-128, 127, (3, 5, 7), dtype=np.int8),
        "scalar": np.asarray(3.5, np.float64),
    }
    img = rimfs.pack(files)
    fs = rimfs.mount(img)
    assert sorted(fs.files()) == sorted(files)
    for k, v in files.items():
        np.testing.assert_array_equal(fs.read(k), v)
    assert fs.verify() and fs.verify_image()


def test_zero_copy_view(rng):
    w = rng.randn(64, 64).astype(np.float32)
    img = rimfs.pack({"w": w})
    fs = rimfs.mount(img)
    view = fs.read("w")
    # a true view: no copy — base buffer is the image itself
    assert view.base is not None
    assert not view.flags["OWNDATA"]


def test_alignment(rng):
    files = {f"t{i}": rng.randn(i + 1).astype(np.float32) for i in range(7)}
    fs = rimfs.mount(rimfs.pack(files))
    for name in fs.files():
        off, _ = fs.address_of(name)
        assert off % rimfs.ALIGN == 0


def test_crc_detects_bit_flip(rng):
    img = bytearray(rimfs.pack({"w": rng.randn(32).astype(np.float32)}))
    fs0 = rimfs.mount(bytes(img))
    off, n = fs0.address_of("w")
    img[off + 5] ^= 0x10
    fs = rimfs.mount(bytes(img))
    with pytest.raises(rimfs.RIMFSError, match="CRC"):
        fs.verify()
    with pytest.raises(rimfs.RIMFSError, match="CRC"):
        fs.verify_image()


def test_mount_file_mmap(tmp_path, rng):
    w = rng.randn(128).astype(np.float32)
    rimfs.save_file(tmp_path / "img.rimfs", {"w": w})
    fs = rimfs.mount_file(tmp_path / "img.rimfs")
    np.testing.assert_array_equal(fs.read("w"), w)
    assert fs.verify()


def test_resident_views_alias_image_no_copy(rng):
    """The round-trip property: the host views the resident upload consumed
    ARE views of the mounted image bytes — no staging copy anywhere."""
    w = rng.randn(64, 64).astype(np.float32)
    img = rimfs.pack({"w": w})
    fs = rimfs.mount(img)
    drv = rhal.make_eager_driver()
    ri = fs.resident(drv)
    view = ri.host_view("w")
    assert np.shares_memory(view, np.frombuffer(img, np.uint8))
    np.testing.assert_array_equal(view, w)
    # the uploaded device buffer round-trips the same bits
    np.testing.assert_array_equal(np.asarray(ri["w"]), w)
    # and its offset comes from address_of's aligned placement
    off, nbytes = fs.address_of("w")
    assert off % rimfs.ALIGN == 0 and nbytes == w.nbytes


def test_resident_is_pinned_once_per_driver(rng):
    files = {f"w{i}": rng.randn(32, 32).astype(np.float32)
             for i in range(4)}
    fs = rimfs.mount(rimfs.pack(files))
    drv = rhal.make_eager_driver(debug_arena=True)
    ri1 = fs.resident(drv)
    moved = drv.stats.get("dma_bytes", 0)
    assert moved == sum(v.nbytes for v in files.values())
    pinned = drv.arena.bytes_in_use
    # second resident call: same object, zero additional DMA, zero arena
    ri2 = fs.resident(drv)
    assert ri2 is ri1
    assert drv.stats.get("dma_bytes", 0) == moved
    assert drv.arena.bytes_in_use == pinned
    # a different driver gets its own pinning
    drv2 = rhal.make_eager_driver()
    fs.resident(drv2)
    assert drv2.stats.get("dma_bytes", 0) == moved
    # unpin releases the arena ranges and invalidates the cache entry
    ri1.unpin()
    assert drv.arena.bytes_in_use == pinned - moved
    assert fs.resident(drv) is not ri1


def test_resident_pins_subset_and_extends(rng):
    """bind-style subset pinning: only requested files upload; later
    requests extend incrementally; already-pinned files never re-move."""
    files = {f"w{i}": rng.randn(16, 16).astype(np.float32)
             for i in range(3)}
    fs = rimfs.mount(rimfs.pack(files))
    drv = rhal.make_eager_driver()
    ri = fs.resident(drv, names=["w0"])
    assert ri.files() == ["w0"]
    assert drv.stats.get("dma_bytes", 0) == files["w0"].nbytes
    ri2 = fs.resident(drv, names=["w0", "w2"])       # extend
    assert ri2 is ri and sorted(ri.files()) == ["w0", "w2"]
    assert drv.stats["dma_bytes"] == files["w0"].nbytes \
        + files["w2"].nbytes


def test_resident_cache_drops_dead_drivers(rng):
    """The per-driver cache must not keep a collected driver's weight
    copy alive (elasticity churn creates many short-lived drivers)."""
    import gc
    fs = rimfs.mount(rimfs.pack({"w": rng.randn(8).astype(np.float32)}))
    drv = rhal.make_eager_driver()
    fs.resident(drv)
    assert len(fs._resident) == 1
    del drv
    gc.collect()
    drv2 = rhal.make_eager_driver()
    fs.resident(drv2)                     # prunes the dead entry
    assert len(fs._resident) == 1


def test_overhead_small(rng):
    """Paper Table 2: runtime memory dominated by weights, minimal overhead."""
    w = rng.randn(512, 512).astype(np.float32)     # 1 MB payload
    fs = rimfs.mount(rimfs.pack({"w": w}))
    assert fs.overhead_bytes() < 0.01 * fs.total_bytes()


if _HAS_HYPOTHESIS:
    @given(st.dictionaries(
        st.text("abcdef", min_size=1, max_size=6),
        st.tuples(st.sampled_from(["float32", "int8", "int32", "float16"]),
                  st.lists(st.integers(1, 5), min_size=0, max_size=3)),
        min_size=1, max_size=8))
    @settings(max_examples=30, deadline=None)
    def test_property_roundtrip(spec):
        rng = np.random.RandomState(42)
        files = {k: (np.asarray(rng.randn(*shape)) * 10).astype(dt)
                 for k, (dt, shape) in spec.items()}
        fs = rimfs.mount(rimfs.pack(files))
        assert fs.verify()
        for k, v in files.items():
            np.testing.assert_array_equal(fs.read(k), v)
