"""Paged-KV serving engine (ISSUE 8): dense/paged conformance, block-aware
admission, slot recycling, arena residency, AOT executable sharing, and the
LM sampling / max_new contracts."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import rhal, rimfs
from repro.core.executor import Executor
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.serving.engine import (Request, ServingEngine, pack_params_image)
from repro.serving.paged_engine import PagedServingEngine
from repro.serving.scheduler import DeadlineScheduler


@pytest.fixture(scope="module")
def lm():
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    return cfg, params


def _requests(cfg, rng, n, plen=6, max_new=4, **kw):
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, (plen,))
                    .astype(np.int32), max_new=max_new, **kw)
            for i in range(n)]


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return [r.out_tokens for r in reqs]


# ------------------------------------------------------------- conformance
@pytest.mark.parametrize("batch", [1, 4])
def test_paged_matches_dense_greedy(lm, rng, batch):
    """Conformance matrix: greedy decode through the paged compiled path
    is bit-identical to the dense-cache engine at batch 1 and at
    max_batch — same prompts, same admission order."""
    cfg, params = lm
    prompts = [rng.randint(0, cfg.vocab_size, (5 + 2 * (i % 3),))
               .astype(np.int32) for i in range(batch)]
    dense = ServingEngine(cfg, params, max_batch=batch, max_seq=64)
    paged = PagedServingEngine(cfg, params, max_batch=batch, max_seq=64,
                               block_size=8)
    d = _drain(dense, [Request(rid=i, prompt=p, max_new=6)
                       for i, p in enumerate(prompts)])
    p = _drain(paged, [Request(rid=i, prompt=p, max_new=6)
                       for i, p in enumerate(prompts)])
    assert d == p


def test_decode_window_exact_token_count(lm, rng):
    """The multi-token decode window must not overshoot: max_new counts
    decode tokens exactly, whatever the window ladder does."""
    cfg, params = lm
    for max_new in (1, 3, 5, 8):
        eng = PagedServingEngine(cfg, params, max_batch=2, max_seq=64,
                                 block_size=8)
        reqs = _requests(cfg, rng, 2, max_new=max_new)
        _drain(eng, reqs)
        assert all(len(r.out_tokens) == max_new + 1 for r in reqs)


# --------------------------------------------------- admission / lifecycle
def test_out_of_blocks_is_shed_verdict_not_crash(lm, rng):
    """Pool exhaustion surfaces as a scheduler shed verdict at admission —
    OutOfBlocksError never fires mid-step."""
    cfg, params = lm
    sched = DeadlineScheduler()
    # 4 blocks of 8 = 32 tokens; each request reserves 6+6=12 -> 2 blocks
    eng = PagedServingEngine(cfg, params, max_batch=4, max_seq=64,
                             block_size=8, num_blocks=4, scheduler=sched)
    reqs = _requests(cfg, rng, 4, max_new=6)
    _drain(eng, reqs)
    served = [r for r in reqs if not r.shed]
    shed = [r for r in reqs if r.shed]
    assert len(served) == 2 and len(shed) == 2
    assert all(r.done and "out of KV blocks" in r.verdict for r in shed)
    assert all(len(r.out_tokens) == 7 for r in served)
    assert sched.shed_count == 2


def test_blocks_recycle_after_completion(lm, rng):
    """Completion releases blocks defrag-free; a second wave reuses the
    same physical pool with no leaked table entries."""
    cfg, params = lm
    eng = PagedServingEngine(cfg, params, max_batch=2, max_seq=64,
                             block_size=8, num_blocks=4)
    total = eng.cache.num_blocks
    for wave in range(3):
        reqs = _requests(cfg, rng, 2, max_new=4)
        _drain(eng, reqs)
        assert all(r.done and not r.shed for r in reqs)
        assert eng.cache.tables == {} and eng.cache.lengths == {}
        assert eng.cache.free_blocks() == total


def test_fifo_path_sheds_on_block_pressure(lm, rng):
    """Block-aware admission also guards the scheduler-less FIFO path."""
    cfg, params = lm
    eng = PagedServingEngine(cfg, params, max_batch=4, max_seq=64,
                             block_size=8, num_blocks=2)
    reqs = _requests(cfg, rng, 3, max_new=6)
    _drain(eng, reqs)
    shed = [r for r in reqs if r.shed]
    assert len(shed) == 2
    assert all("out of KV blocks" in r.verdict for r in shed)
    assert all(r.done for r in reqs)


# ------------------------------------------------------------- residency
def test_pool_registers_with_device_arena(lm, rng):
    """KV pool pages are arena-resident: fleet reshapes / watchdog
    accounting see them like any other resident buffer, and close()
    returns the ranges."""
    cfg, params = lm
    fs = rimfs.mount(pack_params_image(params))
    drv = rhal.make_eager_driver()
    base = drv.arena.bytes_in_use
    eng = PagedServingEngine.from_rimfs(cfg, fs, driver=drv, max_batch=2,
                                        max_seq=64, block_size=8)
    assert drv.arena.bytes_in_use >= base + eng.cache.pool_bytes()
    reqs = _requests(cfg, rng, 2, max_new=3)
    _drain(eng, reqs)
    with_pool = drv.arena.bytes_in_use
    eng.close()
    assert drv.arena.bytes_in_use == with_pool - eng.cache.pool_bytes()


def test_engine_accepts_tile_mesh(lm, rng):
    """A TileMesh provisions the paged engine like a driver: weights and
    pool anchor on the primary group, decode matches a plain engine."""
    cfg, params = lm
    fs = rimfs.mount(pack_params_image(params))
    mesh = rhal.TileMesh(2)
    eng_m = PagedServingEngine.from_rimfs(cfg, fs, driver=mesh, max_batch=2,
                                          max_seq=64, block_size=8)
    assert eng_m.mesh is mesh and eng_m.driver is mesh.primary
    eng_d = PagedServingEngine(cfg, params, max_batch=2, max_seq=64,
                               block_size=8)
    p = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    r1 = Request(rid=0, prompt=p, max_new=4)
    r2 = Request(rid=0, prompt=p, max_new=4)
    _drain(eng_m, [r1])
    _drain(eng_d, [r2])
    assert r1.out_tokens == r2.out_tokens


# ------------------------------------------------------- AOT executable cache
def test_aot_executables_shared_across_engines(lm, rng):
    """Two engines over the same service program share CRC-keyed AOT
    executables: the second engine's traffic adds no cache entries."""
    cfg, params = lm
    def mk():
        return PagedServingEngine(cfg, params, max_batch=2, max_seq=64,
                                  block_size=8)
    e1, e2 = mk(), mk()
    assert e1.program.crc() == e2.program.crc()
    r1 = _requests(cfg, rng, 2, max_new=4)
    _drain(e1, r1)
    keys_after_first = set(Executor._batch_cache)
    rng2 = np.random.RandomState(7)
    r2 = _requests(cfg, rng2, 2, max_new=4)
    _drain(e2, r2)
    assert set(Executor._batch_cache) == keys_after_first


# ------------------------------------------------------------- sampling
def test_sampling_respects_greedy_flag(lm, rng):
    """Regression (dead ``greedy`` flag): temperature sampling must
    actually diverge from argmax decoding, deterministically per seed."""
    cfg, params = lm
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)

    def run(engine_cls, **kw):
        eng = engine_cls(cfg, params, max_batch=1, max_seq=64, **kw)
        r = Request(rid=0, prompt=prompt, max_new=8)
        _drain(eng, [r])
        return r.out_tokens

    for cls, kw in ((ServingEngine, {}),
                    (PagedServingEngine, {"block_size": 8})):
        greedy = run(cls, greedy=True, **kw)
        s0 = run(cls, greedy=False, temperature=1.0, seed=0, **kw)
        s0b = run(cls, greedy=False, temperature=1.0, seed=0, **kw)
        s1 = run(cls, greedy=False, temperature=1.0, seed=1, **kw)
        assert s0 == s0b                      # deterministic per seed
        assert s0 != greedy or s1 != greedy   # the flag is live


def test_max_new_counts_decode_tokens(lm, rng):
    """Regression (off-by-one): a request yields exactly ``max_new``
    decode tokens; the prefill token rides along but does not consume
    the budget."""
    cfg, params = lm
    for cls, kw in ((ServingEngine, {}),
                    (PagedServingEngine, {"block_size": 8})):
        eng = cls(cfg, params, max_batch=2, max_seq=64, **kw)
        reqs = _requests(cfg, rng, 2, max_new=4)
        _drain(eng, reqs)
        assert all(len(r.out_tokens) == 5 for r in reqs), \
            [len(r.out_tokens) for r in reqs]


# ------------------------------------------------------------- over the wire
def test_server_serves_paged_engine(lm, rng):
    """The server's LM path is engine-polymorphic: a paged engine serves
    prompts over the wire with tokens matching a local run, and the
    telemetry summary reports KV pool occupancy."""
    from repro.serving.server import Client, InferenceServer

    cfg, params = lm
    eng = PagedServingEngine(cfg, params, max_batch=2, max_seq=64,
                             block_size=8)
    server = InferenceServer(engine=eng)
    addr = server.start()
    client = Client(addr)
    try:
        prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
                   for _ in range(3)]
        rids = [client.infer_async(prompt=p, max_new=3) for p in prompts]
        outs = [client.result(rid)["tokens"] for rid in rids]
        tel = client.telemetry()
        assert tel["engine"]["kv"]["num_blocks"] > 0
        ref = PagedServingEngine(cfg, params, max_batch=2, max_seq=64,
                                 block_size=8)
        refs = [Request(rid=i, prompt=p, max_new=3)
                for i, p in enumerate(prompts)]
        _drain(ref, refs)
        for out, r in zip(outs, refs):
            assert list(out) == r.out_tokens
    finally:
        client.close()
        server.stop()
