"""Chaos-injection harness for the fleet controller (ISSUE 6 tentpole).

Drives sustained client traffic against a live ``InferenceServer`` +
``FleetController`` while injecting the fault taxonomy from DESIGN.md
§10 — tile-group kills, DMA delays, CRC-corrupted frames, a bad-weight
swap — and asserts the system converges:

  * zero failed client requests (backpressure refusals retried by the
    client count as latency, not failure),
  * every response bit-identical to the precomputed single-device
    reference,
  * the scale cycle (base -> peak -> base), one hot weight swap and one
    tile-group kill+heal all complete mid-traffic,
  * the forced bad-weight swap is caught by the conformance probe and
    rolled back with the old binding still serving.

Three consumers share this file: ``tests/test_fleet.py`` imports
``run_chaos`` for tier-1 coverage, ``benchmarks/run.py`` loads it for
the ``fleet/*`` BENCH rows, and CI's chaos-matrix job executes it
directly (``python tests/chaos.py --groups N --seed S``) — exit 1 on
any failed invariant.
"""
from __future__ import annotations

import argparse
import json
import socket
import sys
import threading
import time

import numpy as np

from repro.core import rctc, rhal, rimfs
from repro.core.executor import Executor
from repro.core.fleet import FleetConfig, FleetController
from repro.core.integrity import IntegrityError
from repro.core import rbl
from repro.serving import protocol as proto
from repro.serving.server import Client, InferenceServer


def delay_dma(mesh, gid: int, seconds: float):
    """Fault: slow one group's async DMA issue path by ``seconds`` per
    transfer (a congested interconnect segment, not a dead one).
    Returns an undo callable."""
    driver = mesh.group(gid).driver
    orig = driver.dma_async

    def slow(host_buf, direction, prefetched=False):
        time.sleep(seconds)
        return orig(host_buf, direction, prefetched=prefetched)

    driver.dma_async = slow
    return lambda: setattr(driver, "dma_async", orig)


def slow_group_redeem(mesh, gid: int, seconds: float):
    """Fault: stall one group's inbound ticket redemption by ``seconds``
    per transfer (a congested link INTO the group, or a throttled
    endpoint). Unlike ``delay_dma`` this lands inside the stage-busy
    window, so the fleet controller's per-group stage EWMA sees the
    group as a straggler. Returns an undo callable."""
    driver = mesh.group(gid).driver
    orig = driver.dma_wait

    def slow(ticket):
        time.sleep(seconds)
        return orig(ticket)

    driver.dma_wait = slow
    return lambda: setattr(driver, "dma_wait", orig)


def corrupt_dma_payload(mesh, gid: int, count: int = 3):
    """Fault: flip one bit in the device-side payload of the next
    ``count`` CRC-stamped transfers landing on one group (a flaky
    interconnect lane). The ticket's CRC and retained source were
    stamped from the CLEAN bytes inside the real issue call, so
    redemption detects the corruption and the driver's bounded in-place
    retry re-issues from the source — through ``jax.device_put``
    directly, bypassing this wrapper, so a retry is never re-corrupted.
    Returns ``(undo, state)``."""
    import jax
    import jax.numpy as jnp
    driver = mesh.group(gid).driver
    orig = driver.dma_async
    state = {"corrupted": 0}

    def corrupting(host_buf, direction, prefetched=False):
        ticket = orig(host_buf, direction, prefetched=prefetched)
        if state["corrupted"] < count and ticket.crc is not None:
            bad = np.array(np.asarray(ticket.buf))      # writable copy:
            bad.reshape(-1).view(np.uint8)[0] ^= 0x01   # producer's buffer
            ticket.buf = jax.device_put(jnp.asarray(bad))  # stays clean
            state["corrupted"] += 1
        return ticket

    driver.dma_async = corrupting
    return (lambda: setattr(driver, "dma_async", orig)), state


def hang_until_killed(mesh, gid: int):
    """Fault: the next DMA redemption on one group blocks indefinitely —
    a wedged interconnect endpoint that no software timeout below the
    runtime can break. The block releases only when the group is killed
    (the watchdog preemption's hardware-reset analogue); the original
    guarded slot then raises ``TileFailure`` and the stage fails over.
    Returns ``(undo, state)``."""
    group = mesh.group(gid)
    driver = group.driver
    orig = driver.dma_wait
    state = {"hung": False, "released": False}

    def hang(ticket):
        if not state["hung"]:
            state["hung"] = True
            while group.alive:
                time.sleep(0.005)
            state["released"] = True
        return orig(ticket)

    driver.dma_wait = hang
    return (lambda: setattr(driver, "dma_wait", orig)), state


def inject_corrupt_frame(address) -> bool:
    """Fault: send an INFER frame whose CRC trailer is flipped. A healthy
    server answers with a connection-level protocol ERROR (or tears the
    connection down) without disturbing any other route. Returns True
    when the server reacted that way."""
    s = socket.create_connection(address)
    try:
        frame = bytearray(proto.encode_frame(proto.Msg.INFER_REQUEST,
                                             b"\x00" * 64))
        frame[-1] ^= 0xFF                       # corrupt the CRC-32
        s.sendall(bytes(frame))
        try:
            f = proto.recv_frame_ex(s, max_frame=proto.MAX_FRAME)
            return f.kind == proto.Msg.ERROR
        except Exception:
            return True                         # server closed on us: fine
    finally:
        s.close()


def _percentile(xs: list, p: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(p * len(xs)))]


def run_chaos(groups: int = 2, seed: int = 7, requests: int = 90,
              clients: int = 3, depth: int = 8, n: int = 24,
              scale_peak: int = 8, retries: int = 10,
              dma_delay_s: float = 0.2, p99_bound_s: float = 30.0,
              pace_s: float = 0.03, verbose: bool = False) -> dict:
    """One full chaos scenario; returns the report dict (see asserts in
    ``check_report`` for the invariants it must satisfy)."""
    if scale_peak == groups:                   # a scale cycle needs two
        scale_peak = 2 if groups > 2 else 8    # distinct mesh sizes
    rng = np.random.RandomState(seed)
    prog = rctc.compile_gemm_chain(depth, n)
    files = rctc.gemm_chain_weights(depth, n)
    image = rimfs.pack(files)
    # reference answers for a pool of distinct inputs, single-device
    pool = [rng.randn(n, n).astype(np.float32) for _ in range(8)]
    fs = rimfs.mount(image)
    refs = []
    for x in pool:
        out = Executor().run(rbl.bind(prog, rimfs=fs, inputs={"input": x}))
        refs.append({k: np.asarray(v) for k, v in out.items()})

    server = InferenceServer(mesh=rhal.TileMesh(groups), max_queue=256)
    addr = server.start()
    boot = Client(addr)
    boot.provision(image, prog.encode())
    boot.close()

    # The fault schedule scripts the scale transitions itself, so the
    # depth-based autoscaler is parked (thresholds unreachable) — ticks
    # still run the full observe/heal/probation machinery. The
    # autoscaler's own decision loop is covered by tests/test_fleet.py.
    cfg = FleetConfig(min_groups=min(2, groups),
                      max_groups=max(scale_peak, groups),
                      scale_up_depth=10 ** 6, scale_down_depth=-1)
    fleet = FleetController(server, cfg)

    done = threading.Event()
    counters = {"sent": 0, "ok": 0, "mismatch": 0}
    failures: list = []
    latencies: list = []
    lock = threading.Lock()
    per_client = requests // clients

    def traffic(cid: int) -> None:
        cl = Client(addr, retries=retries, backoff=0.02,
                    retry_seed=seed * 1000 + cid)
        try:
            for i in range(per_client):
                j = (cid * per_client + i) % len(pool)
                with lock:
                    counters["sent"] += 1
                t0 = time.perf_counter()
                try:
                    out = cl.infer(input=pool[j])
                except Exception as e:
                    with lock:
                        failures.append(f"client{cid} req{i}: {e!r}")
                    continue
                dt = time.perf_counter() - t0
                ident = set(out) == set(refs[j]) and all(
                    np.array_equal(out[k], refs[j][k]) for k in refs[j])
                with lock:
                    latencies.append(dt)
                    if ident:
                        counters["ok"] += 1
                    else:
                        counters["mismatch"] += 1
                time.sleep(pace_s)      # sustained traffic, not a burst:
                                        # the fault schedule lands
                                        # mid-stream, not after the fact
        finally:
            cl.close()

    threads = [threading.Thread(target=traffic, args=(c,), daemon=True)
               for c in range(clients)]
    for t in threads:
        t.start()

    # -------- coordinator: deterministic fault schedule at traffic
    # milestones (fractions of total completed requests), seeded by the
    # CLI so the chaos-matrix job replays the same schedule.
    total = per_client * clients
    kill_gid = int(rng.randint(1, scale_peak))
    report: dict = {"schedule": {"seed": seed, "kill_gid": kill_gid},
                    "faults": [], "timings": {}}

    def timed(key: str, fn):
        t0 = time.perf_counter()
        out = fn()
        report["timings"][key] = time.perf_counter() - t0
        return out

    def completed() -> int:
        with lock:
            return counters["ok"] + counters["mismatch"] + \
                len(failures)

    def wait_frac(frac: float, timeout: float = 120.0) -> None:
        deadline = time.monotonic() + timeout
        while completed() < int(total * frac):
            if time.monotonic() > deadline or done.is_set():
                return
            fleet.tick()
            time.sleep(0.02)

    def log(msg: str) -> None:
        if verbose:
            print(f"[chaos {completed():3d}/{total}] {msg}", flush=True)

    undo_delay = None
    try:
        wait_frac(0.10)
        log(f"scale {groups} -> {scale_peak}")
        timed("scale_up", lambda: fleet.scale_to(scale_peak))
        report["faults"].append("scale_up")

        wait_frac(0.25)
        log(f"kill tile group {kill_gid}")
        server.mesh.kill(kill_gid)          # in-flight stages fail over
        report["faults"].append(f"kill_g{kill_gid}")
        t_kill = time.perf_counter()
        for _ in range(20):                 # converge: tick until repaired
            rep = fleet.tick()              # (partial reshape for a single
            if any(k in ("heal_complete", "reshape_complete")
                   for k, _ in fleet.events):   # dead group, heal for more)
                break
            time.sleep(0.02)
        report["timings"]["kill_to_heal"] = time.perf_counter() - t_kill
        log("repaired")

        wait_frac(0.33)
        log("journaled install: fault at every mid-write point, fsck "
            "recovers")
        store = rimfs.ImageStore(image)
        repacked = rimfs.pack(files)
        jres = {"rolled_back": 0, "replayed": 0}
        for phase in ("after_intent", "after_stage", "after_commit"):
            try:
                store.install(repacked, fail_at=phase)
            except IntegrityError:
                pass                    # the injected "crash"
            fr = store.fsck(strict=True)
            jres["rolled_back"] += len(fr["rolled_back"])
            jres["replayed"] += len(fr["replayed"])
        jres["image_ok"] = bool(store.fsck(strict=True)["image"]["ok"])
        report["journal"] = jres
        report["faults"].append("journal_fault")
        # the replayed install IS the repacked image: the good swap below
        # serves journal-recovered bytes, closing the recovery loop
        recovered_image = store.image()

        wait_frac(0.36)
        tgt = 1 if server.mesh.n_groups > 1 else 0
        log(f"corrupt DMA payloads toward group {tgt}")
        undo_corrupt, cstate = corrupt_dma_payload(server.mesh, tgt,
                                                   count=3)
        for _ in range(200):            # traffic drives the transfers
            if cstate["corrupted"] >= 3:
                break
            time.sleep(0.03)
        undo_corrupt()
        drv = server.mesh.group(tgt).driver
        report["dma_crc"] = {k: drv.stats.get(k, 0) for k in
                             ("dma_crc_checked", "dma_crc_mismatch",
                              "dma_retry", "dma_retry_recovered")}
        report["faults"].append("dma_payload_corruption")

        wait_frac(0.40)
        log("hot swap: identical weights, journal-recovered image")
        good = timed("swap_good", lambda: fleet.swap_weights(
            recovered_image, label="repack"))
        report["good_swap"] = good
        report["faults"].append("swap_good")
        for _ in range(cfg.probation_ticks + 1):   # probation -> finalize
            fleet.tick()
        fleet.finalize_swap()                      # no-op if already done

        wait_frac(0.55)
        log("hot swap: WRONG weights (probe must roll back)")
        bad_files = rctc.gemm_chain_weights(depth, n, seed=seed + 1)
        bad = timed("swap_bad", lambda: fleet.swap_weights(
            rimfs.pack(bad_files), label="bad"))
        report["bad_swap"] = bad
        report["faults"].append("swap_bad")

        wait_frac(0.62)
        tgt = 1 if server.mesh.n_groups > 1 else 0
        log(f"hang DMA redemption on group {tgt} "
            f"(watchdog must preempt)")
        undo_hang, hstate = hang_until_killed(server.mesh, tgt)
        # a dedicated probe drives one dispatch through the mesh so the
        # wedge is guaranteed to trigger even if the client traffic has
        # already drained (short smoke schedules) — the probe itself
        # must come back bit-identical after the preempt + failover
        probe: dict = {}

        def probe_request() -> None:
            pc = Client(addr, retries=retries, backoff=0.02,
                        retry_seed=seed * 1000 + 999)
            try:
                probe["out"] = pc.infer(input=pool[0])
            except Exception as e:
                probe["error"] = repr(e)
            finally:
                pc.close()

        pt = threading.Thread(target=probe_request, daemon=True)
        pt.start()
        t_hang = time.perf_counter()
        for _ in range(800):            # watchdog budget + failover
            if hstate["released"]:
                break
            fleet.tick()                # heal restores full capacity
            time.sleep(0.02)
        undo_hang()
        pt.join(timeout=30)
        if "out" in probe:
            ident = set(probe["out"]) == set(refs[0]) and all(
                np.array_equal(probe["out"][k], refs[0][k])
                for k in refs[0])
            if not ident:
                with lock:
                    failures.append("hang probe: output not "
                                    "bit-identical after preemption")
        else:
            with lock:
                failures.append(f"hang probe: "
                                f"{probe.get('error', 'no reply')}")
        report["timings"]["hang_to_preempt"] = \
            time.perf_counter() - t_hang
        report["watchdog"] = {
            "released": hstate["released"],
            "preemptions": server.platform.telemetry.counter(
                "watchdog_preemptions"),
        }
        report["faults"].append("hung_dispatch")
        log("preempted + failed over")

        wait_frac(0.68)
        log(f"DMA delay {dma_delay_s}s on group 0")
        undo_delay = delay_dma(server.mesh, 0, dma_delay_s)
        report["faults"].append("dma_delay_g0")
        # the slow group stretches the dispatcher's inter-beat gap —
        # sample the EWMA straggler verdict while the delay is live
        straggler_seen = False
        for _ in range(40):
            v = server.platform.heartbeats.check()
            if v["verdicts"].get("dispatcher") == "straggler":
                straggler_seen = True
                break
            time.sleep(0.03)
        undo_delay()
        undo_delay = None
        report["dispatcher_straggler_seen"] = straggler_seen

        wait_frac(0.80)
        log("corrupt-CRC frame on a sacrificial connection")
        report["crc_fault_contained"] = inject_corrupt_frame(addr)
        report["faults"].append("crc_corruption")

        wait_frac(0.90)
        log(f"scale {scale_peak} -> {groups}")
        timed("scale_down", lambda: fleet.scale_to(groups))
        report["faults"].append("scale_down")

        for t in threads:
            t.join(timeout=180)
        done.set()
    finally:
        if undo_delay is not None:
            undo_delay()
        fleet.stop()
        server.stop()

    report.update({
        "sent": counters["sent"], "ok": counters["ok"],
        "failed": len(failures), "failures": failures[:10],
        "mismatches": counters["mismatch"],
        "retries": None,   # per-client; summed below when needed
        "p50_s": _percentile(latencies, 0.50),
        "p99_s": _percentile(latencies, 0.99),
        "p99_bound_s": p99_bound_s,
        "n_groups_final": server.mesh.n_groups,
        "events": [k for k, _ in fleet.events],
        "fleet": fleet.summary(),
        "counters": server.platform.telemetry.counters(),
    })
    return report


def check_report(report: dict) -> list:
    """The invariants the chaos scenario must satisfy; returns the list
    of violations (empty == converged)."""
    bad = []
    if report["failed"]:
        bad.append(f"{report['failed']} failed requests: "
                   f"{report['failures']}")
    if report["mismatches"]:
        bad.append(f"{report['mismatches']} non-bit-identical responses")
    if report["ok"] != report["sent"]:
        bad.append(f"ok {report['ok']} != sent {report['sent']}")
    if report.get("good_swap") != "committed":
        bad.append(f"good swap not committed: {report.get('good_swap')}")
    if report.get("bad_swap") != "rolled_back":
        bad.append(f"bad swap not rolled back: {report.get('bad_swap')}")
    if not report.get("crc_fault_contained"):
        bad.append("CRC corruption was not contained")
    ev = report["events"]
    for needed in ("scale_complete", "swap_committed",
                   "swap_probed", "swap_rolled_back"):
        if needed not in ev:
            bad.append(f"missing fleet event {needed!r}")
    if "heal_complete" not in ev and "reshape_complete" not in ev:
        bad.append("no repair event: neither heal_complete nor "
                   "reshape_complete")
    if report["p99_s"] > report["p99_bound_s"]:
        bad.append(f"p99 {report['p99_s']:.3f}s past bound "
                   f"{report['p99_bound_s']:.3f}s")
    faults = report.get("faults", ())
    if "dma_payload_corruption" in faults:
        dc = report.get("dma_crc", {})
        if not dc.get("dma_retry_recovered"):
            bad.append("corrupted DMA payloads never recovered by the "
                       f"in-place retry: {dc}")
    if "hung_dispatch" in faults:
        wd = report.get("watchdog", {})
        if not wd.get("released"):
            bad.append("hung dispatch was never preempted (watchdog "
                       "kill did not release the wedge)")
        if not wd.get("preemptions"):
            bad.append("watchdog_preemptions counter never incremented")
    j = report.get("journal")
    if j is not None:
        if j.get("replayed") != 1 or j.get("rolled_back") != 2:
            bad.append(f"journal recovery wrong shape: {j} "
                       "(want 1 replay, 2 rollbacks)")
        if not j.get("image_ok"):
            bad.append("post-recovery image failed fsck")
    return bad


def run_rollout_chaos(groups: int = 2, seed: int = 7, requests: int = 96,
                      clients: int = 3, depth: int = 8, n: int = 24,
                      retries: int = 10, slow_s: float = 0.15,
                      burst: int = 48, p99_bound_s: float = 30.0,
                      pace_s: float = 0.03,
                      verbose: bool = False) -> dict:
    """Safe-rollout & overload chaos scenario (ISSUE 10):

      * ``canary_good``  — canary an identical-weights repack; the SPRT
        must auto-promote it mid-traffic with zero mismatched responses.
      * ``canary_bad``   — canary WRONG weights; the SPRT must auto-
        abort, every sampled disagreement answered with primary bytes
        (zero wrong bytes reach any client).
      * ``slow_group``   — stall one group's inbound redemption; the
        stage-EWMA straggler verdict must partial-reshape exactly that
        group (survivor drivers untouched) without dropping a request.
      * ``overload_burst`` — a low-priority flood; the brown-out ladder
        must engage, every refusal carry a typed verdict kind, the
        scripted failing group get circuit-broken and probed back, and
        the ladder walk back to rung 0 after the burst drains.
    """
    from repro.serving.overload import BrownoutController, OverloadConfig
    from repro.serving.scheduler import VERDICT_KINDS
    from repro.serving.server import RequestShed, ServerBusy

    rng = np.random.RandomState(seed)
    prog = rctc.compile_gemm_chain(depth, n)
    files = rctc.gemm_chain_weights(depth, n)
    image = rimfs.pack(files)
    pool = [rng.randn(n, n).astype(np.float32) for _ in range(8)]
    fs = rimfs.mount(image)
    refs = []
    for x in pool:
        out = Executor().run(rbl.bind(prog, rimfs=fs, inputs={"input": x}))
        refs.append({k: np.asarray(v) for k, v in out.items()})

    server = InferenceServer(mesh=rhal.TileMesh(groups), max_queue=256)
    addr = server.start()
    boot = Client(addr)
    boot.provision(image, prog.encode())
    boot.close()

    # stage_straggler_ratio must clear the mesh's NATURAL stage imbalance
    # (an 8-way pipeline runs its heaviest stage at 10-30x the median
    # busy time) while still catching the scripted slow_s stall, which
    # lands at 100x+ the median — 50x separates the two cleanly at every
    # matrix mesh size
    fleet_cfg = FleetConfig(scale_up_depth=10 ** 6, scale_down_depth=-1,
                            straggler_ticks=2, stage_straggler_ratio=50.0)
    fleet = FleetController(server, fleet_cfg)
    over = BrownoutController(server, OverloadConfig(
        p99_high=0.05, min_window=2, escalate_ticks=1, recover_ticks=2,
        shed_priority=2, breaker_cooldown_ticks=1))

    done = threading.Event()
    counters = {"sent": 0, "ok": 0, "mismatch": 0}
    failures: list = []
    latencies: list = []
    lock = threading.Lock()
    per_client = requests // clients

    def traffic(cid: int) -> None:
        cl = Client(addr, retries=retries, backoff=0.02,
                    retry_seed=seed * 1000 + cid)
        try:
            for i in range(per_client):
                j = (cid * per_client + i) % len(pool)
                with lock:
                    counters["sent"] += 1
                t0 = time.perf_counter()
                try:
                    out = cl.infer(input=pool[j], priority=0)
                except Exception as e:
                    with lock:
                        failures.append(f"client{cid} req{i}: {e!r}")
                    continue
                dt = time.perf_counter() - t0
                ident = set(out) == set(refs[j]) and all(
                    np.array_equal(out[k], refs[j][k]) for k in refs[j])
                with lock:
                    latencies.append(dt)
                    counters["ok" if ident else "mismatch"] += 1
                time.sleep(pace_s)
        finally:
            cl.close()

    threads = [threading.Thread(target=traffic, args=(c,), daemon=True)
               for c in range(clients)]
    for t in threads:
        t.start()

    total = per_client * clients
    report: dict = {"schedule": {"seed": seed, "groups": groups},
                    "faults": [], "timings": {}}

    def completed() -> int:
        with lock:
            return counters["ok"] + counters["mismatch"] + len(failures)

    def wait_frac(frac: float, timeout: float = 120.0,
                  tick_overload: bool = False) -> None:
        deadline = time.monotonic() + timeout
        while completed() < int(total * frac):
            if time.monotonic() > deadline or done.is_set():
                return
            fleet.tick()
            if tick_overload:
                over.tick()
            time.sleep(0.02)

    def log(msg: str) -> None:
        if verbose:
            print(f"[rollout {completed():3d}/{total}] {msg}", flush=True)

    def tick_until(pred, limit: int = 400, overload: bool = False,
                   fleet_ticks: bool = True):
        # fleet_ticks=False while the breaker owns a group: the fleet's
        # dead-group replace policy must not race the circuit's
        # kill/probe/revive cycle
        for _ in range(limit):
            if fleet_ticks:
                fleet.tick()
            if overload:
                over.tick()
            if pred():
                return True
            time.sleep(0.02)
        return pred()

    undo_slow = None
    try:
        # ---------------------------------------------- canary_good
        wait_frac(0.08)
        log("canary GOOD image (identical weights repack)")
        t0 = time.perf_counter()
        started = fleet.canary(rimfs.pack(files), fraction=0.5,
                               label="good")
        report["canary_good_started"] = started
        promoted = tick_until(lambda: any(
            k == "canary_promoted" for k, _ in fleet.events))
        report["timings"]["canary_to_promote"] = time.perf_counter() - t0
        report["canary_good"] = "promoted" if promoted else "undecided"
        good_ev = [p for k, p in fleet.events if k == "canary_promoted"]
        if good_ev:
            report["canary_good_stats"] = good_ev[-1].get("stats")
        report["faults"].append("canary_good")
        log(f"promoted: {promoted}")

        # ----------------------------------------------- canary_bad
        wait_frac(0.30)
        log("canary BAD image (wrong weights — SPRT must abort)")
        bad_files = rctc.gemm_chain_weights(depth, n, seed=seed + 1)
        started = fleet.canary(rimfs.pack(bad_files), fraction=0.5,
                               label="bad")
        report["canary_bad_started"] = started
        aborted = tick_until(lambda: any(
            k == "canary_aborted" for k, _ in fleet.events))
        report["canary_bad"] = "aborted" if aborted else "undecided"
        bad_ev = [p for k, p in fleet.events if k == "canary_aborted"]
        if bad_ev:
            report["canary_bad_stats"] = bad_ev[-1].get("stats")
        report["faults"].append("canary_bad_image")
        log(f"aborted: {aborted}")

        # ------------------------------------------------ slow_group
        wait_frac(0.45)
        slow_gid = 1 if groups > 1 else 0
        mesh_before = server.mesh
        peers = {g: mesh_before.group(g).driver
                 for g in mesh_before.gids if g != slow_gid}
        old_driver = mesh_before.group(slow_gid).driver
        log(f"slow group {slow_gid}: stalled redemption {slow_s}s")
        undo_slow = slow_group_redeem(server.mesh, slow_gid, slow_s)
        report["faults"].append("slow_group")
        t0 = time.perf_counter()
        # count from a baseline: a reshape that predates this fault (for
        # any reason) must not satisfy the straggler-replacement wait
        n_reshapes = sum(1 for k, _ in fleet.events
                         if k == "reshape_complete")
        reshaped = tick_until(lambda: sum(
            1 for k, _ in fleet.events
            if k == "reshape_complete") > n_reshapes)
        if undo_slow is not None:
            undo_slow()
            undo_slow = None
        report["timings"]["slow_to_reshape"] = time.perf_counter() - t0
        report["reshape"] = {
            "happened": reshaped,
            "same_mesh": server.mesh is mesh_before,
            "replaced_driver_changed":
                server.mesh.group(slow_gid).driver is not old_driver,
            "survivors_untouched": all(
                server.mesh.group(g).driver is d
                for g, d in peers.items()),
            "log": [(p.get("group"), p.get("reason"))
                    for k, p in fleet.events if k == "reshape_complete"],
        }
        log(f"reshaped: {report['reshape']}")

        # -------------------------------------------- overload_burst
        wait_frac(0.60)
        log(f"overload burst: {burst} low-priority requests + scripted "
            f"failing group")
        # scripted flaky group for the rung-4 circuit breaker
        flaky_gid = 0
        for _ in range(3):
            server.platform.post("tile_failure",
                                 {"group": flaky_gid, "stage": 0})
        shed_kinds: list = []
        burst_ok = [0]

        def burst_traffic(bid: int) -> None:
            cl = Client(addr, retry_seed=seed * 77 + bid)
            try:
                for i in range(burst // 6):
                    try:
                        cl.infer(input=pool[(bid + i) % len(pool)],
                                 priority=3)
                        with lock:
                            burst_ok[0] += 1
                    except (RequestShed, ServerBusy) as e:
                        with lock:
                            shed_kinds.append(getattr(e, "kind", ""))
                    except Exception:
                        with lock:
                            shed_kinds.append("")
            finally:
                cl.close()

        bt = [threading.Thread(target=burst_traffic, args=(b,),
                               daemon=True) for b in range(6)]
        t0 = time.perf_counter()
        for t in bt:
            t.start()
        max_rung = [0]

        def pump_burst():
            over.tick()
            max_rung[0] = max(max_rung[0], over.rung)
            return not any(t.is_alive() for t in bt)

        tick_until(pump_burst, limit=800, fleet_ticks=False)
        for t in bt:
            t.join(timeout=60)
        # let the ladder walk back down with hysteresis and the breaker
        # probe its quarantined group back in (fleet ticks parked: the
        # replace policy must not race the circuit's kill/revive cycle)
        recovered = tick_until(
            lambda: over.rung == 0 and over.breaker.state == "closed",
            limit=800, overload=True, fleet_ticks=False)
        report["timings"]["overload_recovery"] = time.perf_counter() - t0
        report["overload"] = {
            "max_rung": max_rung[0], "final_rung": over.rung,
            "recovered": recovered,
            "burst_ok": burst_ok[0], "burst_shed": len(shed_kinds),
            "shed_kinds": sorted(set(shed_kinds)),
            "untyped_sheds": sum(1 for k in shed_kinds
                                 if k not in VERDICT_KINDS),
            "breaker": dict(over.breaker.stats,
                            state=over.breaker.state),
            "summary": over.summary(),
        }
        report["faults"].append("overload_burst")
        log(f"overload: {report['overload']}")

        for t in threads:
            t.join(timeout=180)
        done.set()
    finally:
        if undo_slow is not None:
            undo_slow()
        fleet.stop()
        over.stop()
        server.stop()

    report.update({
        "sent": counters["sent"], "ok": counters["ok"],
        "failed": len(failures), "failures": failures[:10],
        "mismatches": counters["mismatch"],
        "p50_s": _percentile(latencies, 0.50),
        "p99_s": _percentile(latencies, 0.99),
        "p99_bound_s": p99_bound_s,
        "events": [k for k, _ in fleet.events] +
        [k for k, _ in over.events],
        "fleet": fleet.summary(),
        "counters": server.platform.telemetry.counters(),
    })
    return report


def check_rollout_report(report: dict) -> list:
    """Invariants for the safe-rollout scenario (empty == converged)."""
    bad = []
    if report["failed"]:
        bad.append(f"{report['failed']} failed requests: "
                   f"{report['failures']}")
    if report["mismatches"]:
        bad.append(f"{report['mismatches']} non-bit-identical responses "
                   "(a canary served wrong bytes?)")
    if report["ok"] != report["sent"]:
        bad.append(f"ok {report['ok']} != sent {report['sent']}")
    if report.get("canary_good") != "promoted":
        bad.append(f"good canary not promoted: {report.get('canary_good')}")
    if report.get("canary_bad") != "aborted":
        bad.append(f"bad canary not aborted: {report.get('canary_bad')}")
    bstats = report.get("canary_bad_stats") or {}
    if bstats.get("served_shadow", 0):
        bad.append(f"bad canary served {bstats['served_shadow']} shadow "
                   "responses")
    rs = report.get("reshape", {})
    if not rs.get("happened"):
        bad.append("slow group never partial-reshaped")
    if not rs.get("same_mesh"):
        bad.append("partial reshape rebuilt the mesh instead of splicing")
    if not rs.get("replaced_driver_changed"):
        bad.append("straggler group's driver not replaced")
    if not rs.get("survivors_untouched"):
        bad.append("partial reshape touched a surviving group's driver")
    ov = report.get("overload", {})
    if ov.get("max_rung", 0) < 1:
        bad.append("overload burst never engaged the brown-out ladder")
    if ov.get("final_rung") != 0 or not ov.get("recovered"):
        bad.append(f"ladder did not walk back to rung 0: {ov}")
    if ov.get("untyped_sheds"):
        bad.append(f"{ov['untyped_sheds']} sheds carried no typed "
                   f"verdict kind (kinds seen: {ov.get('shed_kinds')})")
    if ov.get("burst_shed", 0) + ov.get("burst_ok", 0) == 0:
        bad.append("overload burst sent no traffic")
    ev = report["events"]
    for needed in ("canary_started", "canary_promoted", "canary_aborted",
                   "reshape_started", "reshape_complete"):
        if needed not in ev:
            bad.append(f"missing rollout event {needed!r}")
    if ov.get("max_rung", 0) >= 4:
        br = ov.get("breaker", {})
        if not br.get("trips"):
            bad.append("rung 4 reached but the breaker never tripped")
        if br.get("state") != "closed":
            bad.append(f"breaker did not recover: {br}")
    if report["p99_s"] > report["p99_bound_s"]:
        bad.append(f"p99 {report['p99_s']:.3f}s past bound "
                   f"{report['p99_bound_s']:.3f}s")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", choices=("core", "rollout"),
                    default="core",
                    help="core = scale/heal/swap taxonomy; rollout = "
                         "canary / partial reshape / brown-out ladder")
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--requests", type=int, default=90)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--scale-peak", type=int, default=8)
    ap.add_argument("--p99-bound-s", type=float, default=30.0)
    ap.add_argument("--log", type=str, default=None,
                    help="write the full chaos event report as JSON "
                         "(CI uploads it as an artifact on failure)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.scenario == "rollout":
        report = run_rollout_chaos(
            groups=args.groups, seed=args.seed, requests=args.requests,
            clients=args.clients, p99_bound_s=args.p99_bound_s,
            verbose=args.verbose)
        violations = check_rollout_report(report)
        if args.log:
            with open(args.log, "w") as f:
                json.dump({"report": report, "violations": violations}, f,
                          indent=2, default=lambda o: o.item()
                          if hasattr(o, "item") else str(o))
        print(f"rollout chaos: sent={report['sent']} ok={report['ok']} "
              f"failed={report['failed']} "
              f"mismatches={report['mismatches']} "
              f"canary_good={report.get('canary_good')} "
              f"canary_bad={report.get('canary_bad')} "
              f"reshape={report.get('reshape', {}).get('happened')} "
              f"overload={report.get('overload', {}).get('max_rung')}"
              f"->>{report.get('overload', {}).get('final_rung')}")
        for v in violations:
            print(f"VIOLATION: {v}", file=sys.stderr)
        return 1 if violations else 0
    report = run_chaos(groups=args.groups, seed=args.seed,
                       requests=args.requests, clients=args.clients,
                       scale_peak=args.scale_peak,
                       p99_bound_s=args.p99_bound_s, verbose=args.verbose)
    violations = check_report(report)
    if args.log:
        with open(args.log, "w") as f:
            json.dump({"report": report, "violations": violations}, f,
                      indent=2, default=lambda o: o.item()
                      if hasattr(o, "item") else str(o))
    print(f"chaos: sent={report['sent']} ok={report['ok']} "
          f"failed={report['failed']} mismatches={report['mismatches']} "
          f"p50={report['p50_s'] * 1e3:.1f}ms "
          f"p99={report['p99_s'] * 1e3:.1f}ms "
          f"straggler_seen={report.get('dispatcher_straggler_seen')} "
          f"faults={report['faults']} events={report['fleet']['events']}")
    for v in violations:
        print(f"VIOLATION: {v}", file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
