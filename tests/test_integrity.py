"""Integrity plane (ISSUE 7 tentpole): checksummed DMA with bounded
retry, arena quarantine + CRC re-validation, journaled RIMFS installs
with fsck replay/rollback, verify-on-read, and the execution watchdog."""
import threading
import time

import numpy as np
import pytest

from repro.core import rbl, rctc, rhal, rimfs
from repro.core.integrity import IntegrityError, payload_crc
from repro.core.rtpm import Platform, ServiceLoop, Watchdog


def _corrupt_ticket(ticket):
    """Bit-flip the ticket's device payload (leaves crc/src clean —
    exactly what a flaky interconnect lane does post-issue)."""
    import jax
    bad = np.array(np.asarray(ticket.buf))
    bad.reshape(-1).view(np.uint8)[0] ^= 0x01
    ticket.buf = jax.device_put(bad)


# ------------------------------------------------------------ DMA CRC
def test_dma_ticket_stamped_and_verified(rng):
    drv = rhal.make_eager_driver()
    host = rng.randn(64).astype(np.float32)
    t = drv.dma_async(host, "h2d")
    assert t.crc == payload_crc(host)        # stamped at issue
    out = drv.dma_wait(t)
    np.testing.assert_array_equal(np.asarray(out), host)
    assert drv.stats["dma_crc_checked"] == 1
    assert drv.stats.get("dma_crc_mismatch", 0) == 0


def test_dma_corruption_recovered_by_retry(rng):
    drv = rhal.make_eager_driver()
    host = rng.randn(32, 32).astype(np.float32)
    t = drv.dma_async(host, "h2d")
    _corrupt_ticket(t)
    out = drv.dma_wait(t)                    # retry re-issues from src
    np.testing.assert_array_equal(np.asarray(out), host)  # bit-identical
    assert drv.stats["dma_crc_mismatch"] == 1
    assert drv.stats["dma_retry"] == 1
    assert drv.stats["dma_retry_recovered"] == 1
    assert t.retries == 1


def test_dma_retries_exhausted_raises_integrity_error(rng):
    drv = rhal.make_eager_driver()
    drv.integrity.dma_retries = 0            # no budget: escalate at once
    host = rng.randn(16).astype(np.float32)
    t = drv.dma_async(host, "h2d")
    _corrupt_ticket(t)
    with pytest.raises(IntegrityError, match="CRC mismatch"):
        drv.dma_wait(t)
    assert drv.stats["dma_crc_mismatch"] == 1
    assert drv.stats.get("dma_retry", 0) == 0


def test_dma_crc_disabled_skips_stamp_and_check(rng):
    drv = rhal.make_eager_driver()
    drv.integrity.enabled = False            # the benchmarked off-switch
    t = drv.dma_async(rng.randn(8).astype(np.float32), "h2d")
    assert t.crc is None
    drv.dma_wait(t)
    assert drv.stats.get("dma_crc_checked", 0) == 0


def test_dma_d2h_never_stamped(rng):
    """d2h verification would force a host sync at issue and kill the
    split-phase overlap; the host side is covered by RIMFS CRCs."""
    drv = rhal.make_eager_driver()
    host = rng.randn(8).astype(np.float32)
    dev = drv.dma_wait(drv.dma_async(host, "h2d"))
    t = drv.dma_async(dev, "d2h")
    assert t.crc is None
    np.testing.assert_array_equal(drv.dma_wait(t), host)


def test_dma_batch_tickets_stamped(rng):
    drv = rhal.make_eager_driver()
    hosts = [rng.randn(16).astype(np.float32) for _ in range(3)]
    tickets = drv.dma_async_batch(hosts, "h2d")
    for t, h in zip(tickets, hosts):
        assert t.crc == payload_crc(h)
        np.testing.assert_array_equal(np.asarray(drv.dma_wait(t)), h)


# --------------------------------------------- quarantine / revalidation
def test_kill_quarantines_arena_and_revive_revalidates(rng):
    mesh = rhal.TileMesh(2)
    files = {"w": rng.randn(8, 8).astype(np.float32)}
    fs = rimfs.mount(rimfs.pack(files))
    fs.resident(mesh.group(0).driver)        # pin weights on group 0
    mesh.kill(0)
    assert mesh.group(0).driver.arena.poisoned
    with pytest.raises(rhal.TileFailure, match="quarantined"):
        mesh.group(0).driver.arena.alloc(128)
    mesh.revive(0, rimfs=fs)                 # CRC-clean: quarantine lifts
    assert not mesh.group(0).driver.arena.poisoned
    assert mesh.alive(0)
    assert mesh.group(0).driver.arena.alloc(128) >= 0


def test_revive_rejects_corrupted_residency(rng):
    mesh = rhal.TileMesh(1)
    files = {"w": rng.randn(8, 8).astype(np.float32)}
    fs = rimfs.mount(rimfs.pack(files))
    ri = fs.resident(mesh.group(0).driver)
    mesh.kill(0)
    import jax
    bad = np.array(np.asarray(ri.buffer("w")))
    bad.reshape(-1).view(np.uint8)[3] ^= 0x40
    ri._bufs["w"] = jax.device_put(bad)      # half-written weight copy
    with pytest.raises(IntegrityError, match="re-validation"):
        mesh.revive(0, rimfs=fs)
    assert mesh.group(0).driver.arena.poisoned   # still quarantined


# -------------------------------------------------------- verify-on-read
def test_read_verifies_file_crc(rng):
    img = bytearray(rimfs.pack({"w": rng.randn(32).astype(np.float32)}))
    fs0 = rimfs.mount(bytes(img))
    off, _ = fs0.address_of("w")
    img[off + 2] ^= 0x08
    fs = rimfs.mount(bytes(img))
    with pytest.raises(rimfs.RIMFSError, match="read"):
        fs.read("w")
    fs.read("w", verify=False)               # explicit opt-out still works
    fs2 = rimfs.RIMFS(bytes(img), verify_reads=False)
    fs2.read("w")                            # policy-level opt-out


def test_read_verification_memoized(rng):
    fs = rimfs.mount(rimfs.pack({"w": rng.randn(64).astype(np.float32)}))
    fs.read("w")
    assert "w" in fs._verified
    fs.read("w")                             # second read: memo hit


def test_rimfs_error_is_integrity_error(rng):
    assert issubclass(rimfs.RIMFSError, IntegrityError)


def test_corrupt_image_rejected_before_bind(rng):
    """Satellite: a poisoned weight image must be rejected at provision
    (bring-up fsck), long before any buffer binds or uploads."""
    prog = rctc.compile_gemm_chain(2, 8)
    files = rctc.gemm_chain_weights(2, 8)
    img = bytearray(rimfs.pack(files))
    fs0 = rimfs.mount(bytes(img))
    off, _ = fs0.address_of(sorted(files)[0])
    img[off + 1] ^= 0x20
    plat = Platform()
    with pytest.raises(rimfs.RIMFSError):
        plat.provision(image=bytes(img), program_bytes=prog.encode())
    # and even with bring-up verification off, the read-side CRC check
    # refuses the poisoned file before it can bind
    plat2 = Platform()
    plat2.provision(image=bytes(img), program_bytes=prog.encode(),
                    verify=False)
    with pytest.raises(rimfs.RIMFSError):
        plat2.bind()


def test_fsck_reports_and_raises(rng):
    img = bytearray(rimfs.pack({"a": rng.randn(16).astype(np.float32),
                                "b": rng.randn(16).astype(np.float32)}))
    fs = rimfs.mount(bytes(img))
    rep = fs.fsck(strict=True)
    assert rep["ok"] and rep["files"] == 2 and not rep["bad_files"]
    off, _ = fs.address_of("a")
    img[off] ^= 0x01
    bad_fs = rimfs.RIMFS(bytes(img), verify_reads=False)
    rep = bad_fs.fsck(strict=False)
    assert not rep["ok"] and rep["bad_files"] == ["a"]
    with pytest.raises(rimfs.RIMFSError, match="CRC"):
        bad_fs.fsck(strict=True)             # trailer check trips first


# ------------------------------------------------------ journaled installs
def test_journaled_install_fault_matrix(rng):
    """A fault at every mid-write point leaves the visible image either
    wholly old or wholly new; fsck rolls back uncommitted staging and
    replays committed flips."""
    img_a = rimfs.pack({"w": rng.randn(8).astype(np.float32)})
    img_b = rimfs.pack({"w": rng.randn(8).astype(np.float32)})
    store = rimfs.ImageStore(img_a)
    assert store.image() == img_a

    for phase, visible_after in (("after_intent", img_a),
                                 ("after_stage", img_a),
                                 ("after_commit", img_b)):
        with pytest.raises(IntegrityError, match="injected"):
            store.install(img_b, fail_at=phase)
        assert store.image() in (img_a, img_b)   # never a mixture
        rep = store.fsck(strict=True)
        assert store.image() == visible_after
        assert rep["image"]["ok"]
        if phase == "after_commit":
            assert len(rep["replayed"]) == 1
        else:
            assert len(rep["rolled_back"]) == 1
        store._image = bytes(img_a)              # reset for next phase
    assert not store.journal.pending()           # journal fully resolved


def test_journaled_install_survives_process_crash(tmp_path, rng):
    """File-backed durability: the 'crash' is a NEW ImageStore over the
    same path — recovery must come entirely from the journal + stage
    files on disk, not from in-memory state."""
    img_a = rimfs.pack({"w": rng.randn(8).astype(np.float32)})
    img_b = rimfs.pack({"w": rng.randn(8).astype(np.float32)})
    path = tmp_path / "store.rimfs"
    store = rimfs.ImageStore(img_a, path=path)

    with pytest.raises(IntegrityError):          # crash after commit mark
        store.install(img_b, fail_at="after_commit")
    survivor = rimfs.ImageStore(path=path)       # fresh process
    assert survivor.image() == img_a             # flip never landed
    rep = survivor.fsck(strict=True)
    assert len(rep["replayed"]) == 1
    assert survivor.image() == img_b             # redo from staged bytes
    assert path.read_bytes() == img_b

    with pytest.raises(IntegrityError):          # crash before commit
        survivor.install(img_a, fail_at="after_stage")
    survivor2 = rimfs.ImageStore(path=path)
    rep = survivor2.fsck(strict=True)
    assert len(rep["rolled_back"]) == 1          # undo: stays on img_b
    assert survivor2.image() == img_b
    assert not survivor2.journal.pending()


def test_image_store_plain_install_roundtrip(rng):
    img = rimfs.pack({"w": rng.randn(4).astype(np.float32)})
    store = rimfs.ImageStore()
    with pytest.raises(rimfs.RIMFSError, match="empty"):
        store.mount()
    store.install(img)
    fs = store.mount()
    assert fs.files() == ["w"]
    assert store.fsck(strict=True)["image"]["ok"]


# --------------------------------------------------------------- watchdog
def test_watchdog_fires_once_per_dispatch():
    fired = []
    wd = Watchdog(budget_fn=lambda item: 0.05, on_hang=fired.append,
                  poll=0.01)
    try:
        wd.arm("x")
        time.sleep(0.3)                      # budget blown several times
        assert fired == ["x"]                # ...but exactly one fire
        wd.disarm()
        wd.arm("y")
        wd.disarm()                          # finished in time
        time.sleep(0.1)
        assert fired == ["x"]
        assert wd.stats["preemptions"] == 1
    finally:
        wd.close()


def test_watchdog_boot_grace_none_budget():
    fired = []
    wd = Watchdog(budget_fn=lambda item: None, on_hang=fired.append,
                  poll=0.01)
    try:
        wd.arm("unwatched")
        time.sleep(0.1)
        assert fired == []                   # no EWMA evidence: no deadline
        assert wd.stats["armed"] == 0
    finally:
        wd.close()


def test_service_loop_watchdog_preempts_hung_dispatch():
    """The loop-level integration: a hung handler is preempted via
    on_hang, which breaks the wedge (here: a gate, standing in for the
    TileFailure path) — the worker survives and keeps serving."""
    plat = Platform()
    gate = threading.Event()
    preempted = []
    handled = []

    def handler(item):
        if item == "hang":
            gate.wait(10)                    # wedged until preemption
        handled.append(item)

    loop = ServiceLoop(plat, handler, max_queue=8, poll=0.01,
                       watchdog_budget=lambda it: 0.1,
                       on_hang=lambda it: (preempted.append(it),
                                           gate.set()),
                       watchdog_poll=0.01)
    try:
        assert loop.submit("hang")
        assert loop.submit("next")
        deadline = time.monotonic() + 5
        while len(handled) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert preempted == ["hang"]         # watchdog broke the wedge
        assert handled == ["hang", "next"]   # worker lived on
    finally:
        loop.close(drain=False, timeout=2)


def test_close_racing_watchdog_preemption_drops_once():
    """Satellite: ``close(timeout=)`` racing a watchdog preemption —
    the preempted in-flight dispatch lands in ``on_drop`` exactly once,
    and the worker exits once the preemption unwedges it."""
    plat = Platform()
    gate = threading.Event()
    started = threading.Event()
    dropped, preempted = [], []

    def handler(item):
        started.set()
        gate.wait(10)                        # wedged until preempted

    loop = ServiceLoop(plat, handler, max_queue=8, poll=0.01,
                       on_drop=dropped.append,
                       watchdog_budget=lambda it: 0.5,
                       on_hang=lambda it: (preempted.append(it),
                                           gate.set()),
                       watchdog_poll=0.01)
    assert loop.submit("victim")
    assert started.wait(5)
    # close with a timeout shorter than the watchdog budget: the worker
    # is wedged, so close hands the in-flight item to on_drop and exits
    loop.close(drain=True, timeout=0.1)
    assert dropped == ["victim"]             # exactly once, no dupes
    # the preemption then fires and unwedges the worker -> clean exit
    loop._thread.join(timeout=5)
    assert not loop.alive()
    assert preempted == ["victim"]
    assert dropped == ["victim"]             # drop not repeated on exit


# -------------------------------------------------------- counters plumb
def test_platform_counts_integrity_events():
    plat = Platform()
    plat.post("integrity_error", {"n": 2})
    plat.post("watchdog_preempt", {})
    plat.post("dma_retry", {"n": 3})
    assert plat.telemetry.counter("integrity_errors") == 2
    assert plat.telemetry.counter("watchdog_preemptions") == 1
    assert plat.telemetry.counter("dma_retries") == 3
    assert plat.telemetry.counters()["integrity_errors"] == 2


def test_partitioned_corruption_recovers_bit_identical(rng):
    """End-to-end through the partitioned path: corrupt a cut-edge
    stream payload, the redeeming stage's driver retries in place, the
    answer stays bit-identical and the platform counters move."""
    import chaos
    depth, n = 4, 16
    prog = rctc.compile_gemm_chain(depth, n)
    files = rctc.gemm_chain_weights(depth, n)
    fs = rimfs.mount(rimfs.pack(files))
    x = rng.randn(n, n).astype(np.float32)
    from repro.core.executor import Executor
    ref = Executor().run(rbl.bind(prog, rimfs=fs, inputs={"input": x}))

    plat = Platform()
    mesh = rhal.TileMesh(2)
    undo, state = chaos.corrupt_dma_payload(mesh, 1, count=2)
    try:
        bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
        out = plat.run_partitioned(bound, mesh=mesh, rimfs=fs)
    finally:
        undo()
    assert state["corrupted"] >= 1
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(out[k]))
    drv = mesh.group(1).driver
    assert drv.stats["dma_retry_recovered"] == state["corrupted"]
    assert plat.telemetry.counter("dma_retries") >= 1
    assert plat.telemetry.counter("integrity_errors") >= 1
