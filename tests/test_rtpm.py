"""RTPM: event dispatch, heartbeats/stragglers, telemetry CV, provisioning."""
import numpy as np

from repro.core import rctc, rimfs
from repro.core.executor import Executor
from repro.core.rtpm import EventDispatcher, HeartbeatMonitor, Platform, \
    Telemetry


def test_event_dispatch_fanout():
    d = EventDispatcher()
    seen = []
    d.register("x", lambda p: seen.append(("a", p["v"])))
    d.register("x", lambda p: seen.append(("b", p["v"])))
    d.post("x", {"v": 1})
    d.post("y", {})
    assert d.process() == 2
    assert seen == [("a", 1), ("b", 1)]
    assert d.dropped == 1                     # unhandled "y"


def test_heartbeat_failure_and_straggler():
    t = [0.0]
    mon = HeartbeatMonitor(deadline=10.0, straggler_factor=2.0,
                           clock=lambda: t[0])
    for w in ("w0", "w1", "w2"):
        mon.beat(w, step=10)
    t[0] = 6.0
    mon.beat("w0", step=11)                    # w1/w2 now 6s stale (> 10/2)
    v = mon.check()
    assert set(v["stragglers"]) == {"w1", "w2"}
    assert v["failed"] == []
    t[0] = 17.0                                # w1/w2 now 17s stale (> 10)
    mon.beat("w0", step=12)                    # w0 stays healthy
    v = mon.check()
    assert set(v["failed"]) == {"w1", "w2"}
    # dead workers stay dead
    assert mon.check()["failed"] == []


def test_step_lag_marks_straggler():
    t = [0.0]
    mon = HeartbeatMonitor(deadline=100.0, clock=lambda: t[0])
    mon.beat("fast1", step=50)
    mon.beat("fast2", step=51)
    mon.beat("slow", step=10)
    v = mon.check()
    assert "slow" in v["stragglers"]


def test_telemetry_cv():
    tel = Telemetry()
    rng = np.random.RandomState(0)
    for _ in range(1000):
        tel.record_latency(1e-3 + rng.randn() * 1e-6)
    s = tel.summary(warmup=10)
    assert s["n"] == 990
    assert s["cv_percent"] < 1.0
    assert s["p99"] >= s["p50"] >= s["min"]


def test_platform_provision_bind_run(rng):
    """The paper's 4-phase flow end to end through the Platform."""
    prog = rctc.compile_matmul(16)
    img = rimfs.pack({"b": rng.randn(16, 16).astype(np.float32)})
    plat = Platform()
    plat.provision(image=img, program_bytes=prog.encode())
    assert plat.time_to_service() >= 0
    bound = plat.bind(inputs={"a": rng.randn(16, 16).astype(np.float32)})
    ex = Executor(rtpm=plat)
    out = ex.run(bound)
    assert out["output"].shape == (16, 16)


def test_platform_rejects_corrupt_image(rng):
    import pytest

    from repro.core.rimfs import RIMFSError
    img = bytearray(rimfs.pack({"w": rng.randn(8).astype(np.float32)}))
    img[-2] ^= 0xFF
    with pytest.raises(RIMFSError):
        Platform().provision(image=bytes(img))
