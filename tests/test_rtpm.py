"""RTPM: event dispatch, heartbeats/stragglers, telemetry CV, provisioning,
the ServiceLoop dispatcher worker, and tile-group fault injection (kill a
worker mid-program -> heartbeat detection -> stage re-queue on a survivor
-> reference-identical output)."""
import threading
import time

import numpy as np

import jax

from repro.core import rbl, rctc, rhal, rimfs
from repro.core.executor import Executor
from repro.core.rtpm import EventDispatcher, HeartbeatMonitor, Platform, \
    ServiceLoop, Telemetry


def test_event_dispatch_fanout():
    d = EventDispatcher()
    seen = []
    d.register("x", lambda p: seen.append(("a", p["v"])))
    d.register("x", lambda p: seen.append(("b", p["v"])))
    d.post("x", {"v": 1})
    d.post("y", {})
    assert d.process() == 2
    assert seen == [("a", 1), ("b", 1)]
    assert d.dropped == 1                     # unhandled "y"


def test_heartbeat_failure_and_straggler():
    t = [0.0]
    mon = HeartbeatMonitor(deadline=10.0, straggler_factor=2.0,
                           clock=lambda: t[0])
    for w in ("w0", "w1", "w2"):
        mon.beat(w, step=10)
    t[0] = 6.0
    mon.beat("w0", step=11)                    # w1/w2 now 6s stale (> 10/2)
    v = mon.check()
    assert set(v["stragglers"]) == {"w1", "w2"}
    assert v["failed"] == []
    t[0] = 17.0                                # w1/w2 now 17s stale (> 10)
    mon.beat("w0", step=12)                    # w0 stays healthy
    v = mon.check()
    assert set(v["failed"]) == {"w1", "w2"}
    # dead workers stay dead
    assert mon.check()["failed"] == []


def test_step_lag_marks_straggler():
    t = [0.0]
    mon = HeartbeatMonitor(deadline=100.0, clock=lambda: t[0])
    mon.beat("fast1", step=50)
    mon.beat("fast2", step=51)
    mon.beat("slow", step=10)
    v = mon.check()
    assert "slow" in v["stragglers"]


def test_telemetry_cv():
    tel = Telemetry()
    rng = np.random.RandomState(0)
    for _ in range(1000):
        tel.record_latency(1e-3 + rng.randn() * 1e-6)
    s = tel.summary(warmup=10)
    assert s["n"] == 990
    assert s["cv_percent"] < 1.0
    assert s["p99"] >= s["p50"] >= s["min"]


def test_platform_provision_bind_run(rng):
    """The paper's 4-phase flow end to end through the Platform."""
    prog = rctc.compile_matmul(16)
    img = rimfs.pack({"b": rng.randn(16, 16).astype(np.float32)})
    plat = Platform()
    plat.provision(image=img, program_bytes=prog.encode())
    assert plat.time_to_service() >= 0
    bound = plat.bind(inputs={"a": rng.randn(16, 16).astype(np.float32)})
    ex = Executor(rtpm=plat)
    out = ex.run(bound)
    assert out["output"].shape == (16, 16)


def test_platform_rejects_corrupt_image(rng):
    import pytest

    from repro.core.rimfs import RIMFSError
    img = bytearray(rimfs.pack({"w": rng.randn(8).astype(np.float32)}))
    img[-2] ^= 0xFF
    with pytest.raises(RIMFSError):
        Platform().provision(image=bytes(img))


# ---------------------------------------------------------------------------
# ServiceLoop (the single-owner dispatcher worker)
# ---------------------------------------------------------------------------

def _wait_until(pred, timeout=10.0):
    deadline = time.monotonic() + timeout
    while not pred():
        if time.monotonic() > deadline:
            return False
        time.sleep(0.005)
    return True


def test_service_loop_processes_in_order_and_heartbeats():
    plat = Platform()
    seen = []
    loop = ServiceLoop(plat, seen.append, name="w0", max_queue=16,
                       poll=0.01)
    try:
        assert all(loop.submit(i) for i in range(5))
        assert _wait_until(lambda: len(seen) == 5)
        assert seen == [0, 1, 2, 3, 4]        # one thread, FIFO order
        w = plat.heartbeats.workers["w0"]
        assert w.alive and w.step == 5
        assert loop.stats["processed"] == 5
        assert loop.queue_wait.summary()["n"] == 5
    finally:
        loop.close()


def test_service_loop_backpressure_then_drain():
    plat = Platform()
    gate = threading.Event()
    started = threading.Event()
    seen = []

    def handler(item):
        started.set()
        gate.wait(10)
        seen.append(item)

    loop = ServiceLoop(plat, handler, max_queue=2, poll=0.01)
    assert loop.submit("a")
    assert started.wait(5)                    # "a" dequeued, worker gated
    assert loop.submit("b") and loop.submit("c")
    assert not loop.submit("d")               # queue full -> rejected
    assert loop.stats["rejected"] == 1
    gate.set()
    loop.close(drain=True)                    # graceful: b/c still processed
    assert seen == ["a", "b", "c"]
    assert not loop.submit("e")               # draining rejects new work
    assert loop.stats["rejected"] == 2


def test_service_loop_handler_error_does_not_kill_worker():
    plat = Platform()
    seen = []

    def handler(item):
        if item == "boom":
            raise RuntimeError("boom")
        seen.append(item)

    loop = ServiceLoop(plat, handler, poll=0.01)
    try:
        loop.submit("boom")
        loop.submit("ok")
        assert _wait_until(lambda: seen == ["ok"])
        assert loop.stats["errors"] == 1
        assert loop.stats["processed"] == 2
    finally:
        loop.close()


def test_service_loop_on_idle_pumps_between_items():
    plat = Platform()
    pumped = {"n": 0, "left": 3}

    def on_idle():
        if pumped["left"] > 0:
            pumped["left"] -= 1
            pumped["n"] += 1
            return True
        return False

    loop = ServiceLoop(plat, lambda item: None, poll=0.01, on_idle=on_idle)
    try:
        assert _wait_until(lambda: pumped["n"] == 3)
    finally:
        loop.close()


def test_service_loop_accepted_submits_survive_racing_close():
    """A submit that returned True is never silently dropped by a
    concurrent close(drain=True): the drain sentinel always lands after
    every accepted item."""
    plat = Platform()
    seen = []
    loop = ServiceLoop(plat, seen.append, max_queue=4096, poll=0.005)
    accepted = []

    def produce(base):
        for i in range(300):
            if loop.submit(base + i):
                accepted.append(base + i)

    producers = [threading.Thread(target=produce, args=(t * 1000,))
                 for t in range(4)]
    closer = threading.Thread(target=lambda: loop.close(drain=True))
    for t in producers:
        t.start()
    closer.start()
    for t in producers:
        t.join()
    closer.join()
    assert set(accepted) <= set(seen)


def test_service_loop_forced_close_hands_back_dropped_items():
    """close(drain=False) never silently discards accepted work — every
    dropped item goes to on_drop so its submitter can be refused."""
    plat = Platform()
    gate = threading.Event()
    started = threading.Event()
    handled, dropped = [], []

    def handler(item):
        started.set()
        gate.wait(10)
        handled.append(item)

    loop = ServiceLoop(plat, handler, max_queue=8, poll=0.01,
                       on_drop=dropped.append)
    assert loop.submit("a")
    assert started.wait(5)                    # worker holds "a"
    assert loop.submit("b") and loop.submit("c")
    closer = threading.Thread(target=lambda: loop.close(drain=False))
    closer.start()
    deadline = time.monotonic() + 5
    while len(dropped) < 2 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert dropped == ["b", "c"]              # refused, not vanished
    gate.set()
    closer.join(timeout=10)
    assert handled == ["a"]


def test_event_dispatcher_concurrent_posts_lose_nothing():
    d = EventDispatcher()
    seen = []
    d.register("tick", lambda p: seen.append(p["v"]))
    n_threads, per_thread = 4, 200

    def produce(base):
        for i in range(per_thread):
            d.post("tick", {"v": base + i})

    threads = [threading.Thread(target=produce, args=(t * 1000,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    d.process()
    assert sorted(seen) == sorted(t * 1000 + i for t in range(n_threads)
                                  for i in range(per_thread))


# ---------------------------------------------------------------------------
# Tile-group fault injection (partitioned execution under RTPM)
# ---------------------------------------------------------------------------

def _chain_setup(depth=4, n=16, seed=0):
    prog = rctc.compile_gemm_chain(depth, n)
    files = rctc.gemm_chain_weights(depth, n)
    fs = rimfs.mount(rimfs.pack(files))
    x = np.random.RandomState(seed).randn(n, n).astype(np.float32)
    ref = Executor().run(rbl.bind(prog, rimfs=fs, inputs={"input": x}))
    ref = {k: np.asarray(jax.block_until_ready(v)) for k, v in ref.items()}
    return prog, fs, x, ref


def test_tile_failure_detected_and_stage_requeued(rng):
    """Kill a tile group mid-program: HeartbeatMonitor flags it dead,
    Platform re-queues the orphaned stage on a surviving group, and the
    final output is bit-identical to the single-device reference."""
    prog, fs, x, ref = _chain_setup()
    t = {"now": 0.0}
    plat = Platform(deadline=5.0, clock=lambda: t["now"])
    mesh = rhal.TileMesh(2)
    seen = {"failed": [], "requeued": []}
    plat.events.register("worker_failed",
                         lambda p: seen["failed"].append(p))
    plat.events.register("stage_requeued",
                         lambda p: seen["requeued"].append(p))

    def killer(p):
        if p["stage"] == 0:            # group 1's stage has NOT run yet
            mesh.kill(1)
            t["now"] += 10.0           # past the 5 s heartbeat deadline
    plat.events.register("stage_complete", killer)

    bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    out = plat.run_partitioned(bound, mesh=mesh, rimfs=fs)

    # detection: the monitor (not the exception path) judged tile1 dead —
    # live groups answered the liveness sweep, the killed one could not
    assert plat.heartbeats.workers["tile1"].alive is False
    assert any("tile1" in p["workers"] for p in seen["failed"])
    # re-queue: stage 1 moved to the surviving group 0
    assert seen["requeued"] and seen["requeued"][0]["from"] == 1
    assert seen["requeued"][0]["to"] == 0
    # output survives the failover bit-identically
    assert set(out) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(
            ref[k], np.asarray(jax.block_until_ready(out[k])))


def test_tile_failure_on_first_stage_fails_over(rng):
    """A group dead BEFORE its first dispatch: the stage never starts
    there — it re-queues and the program still completes correctly."""
    prog, fs, x, ref = _chain_setup()
    t = {"now": 0.0}
    plat = Platform(deadline=5.0, clock=lambda: t["now"])
    mesh = rhal.TileMesh(3)
    mesh.kill(0)
    t["now"] = 10.0                    # group 0 silent past the deadline
    bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    out = plat.run_partitioned(bound, mesh=mesh, rimfs=fs)
    assert plat.heartbeats.workers["tile0"].alive is False
    for k in ref:
        np.testing.assert_array_equal(
            ref[k], np.asarray(jax.block_until_ready(out[k])))


def test_all_tiles_dead_raises(rng):
    import pytest
    prog, fs, x, _ = _chain_setup(depth=2)
    mesh = rhal.TileMesh(2)
    mesh.kill(0)
    mesh.kill(1)
    bound = rbl.bind(prog, rimfs=fs, inputs={"input": x})
    with pytest.raises(rhal.TileFailure):
        Executor().run_partitioned(bound, rimfs=fs, mesh=mesh)


def test_heartbeat_ewma_straggler_verdict():
    """Satellite (ISSUE 6): a worker with an established beat rhythm is
    flagged ``straggler`` once its silence exceeds the EWMA of its own
    inter-beat gaps times ``straggler_factor`` — long before the
    wall-clock deadline would notice."""
    t = [0.0]
    mon = HeartbeatMonitor(deadline=1000.0, straggler_factor=3.0,
                           clock=lambda: t[0])
    for i in range(1, 6):                      # rhythm: one beat per 1.0s
        t[0] = float(i)
        mon.beat("rhythmic", step=i)
        mon.beat("other", step=i)
    assert abs(mon.workers["rhythmic"].gap_ewma - 1.0) < 1e-9
    t[0] = 10.0
    mon.beat("other", step=6)                  # keeps beating (gap ewma
    mon.beat("fresh", step=5)                  # adapts); fresh: one beat,
    v = mon.check()                            # no rhythm yet
    assert v["verdicts"]["rhythmic"] == "straggler"   # 5s silent vs ~1s
    assert v["verdicts"]["other"] == "ok"
    assert v["verdicts"]["fresh"] == "ok"      # no EWMA -> no verdict
    assert v["failed"] == []                   # alive, not dead: 5s << 1000s
    t[0] = 10.5
    mon.beat("rhythmic", step=6)               # it was just slow — beats
    assert mon.check()["verdicts"]["rhythmic"] == "ok"


def test_service_loop_close_wedged_handler_times_out_and_hands_back():
    """Satellite (ISSUE 6, extended by ISSUE 7): close(drain=True,
    timeout=...) against a wedged handler honours the timeout, hands
    every still-queued item AND the wedged in-flight item to on_drop
    (its submitter must be refused, not parked forever; downstream
    reply-once guards make a late handler completion harmless), and
    leaves the heartbeat monitor to report the dispatcher dead — no
    indefinite hang, no silently vanished work."""
    t = {"now": 0.0}
    plat = Platform(deadline=5.0, clock=lambda: t["now"])
    gate = threading.Event()
    started = threading.Event()
    handled, dropped = [], []

    def handler(item):
        started.set()
        gate.wait(30)                          # wedged mid-item
        handled.append(item)

    loop = ServiceLoop(plat, handler, max_queue=8, poll=0.01,
                       on_drop=dropped.append)
    try:
        assert loop.submit("a")
        assert started.wait(5)                 # worker holds "a"
        assert loop.submit("b") and loop.submit("c")
        w0 = time.monotonic()
        loop.close(drain=True, timeout=0.4)
        elapsed = time.monotonic() - w0
        assert elapsed < 3.0                   # timeout honoured, no hang
        assert loop.alive()                    # worker is still wedged
        # pending work handed back, then the wedged in-flight item too
        assert dropped == ["b", "c", "a"]
        t["now"] = 10.0                        # silence past the deadline
        v = plat.heartbeats.check()
        assert "dispatcher" in v["failed"]     # monitor calls it dead
    finally:
        gate.set()                             # late unwedge: worker must
    loop._thread.join(timeout=10)              # exit via re-armed sentinel
    assert not loop.alive()
    assert handled == ["a"]
