"""The paper's serving path ON A MESH: RBL binds the LM service program's
params with NamedShardings resolved from TensorDescs, and the GRAPH_EXEC
artifacts run as sharded fused steps (8-device subprocess)."""
import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def test_sharded_lm_service_via_rcb():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=REPO_SRC)
    script = textwrap.dedent("""
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.core import rctc
    from repro.core.rbl import bind, resolve_shardings
    from repro.core.executor import Executor
    from repro.distributed.sharding import axis_rules
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models import transformer as tf
    from repro.models.common import init_params, param_shardings

    cfg = get_config("qwen2-1.5b-smoke")
    cfg = dataclasses.replace(cfg, d_model=64, num_heads=4, num_kv_heads=4,
                              head_dim=16, d_ff=128, vocab_size=256)
    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    B, S = 2, 16
    with axis_rules(mesh, "decode"):
        specs = tf.model_specs(cfg)
        params = init_params(jax.random.PRNGKey(0), specs)
        shardings = param_shardings(specs)
        params = jax.device_put(params, shardings)

        prefill = jax.jit(make_prefill_step(cfg))
        decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
        prog = rctc.compile_lm_service(cfg, B, S, prefill, decode)

        # RBL resolves the program's symbolic tensor shardings on the mesh
        sh = resolve_shardings(prog)
        assert sh["tokens"] is not None            # batch-sharded input

        bound = bind(prog, inputs={})
        ex = Executor()
        toks = jnp.asarray(np.random.RandomState(0)
                           .randint(0, 256, (B, S)))
        cache = init_params(jax.random.PRNGKey(1),
                            tf.cache_specs(cfg, B, S + 8))
        with mesh:
            # Dispatch phase: GRAPH_EXEC artifacts through the executor
            buffers = dict(bound.buffers)
            buffers.update({"params": params, "tokens": toks})
            logits, pc = prog.artifacts["prefill"](params,
                                                   {"inputs": toks})
            cache = dict(cache)
            cache["k"] = cache["k"].at[:, :, :S].set(
                pc["k"].astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, :, :S].set(
                pc["v"].astype(cache["v"].dtype))
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            l2, cache = prog.artifacts["decode"](
                params, cache, {"inputs": nxt,
                                "pos": jnp.full((B,), S, jnp.int32)})
        assert l2.shape == (B, 256)
        assert bool(jnp.all(jnp.isfinite(l2)))
    print("ok")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
