"""Serving: wire protocol, socket server, LM engine with batched requests."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import rctc, rhal, rimfs
from repro.models import resnet as rn
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.serving import protocol as proto
from repro.serving.engine import (Request, ServingEngine, pack_params_image,
                                  params_from_rimfs)
from repro.serving.scheduler import DeadlineScheduler
from repro.serving.server import Client, InferenceServer


def test_frame_roundtrip():
    payload = b"hello aeg" * 100
    kind, back = proto.decode_frame(
        proto.encode_frame(proto.Msg.INFER_REQUEST, payload))
    assert kind == proto.Msg.INFER_REQUEST and back == payload


def test_frame_crc_detects_corruption():
    frame = bytearray(proto.encode_frame(proto.Msg.TELEMETRY, b"x" * 64))
    frame[20] ^= 1
    with pytest.raises(proto.ProtocolError, match="CRC"):
        proto.decode_frame(bytes(frame))


def test_frame_v2_roundtrip_carries_request_id_and_flags():
    payload = b"response" * 16
    f = proto.decode_frame_ex(proto.encode_frame(
        proto.Msg.INFER_RESPONSE, payload, request_id=77,
        flags=proto.F_SHED))
    assert f.kind == proto.Msg.INFER_RESPONSE and f.payload == payload
    assert f.request_id == 77 and f.flags == proto.F_SHED and f.version == 2


def test_frame_v1_decodes_through_extended_decoder():
    f = proto.decode_frame_ex(proto.encode_frame(proto.Msg.HEARTBEAT, b"hb"))
    assert (f.kind, f.payload, f.request_id, f.flags, f.version) == \
        (proto.Msg.HEARTBEAT, b"hb", 0, 0, 1)


def test_frame_v2_crc_detects_corruption():
    frame = bytearray(proto.encode_frame(proto.Msg.INFER_RESPONSE,
                                         b"y" * 64, request_id=3))
    frame[22] ^= 1
    with pytest.raises(proto.ProtocolError, match="CRC"):
        proto.decode_frame_ex(bytes(frame))


def test_decode_frame_enforces_length_cap_before_parsing():
    head = proto.HEADER.pack(proto.MAGIC, int(proto.Msg.INFER_REQUEST),
                             0xFFFF_FFF0)
    with pytest.raises(proto.ProtocolError, match="MAX_FRAME"):
        proto.decode_frame_ex(head, max_frame=1 << 10)


def test_decode_frame_rejects_unknown_type():
    head = proto.HEADER.pack(proto.MAGIC, 0x55, 0)
    with pytest.raises(proto.ProtocolError, match="unknown"):
        proto.decode_frame_ex(head + b"\x00" * 4)


def test_tensor_payload_roundtrip(rng):
    t = {"a": rng.randn(3, 4).astype(np.float32),
         "b": rng.randint(0, 9, (2,), dtype=np.int32)}
    back = proto.unpack_tensors(proto.pack_tensors(t))
    for k in t:
        np.testing.assert_array_equal(t[k], back[k])


def test_network_service_end_to_end(rng):
    """Provision ResNet over the wire, run batched inference, read CV
    telemetry — the paper's network-attached deployment."""
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    folded = rn.fold_bn(params)
    prog, image = rctc.compile_resnet18(cfg, folded, batch=2)

    server = InferenceServer()
    addr = server.start()
    try:
        client = Client(addr)
        status = client.provision(image, prog.encode())
        assert status["status"] == "ready"
        x = rng.rand(2, cfg.image_size, cfg.image_size, 3).astype(np.float32)
        for _ in range(5):
            out = client.infer(input=x)
        ref = np.asarray(rn.resnet_forward(cfg, params, jnp.asarray(x)))
        np.testing.assert_allclose(out["output"], ref, atol=1e-5)
        tel = client.telemetry()
        assert tel["n"] >= 4 and "cv_percent" in tel
        client.close()
    finally:
        server.stop()


def test_batched_prefill_matches_sequential_admission(rng):
    """Regression for the grouped-prefill admission path: prompts that
    prefill together as one (k, S) dispatch must produce the SAME tokens
    as the same prompts admitted one at a time (batch-1 prefill each) —
    otherwise engine output becomes admission-timing-dependent."""
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    prompts = [rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(3)]

    # grouped: all three admitted in one _admit -> one (3, 6) prefill
    eng_b = ServingEngine(cfg, params, max_batch=3, max_seq=64)
    batched = [Request(rid=i, prompt=p, max_new=4)
               for i, p in enumerate(prompts)]
    for r in batched:
        eng_b.submit(r)
    eng_b.run_until_drained()

    # sequential: one slot -> every prefill is batch-1
    eng_s = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    serial = []
    for i, p in enumerate(prompts):
        r = Request(rid=i, prompt=p, max_new=4)
        eng_s.submit(r)
        eng_s.run_until_drained()
        serial.append(r)

    for rb, rs in zip(batched, serial):
        assert rb.out_tokens == rs.out_tokens


def test_lm_engine_batched_requests(rng):
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size, (8,))
                    .astype(np.int32),
                    max_new=4)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) >= 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out_tokens)


def test_engine_feeds_scheduler_latency_ewma(rng):
    """The admission policy's EWMA must track REAL decode latencies, not
    the constructor default (eta/shedding ran on 1e-2 forever)."""
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    sched = DeadlineScheduler(step_latency_estimate=123.0)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                        scheduler=sched)
    eng.submit(Request(rid=0, prompt=rng.randint(
        0, cfg.vocab_size, (4,)).astype(np.int32), max_new=3))
    eng.run_until_drained()
    # EWMA moved off the seed value toward measured step latency (which is
    # far below 123 s on any machine)
    assert sched.est < 123.0
    assert sched.est > 0.0


def test_engine_routes_through_scheduler_and_sheds(rng):
    """ISSUE 4 satellite: submit() routes through scheduler.submit and
    _admit() through scheduler.admit — an infeasible deadline is shed
    BEFORE any compute, marked done with an observable verdict."""
    import time as time_mod
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    sched = DeadlineScheduler()
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                        scheduler=sched)
    prompt = rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
    good = Request(rid=0, prompt=prompt, max_new=3)
    bad = Request(rid=1, prompt=prompt, max_new=3,
                  deadline=time_mod.monotonic() - 1.0)   # already past
    eng.submit(good)
    eng.submit(bad)
    assert sched.pending() == 2       # queued in the scheduler, not FIFO
    eng.run_until_drained()
    assert bad.done and bad.shed and "shed" in bad.verdict
    assert bad.out_tokens == []       # no compute spent on the shed request
    assert good.done and not good.shed and good.verdict == "admitted"
    assert len(good.out_tokens) >= 3
    assert sched.shed_count == 1


def test_engine_from_rimfs_zero_reupload(rng):
    """Repeated engine construction over one RIMFS image re-binds pinned
    weights: the driver's DMA counters must not move the second time."""
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    img = pack_params_image(params)
    fs = rimfs.mount(img)
    drv = rhal.make_eager_driver()
    eng1 = ServingEngine.from_rimfs(cfg, fs, driver=drv, max_batch=2,
                                    max_seq=64)
    uploaded = drv.stats.get("dma_bytes", 0)
    assert uploaded > 0
    snapshot = dict(drv.stats)
    eng2 = ServingEngine.from_rimfs(cfg, fs, driver=drv, max_batch=2,
                                    max_seq=64)
    for key in ("dma", "dma_async", "dma_bytes"):
        assert drv.stats.get(key, 0) == snapshot.get(key, 0), key
    # both engines decode identically from the shared pinned weights
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    r1 = Request(rid=0, prompt=prompt, max_new=3)
    r2 = Request(rid=1, prompt=prompt, max_new=3)
    eng1.submit(r1)
    eng2.submit(r2)
    eng1.run_until_drained()
    eng2.run_until_drained()
    assert r1.out_tokens == r2.out_tokens


def test_engine_accepts_tile_mesh(rng):
    """ServingEngine provisions from a TileMesh in place of one driver:
    weights pin into the primary tile group's arena (same zero-reupload
    residency), the mesh rides on the engine, and decode matches a
    single-driver engine token for token."""
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    fs = rimfs.mount(pack_params_image(params))
    mesh = rhal.TileMesh(2)
    eng_m = ServingEngine.from_rimfs(cfg, fs, driver=mesh, max_batch=2,
                                     max_seq=64)
    assert eng_m.mesh is mesh
    primary = mesh.primary
    uploaded = primary.stats.get("dma_bytes", 0)
    assert uploaded > 0                       # pinned into group 0's arena
    snapshot = dict(primary.stats)
    ServingEngine.from_rimfs(cfg, fs, driver=mesh, max_batch=2, max_seq=64)
    assert primary.stats.get("dma_bytes", 0) == snapshot.get("dma_bytes", 0)
    drv = rhal.make_eager_driver()
    eng_d = ServingEngine.from_rimfs(cfg, fs, driver=drv, max_batch=2,
                                     max_seq=64)
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    r1 = Request(rid=0, prompt=prompt, max_new=3)
    r2 = Request(rid=1, prompt=prompt, max_new=3)
    eng_m.submit(r1)
    eng_d.submit(r2)
    eng_m.run_until_drained()
    eng_d.run_until_drained()
    assert r1.out_tokens == r2.out_tokens


def test_params_rimfs_roundtrip_matches(rng):
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    fs = rimfs.mount(pack_params_image(params))
    back = params_from_rimfs(cfg, fs)
    flat_a = jax.tree_util.tree_leaves(params)
    flat_b = jax.tree_util.tree_leaves(back)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lm_engine_matches_offline_decode(rng):
    """Engine tokens == straight greedy decode with the same params."""
    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    prompt = rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)

    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(req)
    eng.run_until_drained()

    # offline: full forward re-run per token (slow but unimpeachable)
    toks = list(prompt)
    out = []
    for _ in range(4):
        logits, _, _ = tf.forward_full(
            cfg, params, jnp.asarray(np.asarray(toks))[None, :])
        t = int(jnp.argmax(logits[0, -1]))
        out.append(t)
        toks.append(t)
    assert req.out_tokens[:4] == out
