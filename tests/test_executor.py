"""Executor: eager == fused over the op vocabulary; paper's data-path checks."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import rbl, rctc, rimfs
from repro.core.executor import Executor
from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc


def test_xgemm_64_exact_match(rng):
    """Paper §4.3: all 4096 outputs of the 64x64 XGEMM match the reference."""
    prog = rctc.compile_matmul(64)
    a = rng.randn(64, 64).astype(np.float32)
    b = rng.randn(64, 64).astype(np.float32)
    img = rimfs.pack({"b": b})
    bound = rbl.bind(prog, rimfs=rimfs.mount(img), inputs={"a": a})
    out = np.asarray(Executor().run(bound)["output"])
    ref = a @ b
    matches = int(np.sum(np.isclose(out, ref, rtol=1e-5, atol=1e-5)))
    assert matches == 4096, f"{matches}/4096"


def test_conv_relu_softmax_pipeline(rng):
    """Paper §4.3: the 9-output neural pipeline matches NumPy exactly."""
    prog = rctc.compile_conv_relu_softmax(n=1, h=8, w=8, cin=3, cout=9)
    x = rng.randn(1, 8, 8, 3).astype(np.float32)
    w = rng.randn(3, 3, 3, 9).astype(np.float32)
    bound = rbl.bind(prog, rimfs=rimfs.mount(rimfs.pack({"w_conv": w})),
                     inputs={"input": x})
    out = np.asarray(Executor().run(bound)["output"])
    # NumPy reference
    import jax
    ref = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    ref = np.maximum(np.asarray(ref), 0).mean(axis=(1, 2))
    ref = np.exp(ref - ref.max()) / np.exp(ref - ref.max()).sum()
    assert out.shape == (1, 9)
    matches = int(np.sum(np.isclose(out, ref, rtol=1e-5, atol=1e-6)))
    assert matches == 9, f"{matches}/9"


def _mixed_program():
    """Touch every compute opcode once."""
    t = {
        "x": TensorDesc("x", (4, 8, 8, 3), "float32", "input"),
        "w": TensorDesc("w", (3, 3, 3, 4), "float32", "weight"),
        "scale": TensorDesc("scale", (4,), "float32", "weight"),
        "shift": TensorDesc("shift", (4,), "float32", "weight"),
        "fcw": TensorDesc("fcw", (4, 6), "float32", "weight"),
        "fcb": TensorDesc("fcb", (6,), "float32", "weight"),
        "t1": TensorDesc("t1", (4, 8, 8, 4), "float32", "scratch"),
        "t2": TensorDesc("t2", (4, 8, 8, 4), "float32", "scratch"),
        "t3": TensorDesc("t3", (4, 8, 8, 4), "float32", "scratch"),
        "t4": TensorDesc("t4", (4, 4, 4, 4), "float32", "scratch"),
        "t5": TensorDesc("t5", (4, 4), "float32", "scratch"),
        "t6": TensorDesc("t6", (4, 6), "float32", "scratch"),
        "out": TensorDesc("out", (4, 6), "float32", "output"),
    }
    ops = [
        RCBOp(Op.CONV2D, ("t1",), ("x", "w"), {"stride": [1, 1],
                                               "padding": "SAME"}),
        RCBOp(Op.SCALE_SHIFT, ("t2",), ("t1", "scale", "shift")),
        RCBOp(Op.RELU, ("t3",), ("t2",)),
        RCBOp(Op.MAXPOOL, ("t4",), ("t3",), {"window": [2, 2],
                                             "stride": [2, 2]}),
        RCBOp(Op.AVGPOOL_GLOBAL, ("t5",), ("t4",)),
        RCBOp(Op.DENSE, ("t6",), ("t5", "fcw", "fcb")),
        RCBOp(Op.SOFTMAX, ("out",), ("t6",)),
        RCBOp(Op.FENCE),
    ]
    return RCBProgram("mixed", t, [RCB(0, "layer", (), tuple(ops))])


def test_eager_equals_fused(rng):
    """The paper's portability property: the same RCBs drive both modes."""
    prog = _mixed_program()
    weights = {
        "w": rng.randn(3, 3, 3, 4).astype(np.float32),
        "scale": rng.rand(4).astype(np.float32) + 0.5,
        "shift": rng.randn(4).astype(np.float32),
        "fcw": rng.randn(4, 6).astype(np.float32),
        "fcb": rng.randn(6).astype(np.float32),
    }
    x = rng.randn(4, 8, 8, 3).astype(np.float32)
    fs = rimfs.mount(rimfs.pack(weights))
    ex = Executor()
    bound = rbl.bind(prog, rimfs=fs, inputs={"x": x})
    out_eager = np.asarray(ex.run(bound)["out"])

    bound2 = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound2)
    out_fused = np.asarray(fused({"x": x}, ex.weights_from(bound2))["out"])
    np.testing.assert_allclose(out_eager, out_fused, rtol=1e-6, atol=1e-6)


def test_liveness_frees_scratch(rng):
    prog = _mixed_program()
    last = rbl.liveness(prog)
    assert last["t1"] < last["t3"] < last["t5"]


def test_quant_dequant_ops(rng):
    t = {
        "x": TensorDesc("x", (8, 8), "float32", "input"),
        "q": TensorDesc("q", (8, 8), "int8", "scratch"),
        "y": TensorDesc("y", (8, 8), "float32", "output"),
    }
    ops = [RCBOp(Op.QUANTIZE, ("q",), ("x",), {"scale": 0.05}),
           RCBOp(Op.DEQUANT, ("y",), ("q",), {"scale": 0.05})]
    prog = RCBProgram("q", t, [RCB(0, "layer", (), tuple(ops))])
    x = (rng.rand(8, 8).astype(np.float32) - 0.5) * 10
    bound = rbl.bind(prog, inputs={"x": x})
    y = np.asarray(Executor().run(bound)["y"])
    np.testing.assert_allclose(y, np.clip(np.round(x / 0.05), -127, 127)
                               * 0.05, atol=1e-6)


def test_missing_input_raises(rng):
    prog = rctc.compile_matmul(8)
    img = rimfs.pack({"b": rng.randn(8, 8).astype(np.float32)})
    bound = rbl.bind(prog, rimfs=rimfs.mount(img))
    with pytest.raises(ValueError, match="missing input"):
        Executor().run(bound)


def test_driver_stats_count_dispatches(rng):
    prog = rctc.compile_matmul(16)
    img = rimfs.pack({"b": rng.randn(16, 16).astype(np.float32)})
    ex = Executor()
    bound = rbl.bind(prog, rimfs=rimfs.mount(img),
                     inputs={"a": rng.randn(16, 16).astype(np.float32)})
    ex.run(bound)
    assert ex.driver.stats.get("dispatch", 0) >= 1
    assert ex.driver.stats.get("fence", 0) >= 1
